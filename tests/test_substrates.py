"""Data / optimizer / checkpoint / compression substrate tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticTokens


def test_data_deterministic_skip_ahead():
    cfg = DataConfig(vocab=101, seq_len=32, global_batch=4, seed=7)
    a, b = SyntheticTokens(cfg), SyntheticTokens(cfg)
    for step in (0, 5, 1000, 123456):  # O(1) skip-ahead, any order
        x, y = a.batch(step), b.batch(step)
        assert np.array_equal(x["tokens"], y["tokens"])
        assert np.array_equal(x["labels"], y["labels"])
    assert not np.array_equal(a.batch(1)["tokens"], a.batch(2)["tokens"])


def test_data_shards_partition_batch():
    whole = SyntheticTokens(DataConfig(vocab=50, seq_len=8, global_batch=8))
    shard_batches = [
        SyntheticTokens(
            DataConfig(vocab=50, seq_len=8, global_batch=8, n_shards=2, shard=s)
        ).batch(3)["tokens"]
        for s in (0, 1)
    ]
    assert shard_batches[0].shape == (4, 8)
    assert not np.array_equal(shard_batches[0], shard_batches[1])


def test_adamw_decreases_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100,
                            schedule="constant")
    params = {"w": jnp.ones(4) * 5.0}
    state = optim.init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = optim.adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1.0
    assert m["grad_norm"] > 0


def test_lr_schedule_shapes():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(optim.learning_rate(cfg, s)) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[2] > lrs[3] > lrs[4] >= cfg.lr * cfg.min_lr_frac - 1e-6


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_int8_roundtrip_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256).astype(np.float32) * 10)
    q, s = optim.int8_compress(x)
    back = optim.int8_decompress(q, s)
    assert float(jnp.abs(back - x).max()) <= float(s) * 0.5 + 1e-6


def test_checkpoint_atomic_resume_and_retention():
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3)}, "step": jnp.int32(3)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3):
            mgr.save(s, tree, blocking=False)
        mgr.wait()
        assert mgr.all_steps() == [2, 3]  # latest-k retention
        out = mgr.restore(tree)
        np.testing.assert_array_equal(out["a"]["w"], tree["a"]["w"])
        # tmp dirs never survive
        assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_checkpoint_reshard_roundtrip():
    """Save, then restore under a different sharding (elastic restore)."""
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, tree)
        sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        out = mgr.restore(tree, shardings={"w": sh})
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
