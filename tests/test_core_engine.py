"""Functional Phantom core must bit-match dense oracles while its cycle
model rides the same schedule (paper §3 end-to-end)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core import engine


@given(
    st.integers(4, 10),
    st.integers(4, 12),
    st.floats(0.1, 0.9),
    st.floats(0.1, 0.9),
    st.integers(0, 2**31 - 1),
    st.sampled_from(["inorder", "outoforder"]),
    st.integers(1, 9),
)
@settings(max_examples=40, deadline=None)
def test_conv2d_matches_dense(h, w, dw, da, seed, policy, lf):
    rng = np.random.default_rng(seed)
    a = rng.integers(-3, 4, (h, w)).astype(float) * (rng.random((h, w)) < da)
    k = rng.integers(-3, 4, (3, 3)).astype(float) * (rng.random((3, 3)) < dw)
    res = engine.phantom_conv2d(a, k, lookahead=lf, policy=policy)
    oh, ow = h - 2, w - 2
    ref = np.zeros(oh * ow)
    for i in range(oh):
        for j in range(ow):
            ref[i * ow + j] = (a[i : i + 3, j : j + 3] * k).sum()
    np.testing.assert_allclose(res.outputs, ref)
    # §3.8 output encoding: mask ⊇ non-zero outputs (a one may still sum to 0)
    assert np.all(res.out_mask[ref != 0])
    assert res.stats.cycles >= 1


@given(
    st.integers(2, 4),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_non_unit_stride(s, seed):
    """Goal G3: strides SCNN cannot run."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((11, 11)) * (rng.random((11, 11)) < 0.5)
    k = rng.standard_normal((3, 3)) * (rng.random((3, 3)) < 0.5)
    res = engine.phantom_conv2d(a, k, stride=(s, s))
    oh = (11 - 3) // s + 1
    ref = np.array(
        [
            (a[i * s : i * s + 3, j * s : j * s + 3] * k).sum()
            for i in range(oh)
            for j in range(oh)
        ]
    )
    np.testing.assert_allclose(res.outputs, ref)


def test_fc_matches_dense(rng):
    act = (rng.random(45) < 0.4) * rng.standard_normal(45)
    w = (rng.random((45, 30)) < 0.3) * rng.standard_normal((45, 30))
    res = engine.phantom_fc(act, w, lookahead=6)
    np.testing.assert_allclose(res.outputs, act @ w, rtol=1e-9, atol=1e-9)
    assert res.stats.speedup_vs_dense > 1.0  # sparse must beat dense here


def test_intra_balance_never_wrong(rng):
    a = rng.standard_normal((8, 10)) * (rng.random((8, 10)) < 0.3)
    k = rng.standard_normal((3, 3)) * (rng.random((3, 3)) < 0.6)
    r_bal = engine.phantom_conv2d(a, k, intra_balance=True)
    r_un = engine.phantom_conv2d(a, k, intra_balance=False)
    np.testing.assert_allclose(r_bal.outputs, r_un.outputs)
