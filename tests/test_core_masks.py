"""Property tests: sparse-mask representation + traffic models (paper §3.1,
Fig. 25)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core import masks


@st.composite
def small_matrix(draw):
    r = draw(st.integers(1, 12))
    c = draw(st.integers(1, 12))
    vals = draw(
        st.lists(st.integers(-4, 4), min_size=r * c, max_size=r * c)
    )
    return np.array(vals, dtype=np.int64).reshape(r, c)


@given(small_matrix())
@settings(max_examples=100, deadline=None)
def test_pack_unpack_roundtrip(m):
    sm = masks.to_sparse_mask(m)
    assert np.array_equal(masks.from_sparse_mask(sm), m)
    assert sm.nnz == int((m != 0).sum())


@given(small_matrix())
@settings(max_examples=50, deadline=None)
def test_mask_traffic_cheaper_for_metadata(m):
    """The binary mask is one bit/elem; CSC metadata ≥ 1 byte per nnz."""
    sm = masks.to_sparse_mask(m)
    mb = masks.mask_traffic_bytes(m.shape)
    cb = masks.csc_traffic_bytes(sm.mask)
    assert mb == int(np.ceil(m.size / 8))
    if sm.nnz >= m.size // 8:  # beyond 1/8 density CSC must lose
        assert cb >= mb


def test_fig25_regime():
    """Low-sparsity activations: CSC ≈ 4× the mask traffic; high sparsity
    shrinks the gap (paper Fig. 25: → ~1.7×).  CSC columns are per-(W, C)
    stripes with H rows (1-byte row indices, as streamed by CSC PEs)."""
    rng = np.random.default_rng(0)
    m = rng.random((224, 224 * 64)) < 0.5
    ratio = masks.csc_traffic_bytes(m) / masks.mask_traffic_bytes(m.shape)
    assert 3.0 < ratio < 5.5  # paper: ~4×
    m2 = rng.random((14, 14 * 512)) < 0.2
    ratio2 = masks.csc_traffic_bytes(m2) / masks.mask_traffic_bytes(m2.shape)
    assert 1.2 < ratio2 < ratio  # gap narrows with sparsity
