"""Admission control and request validation (DESIGN.md §14).

Properties under test:

* the waiting queue never exceeds ``ServePolicy.max_queue`` — over-limit
  submits raise a structured :class:`RejectedError` (reason / queue_depth /
  max_queue attributes), never a silent drop;
* rejection allocates no rid — accepted requests keep a gap-free FIFO
  sequence, and completion order is submit order;
* draining the queue restores admission;
* invalid requests (``max_new_tokens < 1``, non-positive deadlines) are
  refused at submit with ``ValueError`` plus a
  ``rejected_invalid_request`` counter.

A deterministic seeded interleaving of submit/drain operations runs in
tier-1; the hypothesis stateful machine rides the slow tier (repo
convention — hypothesis is an optional dev extra).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import toy_cnn

import phantom
from repro.obs import Recorder
from repro.serve import (
    CnnServeEngine,
    FaultPlan,
    RejectedError,
    ServeEngine,
    ServePolicy,
)

VOCAB = 16


class _CountModel:
    def init_cache(self, batch, max_len):
        return {"k": jnp.zeros((1, batch, max_len, 2), jnp.float32)}

    def decode_step(self, params, cache, tokens, index):
        logits = jax.nn.one_hot((tokens + 1) % VOCAB, VOCAB)
        b = cache["k"].shape[1]
        k = cache["k"].at[0, jnp.arange(b), index, 0].set(
            1.0 + tokens[:, 0].astype(jnp.float32)
        )
        return logits, {"k": k}


def _engine(policy, *, batch_size=2, recorder=None):
    return ServeEngine(
        _CountModel(), {}, batch_size=batch_size, max_len=64,
        policy=policy, recorder=recorder,
    )


# -- bounded admission --------------------------------------------------------


def test_queue_bound_rejects_with_structured_error():
    rec = Recorder()
    eng = _engine(ServePolicy(max_queue=2), recorder=rec)
    a = eng.submit([1], max_new_tokens=2)
    b = eng.submit([2], max_new_tokens=2)
    with pytest.raises(RejectedError) as ei:
        eng.submit([3], max_new_tokens=2)
    err = ei.value
    assert err.reason == "queue_full"
    assert err.queue_depth == 2 and err.max_queue == 2
    assert "2/2" in str(err)
    assert rec.counters["serve/rejected_queue_full"] == 1.0
    # no silent drop anywhere: both accepted requests are fully served
    done = eng.run()
    assert done == [a, b] and all(r.done for r in done)
    # drained ⇒ admission restored, and the rejected submit burned no rid
    c = eng.submit([4], max_new_tokens=2)
    assert c.rid == b.rid + 1
    assert eng.run() == [c]


def test_fifo_completion_order_preserved():
    eng = _engine(ServePolicy(max_queue=8), batch_size=2)
    reqs = [eng.submit([i + 1], max_new_tokens=3) for i in range(6)]
    done = eng.run()
    assert [r.rid for r in done] == [r.rid for r in reqs]  # submit order
    assert [r.rid for r in reqs] == list(range(6))  # gap-free rid sequence


def test_deterministic_interleaving_never_exceeds_bound():
    """Seeded submit/drain interleaving: the waiting queue never exceeds the
    bound, every outcome is accept-or-RejectedError, and every accepted
    request eventually completes exactly once."""
    for seed in range(4):
        for max_queue in (1, 2, 5):
            op_rng = np.random.default_rng([0xAD71, seed, max_queue])
            eng = _engine(ServePolicy(max_queue=max_queue), batch_size=2)
            accepted, completed, rejected = [], [], 0
            for _ in range(60):
                if op_rng.random() < 0.7:
                    try:
                        accepted.append(eng.submit([1], max_new_tokens=2))
                    except RejectedError:
                        rejected += 1
                        assert len(eng.queue) == max_queue
                else:
                    completed += eng.run()
                assert len(eng.queue) <= max_queue  # the invariant
            completed += eng.run()
            assert rejected > 0  # the schedule actually hit the bound
            assert [r.rid for r in completed] == [r.rid for r in accepted]
            assert all(r.done for r in accepted)


def test_cnn_queue_bound_and_drain(rng):
    layers, params = toy_cnn(rng)
    prog = phantom.compile(
        layers, params,
        phantom.PhantomConfig(enabled=True, block=(16, 16, 16)), batch=2,
    )
    rec = Recorder()
    eng = CnnServeEngine(
        program=prog, batch_size=2, interpret=True, recorder=rec,
        policy=ServePolicy(max_queue=3),
    )
    imgs = rng.standard_normal((4, 8, 8, 3)).astype(np.float32)
    reqs = [eng.submit(im) for im in imgs[:3]]
    with pytest.raises(RejectedError) as ei:
        eng.submit(imgs[3])
    assert ei.value.queue_depth == 3 and ei.value.max_queue == 3
    assert rec.counters["serve_cnn/rejected_queue_full"] == 1.0
    done = eng.run()
    assert [r.rid for r in done] == [0, 1, 2] and all(r.done for r in reqs)
    late = eng.submit(imgs[3])  # drained ⇒ accepted again, rid continues
    assert late.rid == 3
    eng.run()
    assert late.done


# -- request validation (regression: non-positive limits were accepted) ------


@pytest.mark.parametrize("bad", [0, -3])
def test_submit_rejects_nonpositive_max_new_tokens(bad):
    rec = Recorder()
    eng = _engine(None, recorder=rec)
    with pytest.raises(ValueError, match="max_new_tokens must be >= 1"):
        eng.submit([1], max_new_tokens=bad)
    assert rec.counters["serve/rejected_invalid_request"] == 1.0
    assert not eng.queue  # nothing half-admitted


@pytest.mark.parametrize("bad", [0.0, -1.0])
def test_submit_rejects_nonpositive_deadline(bad):
    rec = Recorder()
    eng = _engine(ServePolicy(), recorder=rec)
    with pytest.raises(ValueError, match="deadline_s must be positive"):
        eng.submit([1], max_new_tokens=2, deadline_s=bad)
    assert rec.counters["serve/rejected_invalid_request"] == 1.0
    assert not eng.queue


def test_submit_deadline_requires_policy():
    eng = _engine(None)
    with pytest.raises(ValueError, match="requires failure semantics"):
        eng.submit([1], max_new_tokens=2, deadline_s=1.0)


@pytest.mark.parametrize("bad", [0.0, -2.0])
def test_cnn_submit_rejects_nonpositive_deadline(rng, bad):
    layers, params = toy_cnn(rng)
    prog = phantom.compile(
        layers, params,
        phantom.PhantomConfig(enabled=True, block=(16, 16, 16)), batch=2,
    )
    rec = Recorder()
    eng = CnnServeEngine(
        program=prog, batch_size=2, interpret=True, recorder=rec,
        policy=ServePolicy(),
    )
    img = rng.standard_normal((8, 8, 3)).astype(np.float32)
    with pytest.raises(ValueError, match="deadline_s must be positive"):
        eng.submit(img, deadline_s=bad)
    assert rec.counters["serve_cnn/rejected_invalid_request"] == 1.0
    with pytest.raises(ValueError, match="requires failure semantics"):
        CnnServeEngine(program=prog, batch_size=2, interpret=True).submit(
            img, deadline_s=1.0
        )


def test_policy_field_validation():
    with pytest.raises(ValueError, match="max_queue"):
        ServePolicy(max_queue=0)
    with pytest.raises(ValueError, match="deadline_s"):
        ServePolicy(deadline_s=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        ServePolicy(max_retries=-1)
    with pytest.raises(ValueError, match="backoff_s"):
        ServePolicy(backoff_s=-0.1)
    with pytest.raises(ValueError, match="backoff_factor"):
        ServePolicy(backoff_factor=0.5)
    with pytest.raises(ValueError, match="degrade_after"):
        ServePolicy(degrade_after=0)
    # valid edge values construct fine
    ServePolicy(max_queue=1, max_retries=0, backoff_s=0.0,
                backoff_factor=1.0, degrade_after=1,
                faults=FaultPlan(seed=1))


# -- hypothesis stateful machine (slow tier) ---------------------------------

try:
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import (
        RuleBasedStateMachine,
        initialize,
        invariant,
        rule,
    )

    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 containers without the dev extra
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    class AdmissionMachine(RuleBasedStateMachine):
        """Random submit/drain programs: the queue invariant, structured
        rejection, and exactly-once FIFO completion must hold at every
        step."""

        @initialize(max_queue=st.integers(1, 6), slots=st.integers(1, 3))
        def setup(self, max_queue, slots):
            self.max_queue = max_queue
            self.eng = _engine(
                ServePolicy(max_queue=max_queue), batch_size=slots
            )
            self.accepted = []
            self.completed = []

        @rule(tok=st.integers(1, VOCAB - 1))
        def submit(self, tok):
            try:
                self.accepted.append(self.eng.submit([tok], max_new_tokens=2))
            except RejectedError as e:
                assert e.reason == "queue_full"
                assert e.queue_depth == self.max_queue == e.max_queue

        @rule()
        def drain(self):
            self.completed += self.eng.run()

        @invariant()
        def queue_bounded(self):
            if hasattr(self, "eng"):
                assert len(self.eng.queue) <= self.max_queue

        def teardown(self):
            if hasattr(self, "eng"):
                self.completed += self.eng.run()
                assert [r.rid for r in self.completed] == [
                    r.rid for r in self.accepted
                ]
                assert all(r.done for r in self.accepted)

    @pytest.mark.slow
    class TestAdmissionMachine(AdmissionMachine.TestCase):
        settings = settings(max_examples=25, stateful_step_count=30,
                            deadline=None)
