"""Property tests: LAM + TDS invariants (paper §3.3–3.4).

 * LAM = elementwise AND;
 * every non-zero entry is selected exactly once, zero entries never;
 * ≤ threads entries and ≤ threads ones per selection (mapper capacity);
 * OO cycles ≤ IO cycles ≤ entry count; L_f=1 replicates dense (= E cycles);
 * cycles non-increasing in L_f;
 * the vectorised batch timer matches the exact selector on random queues.
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core import lam, tds


@given(
    st.integers(1, 60),  # queue length
    st.integers(1, 27),  # lookahead
    st.integers(1, 4),  # threads
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=150, deadline=None)
def test_select_column_invariants(n, lf, threads, seed):
    rng = np.random.default_rng(seed)
    pops = rng.integers(0, threads + 1, size=n)
    for policy in tds.POLICIES:
        sched = tds.select_column(pops, lookahead=lf, threads=threads, policy=policy)
        seen = [e for sel in sched.selections for e in sel]
        nonzero = [i for i in range(n) if pops[i] > 0]
        assert sorted(seen) == nonzero  # all valid work, exactly once
        for sel in sched.selections:
            assert len(sel) <= threads
            assert sum(pops[e] for e in sel) <= threads


@given(
    st.integers(1, 40),
    st.integers(1, 4),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=80, deadline=None)
def test_oo_no_slower_than_io_and_lf_monotone(n, threads, seed):
    rng = np.random.default_rng(seed)
    pops = rng.integers(0, threads + 1, size=n)
    prev_oo = None
    for lf in (1, 3, 9, 27):
        io = tds.select_column(pops, lookahead=lf, threads=threads, policy="inorder").cycles
        oo = tds.select_column(pops, lookahead=lf, threads=threads, policy="outoforder").cycles
        assert oo <= io <= n
        if lf == 1:
            assert io == oo == n  # dense replication (§5.2.1)
        if prev_oo is not None:
            assert oo <= prev_oo  # more lookahead never hurts
        prev_oo = oo


@given(
    st.integers(1, 50),
    st.integers(1, 27),
    st.integers(1, 4),
    st.sampled_from(tds.POLICIES),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=150, deadline=None)
def test_batch_matches_exact(n, lf, threads, policy, seed):
    rng = np.random.default_rng(seed)
    pops = rng.integers(0, threads + 1, size=n)
    exact = tds.select_column(pops, lookahead=lf, threads=threads, policy=policy).cycles
    vec = int(
        tds.batch_cycles(
            pops[None].astype(np.int32),
            np.array([n]),
            lookahead=lf,
            threads=threads,
            policy=policy,
        )[0]
    )
    assert vec == exact


def test_lam_is_and(rng=np.random.default_rng(0)):
    w = rng.random((3, 3)) < 0.5
    chunks = rng.random((6, 3, 3)) < 0.5
    out = lam.lam_and(w, chunks)
    assert np.array_equal(out, chunks & w[None])
    om = lam.output_mask(out)
    assert np.array_equal(om, out.reshape(6, -1).any(1))
