"""Deterministic fault injection through the serve policy (DESIGN.md §14).

The load-bearing contract is *differential*: under any seeded
:class:`FaultPlan` with only transient/corrupt faults, every request that
completes has outputs **bit-identical** to a fault-free run of the same
prompts — retries replay the identical functional decode step, degradation
swaps in a program whose outputs are bit-identical by the §9/§10 parity
contracts, and nothing else may touch the data path.

All failure timing runs on injected fake clocks (latency spikes and backoff
advance a skew term, never ``time.sleep``), so every test here asserts
exact, replayable values — including the retry/degradation counters, which
are pinned against an oracle walk of the same schedule.

A deterministic grid over fault rates × slot counts × request counts runs
in tier-1; the hypothesis sweep follows the repo convention (``slow``
marker, skipped without hypothesis).
"""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import toy_cnn

import phantom
from repro.obs import Recorder
from repro.serve import (
    CnnServeEngine,
    FaultExhaustedError,
    FaultInjector,
    FaultPlan,
    ServeEngine,
    ServePolicy,
)
from repro.serve.faults import check_activations, corrupt_array

VOCAB = 16


class _CountModel:
    """Deterministic decode: next token = prev + 1 mod VOCAB (the
    test_serve_fixes toy) — engine mechanics without a real transformer."""

    def init_cache(self, batch, max_len):
        return {"k": jnp.zeros((1, batch, max_len, 2), jnp.float32)}

    def decode_step(self, params, cache, tokens, index):
        logits = jax.nn.one_hot((tokens + 1) % VOCAB, VOCAB)
        b = cache["k"].shape[1]
        k = cache["k"].at[0, jnp.arange(b), index, 0].set(
            1.0 + tokens[:, 0].astype(jnp.float32)
        )
        return logits, {"k": k}


class _Tick:
    """Deterministic engine clock: every read advances by 1 second."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def _run(prompts, *, policy=None, batch_size=3, max_new=4, recorder=None):
    eng = ServeEngine(
        _CountModel(), {}, batch_size=batch_size, max_len=64,
        policy=policy, recorder=recorder,
    )
    reqs = [eng.submit(list(p), max_new_tokens=max_new) for p in prompts]
    eng.run()
    return eng, reqs


def _outputs(reqs):
    return [(r.rid, tuple(r.output), r.done) for r in reqs]


PROMPTS = ([1], [2, 3], [4, 5, 6], [7], [8, 9])


# -- FaultPlan determinism ----------------------------------------------------


def test_fault_plan_schedule_deterministic_and_pure():
    plan = FaultPlan(seed=7, transient_rate=0.4, corrupt_rate=0.2,
                     latency_rate=0.3, latency_s=0.01)
    same = FaultPlan(seed=7, transient_rate=0.4, corrupt_rate=0.2,
                     latency_rate=0.3, latency_s=0.01)
    assert plan.schedule(64) == same.schedule(64)
    assert plan.schedule_bytes(64) == same.schedule_bytes(64)
    # pure in the attempt index: random access equals sequential walk
    assert plan.at(17) == plan.schedule(18)[17]
    other = FaultPlan(seed=8, transient_rate=0.4, corrupt_rate=0.2,
                      latency_rate=0.3, latency_s=0.01)
    assert plan.schedule_bytes(64) != other.schedule_bytes(64)


def test_fault_plan_validation_and_parse():
    with pytest.raises(ValueError, match="transient_rate"):
        FaultPlan(transient_rate=1.5)
    with pytest.raises(ValueError, match="latency_s"):
        FaultPlan(latency_s=-1.0)
    with pytest.raises(ValueError, match="max_faults"):
        FaultPlan(max_faults=-1)
    assert FaultPlan.parse("none") is None
    assert FaultPlan.parse("off") is None
    smoke = FaultPlan.parse("smoke", seed=3)
    assert smoke == FaultPlan.smoke(3) and smoke.transient_rate > 0
    spec = FaultPlan.parse("transient_rate=0.2,max_faults=5", seed=1)
    assert spec == FaultPlan(seed=1, transient_rate=0.2, max_faults=5)
    with pytest.raises(ValueError, match="unknown --faults key"):
        FaultPlan.parse("bogus=1")
    with pytest.raises(ValueError, match="key=value"):
        FaultPlan.parse("justaword")


def test_injector_budget_and_disarm():
    plan = FaultPlan(seed=0, transient_rate=1.0, max_faults=2)
    inj = FaultInjector(plan)
    drawn = [inj.next() for _ in range(5)]
    assert [f.transient for f in drawn] == [True, True, False, False, False]
    assert inj.injected == 2
    inj2 = FaultInjector(FaultPlan(seed=0, transient_rate=1.0, latency_rate=1.0))
    inj2.disarm()
    f = inj2.next()
    assert not f.erroneous and f.latency_s > 0  # latency survives disarm


def test_corruption_and_runtime_verifier_hook():
    x = jnp.ones((2, 3), jnp.float32)
    bad = corrupt_array(x)
    assert bad.shape == x.shape and bool(jnp.isnan(bad).all())
    assert check_activations(x) == []
    (finding,) = check_activations(bad, layer="fc")
    assert finding.rule == "runtime/activation-finite"
    assert finding.layer == "fc" and "6/6" in finding.detail


# -- differential: transient-only faults, bit-identical completed outputs -----


def _expected_error_faults(plan, successes):
    """Oracle walk of the schedule: injected erroneous faults before the
    engine reaches ``successes`` clean decode steps (one draw per attempt,
    unlimited retry budget)."""
    bad = i = done = 0
    while done < successes:
        f = plan.at(i)
        i += 1
        if f.erroneous:
            bad += 1
        else:
            done += 1
    return bad


def test_transient_outputs_bit_identical_with_exact_counters():
    _, clean = _run(PROMPTS)
    rec_free = Recorder(clock=_Tick())
    _run(PROMPTS, recorder=rec_free)
    steps = int(rec_free.counters["serve/decode_steps"])

    plan = FaultPlan(seed=11, transient_rate=0.5)
    rec = Recorder(clock=_Tick())
    eng, reqs = _run(
        PROMPTS,
        policy=ServePolicy(faults=plan, max_retries=64, degrade_after=None),
        recorder=rec,
    )
    assert _outputs(reqs) == _outputs(clean)  # bit-identical, all done
    assert all(r.done for r in reqs)
    want = _expected_error_faults(plan, steps)
    assert want > 0  # the schedule actually fired at this seed
    assert rec.counters["serve/faults_injected{kind=transient}"] == want
    assert rec.counters["serve/retries"] == want
    assert rec.counters["serve/step_failures{kind=transient}"] == want
    assert rec.counters["serve/decode_steps"] == steps  # same executed work
    assert "serve/degradations" not in rec.counters
    assert not eng.degraded


def test_corrupt_faults_detected_retried_and_identical():
    _, clean = _run(PROMPTS)
    plan = FaultPlan(seed=5, corrupt_rate=1.0, max_faults=3)
    rec = Recorder(clock=_Tick())
    _, reqs = _run(
        PROMPTS,
        policy=ServePolicy(faults=plan, max_retries=8, degrade_after=None),
        recorder=rec,
    )
    assert _outputs(reqs) == _outputs(clean)
    assert rec.counters["serve/faults_injected{kind=corrupt}"] == 3
    assert rec.counters["serve/step_failures{kind=corrupt}"] == 3
    assert rec.counters["serve/retries"] == 3


def test_deterministic_grid_rates_x_slots_x_requests():
    """Tier-1 differential grid: transient-only plans across fault rates ×
    slot counts × request counts — completed outputs always bit-identical
    to the fault-free run of the same prompts."""
    for rate in (0.0, 0.3, 0.6):
        for slots in (1, 2, 4):
            for nreq in (1, 3, 5):
                prompts = PROMPTS[:nreq]
                _, clean = _run(prompts, batch_size=slots)
                plan = FaultPlan(seed=nreq * 10 + slots, transient_rate=rate)
                _, reqs = _run(
                    prompts,
                    batch_size=slots,
                    policy=ServePolicy(faults=plan, max_retries=64,
                                       degrade_after=2),
                )
                assert all(r.done for r in reqs), (rate, slots, nreq)
                assert _outputs(reqs) == _outputs(clean), (rate, slots, nreq)


# -- degradation / exhaustion -------------------------------------------------


def test_degradation_disarms_faults_and_preserves_outputs():
    _, clean = _run(PROMPTS)
    plan = FaultPlan(seed=0, transient_rate=1.0)  # every attempt fails
    rec = Recorder(clock=_Tick())
    eng, reqs = _run(
        PROMPTS,
        policy=ServePolicy(faults=plan, max_retries=3, degrade_after=2),
        recorder=rec,
    )
    assert eng.degraded
    assert rec.counters["serve/degradations"] == 1.0
    # exactly degrade_after failures before the swap, none after disarm
    assert rec.counters["serve/step_failures{kind=transient}"] == 2.0
    assert rec.counters["serve/retries"] == 1.0  # failure 1 retried, 2 degraded
    assert _outputs(reqs) == _outputs(clean)


def test_exhaustion_raises_and_engine_recovers():
    plan = FaultPlan(seed=0, transient_rate=1.0, max_faults=3)
    rec = Recorder(clock=_Tick())
    eng = ServeEngine(
        _CountModel(), {}, batch_size=2, max_len=64,
        policy=ServePolicy(faults=plan, max_retries=2, degrade_after=None),
        recorder=rec,
    )
    req = eng.submit([3], max_new_tokens=2)
    with pytest.raises(FaultExhaustedError, match="failed 3 time"):
        eng.run()
    assert not req.done and req.output == []  # state untouched by failures
    assert rec.counters["serve/retries"] == 2.0
    # the budget is spent: a second run completes and outputs are right
    done = eng.run()
    assert done == [req] and req.output == [4, 5]


def test_backoff_and_latency_advance_the_skew_clock():
    plan = FaultPlan(seed=0, transient_rate=1.0, max_faults=2,
                     latency_rate=1.0, latency_s=0.25)
    pol = ServePolicy(faults=plan, max_retries=4, degrade_after=None,
                      backoff_s=1.0, backoff_factor=2.0)
    rec = Recorder(clock=_Tick())
    eng, (req,) = _run([[3]], policy=pol, max_new=2, recorder=rec)
    assert req.done
    # 2 failures → backoff 1.0 + 2.0; every attempt (2 failed + 2 clean
    # decode steps) drew a latency spike of 0.25
    assert eng._rt.skew == pytest.approx(1.0 + 2.0 + 4 * 0.25)
    assert rec.counters["serve/faults_injected{kind=latency}"] == 4.0
    assert rec.hists["serve/retry_backoff_s"] == [1.0, 2.0]
    # latency percentiles include the skew: the lone request's latency is
    # strictly larger than the fault-free fake-clock latency
    rec_free = Recorder(clock=_Tick())
    _run([[3]], max_new=2, recorder=rec_free)
    (lat,) = rec.hists["serve/request_latency_s"]
    (lat_free,) = rec_free.hists["serve/request_latency_s"]
    assert lat == pytest.approx(lat_free + eng._rt.skew)


# -- deadlines ----------------------------------------------------------------


def test_deadline_expiry_fails_request_with_structured_reason():
    rec = Recorder(clock=_Tick())
    eng = ServeEngine(
        _CountModel(), {}, batch_size=1, max_len=64,
        policy=ServePolicy(), recorder=rec,
    )
    doomed = eng.submit([1], max_new_tokens=4, deadline_s=0.5)
    fine = eng.submit([2], max_new_tokens=2, deadline_s=1000.0)
    done = eng.run()
    assert doomed in done and fine in done
    assert not doomed.done and doomed.error == "deadline exceeded"
    assert doomed.output == []
    assert fine.done and fine.error is None and fine.output == [3, 4]
    assert rec.counters["serve/deadline_missed"] == 1.0
    assert rec.counters["serve/completed"] == 1.0
    # overrun histogram: one positive miss, one 0.0 met entry
    ovr = sorted(rec.hists["serve/deadline_overrun_s"])
    assert ovr[0] == 0.0 and ovr[1] > 0.0
    assert rec.gauges["serve/deadline_overrun_p99"] == ovr[1]


def test_met_deadlines_record_zero_overrun_gauge():
    rec = Recorder(clock=_Tick())
    eng = ServeEngine(
        _CountModel(), {}, batch_size=2, max_len=64,
        policy=ServePolicy(deadline_s=10_000.0), recorder=rec,
    )
    for p in ([1], [2]):
        eng.submit(p, max_new_tokens=2)
    eng.run()
    assert rec.counters["serve/completed"] == 2.0
    assert "serve/deadline_missed" not in rec.counters
    assert rec.hists["serve/deadline_overrun_s"] == [0.0, 0.0]
    assert rec.gauges["serve/deadline_overrun_p99"] == 0.0


# -- policy=None parity (acceptance criterion) --------------------------------


def test_noop_policy_bit_identical_to_no_policy():
    """policy=None and a defaults-only ServePolicy() must match bit-for-bit:
    same outputs AND byte-identical recorder snapshots under identical fake
    clocks (same clock-read count, same metric keys, same values)."""
    rec_a = Recorder(clock=_Tick())
    _, reqs_a = _run(PROMPTS, recorder=rec_a)  # policy=None
    rec_b = Recorder(clock=_Tick())
    _, reqs_b = _run(PROMPTS, policy=ServePolicy(), recorder=rec_b)
    assert _outputs(reqs_a) == _outputs(reqs_b)
    assert rec_a.to_json() == rec_b.to_json()


def test_same_seed_byte_identical_metric_snapshots():
    """Determinism audit: two fresh engines, same FaultPlan seed, same fake
    clocks — the full obs snapshot (counters/gauges/histograms) is
    byte-identical; a different seed genuinely changes the schedule."""
    def chaos_run(seed):
        rec = Recorder(clock=_Tick())
        _run(
            PROMPTS,
            policy=ServePolicy(
                faults=FaultPlan(seed=seed, transient_rate=0.5,
                                 latency_rate=0.5, latency_s=0.125),
                max_retries=64, degrade_after=None,
            ),
            recorder=rec,
        )
        return rec.to_json()

    assert chaos_run(11) == chaos_run(11)
    assert chaos_run(11) != chaos_run(12)


# -- CNN engine under faults --------------------------------------------------


def _cnn_setup(rng, *, cores=1, lookahead=0, batch=2):
    layers, params = toy_cnn(rng)
    prog = phantom.compile(
        layers, params,
        phantom.PhantomConfig(enabled=True, block=(16, 16, 16),
                              cores=cores, lookahead=lookahead),
        batch=batch,
    )
    return layers, params, prog


def test_cnn_transient_faults_identical_logits(rng):
    _, _, prog = _cnn_setup(rng)
    imgs = rng.standard_normal((3, 8, 8, 3)).astype(np.float32)
    clean = CnnServeEngine(program=prog, batch_size=2, interpret=True)
    creqs = [clean.submit(im) for im in imgs]
    clean.run()
    ref = np.stack([r.logits for r in creqs])

    rec = Recorder(clock=_Tick())
    plan = FaultPlan(seed=2, transient_rate=0.6, corrupt_rate=0.3)
    eng = CnnServeEngine(
        program=prog, batch_size=2, interpret=True, recorder=rec,
        policy=ServePolicy(faults=plan, max_retries=32, degrade_after=None),
    )
    reqs = [eng.submit(im) for im in imgs]
    eng.run()
    assert all(r.done for r in reqs)
    got = np.stack([r.logits for r in reqs])
    np.testing.assert_array_equal(got, ref)  # bit-identical, not allclose
    injected = sum(
        v for k, v in rec.counters.items()
        if k.startswith("serve_cnn/faults_injected")
    )
    assert injected > 0 and rec.counters["serve_cnn/retries"] > 0


def test_cnn_degradation_swaps_in_fallback_program(rng):
    _, _, prog = _cnn_setup(rng, cores=2, lookahead=2)
    imgs = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
    clean = CnnServeEngine(program=prog, batch_size=2, interpret=True)
    creqs = [clean.submit(im) for im in imgs]
    clean.run()

    rec = Recorder(clock=_Tick())
    eng = CnnServeEngine(
        program=prog, batch_size=2, interpret=True, recorder=rec,
        policy=ServePolicy(faults=FaultPlan(seed=0, transient_rate=1.0),
                           max_retries=4, degrade_after=1),
    )
    reqs = [eng.submit(im) for im in imgs]
    eng.run()
    assert eng.degraded and rec.counters["serve_cnn/degradations"] == 1.0
    assert eng._active is not eng.program  # fallback program is live
    assert eng._active.cfg.cores == 1 and eng._active.cfg.lookahead == 0
    assert eng.program.cfg.cores == 2  # original untouched
    got = np.stack([r.logits for r in reqs])
    ref = np.stack([r.logits for r in creqs])
    np.testing.assert_array_equal(got, ref)  # §9/§10 parity ⇒ bit-identical


def test_cnn_noop_policy_parity(rng):
    _, _, prog = _cnn_setup(rng)
    imgs = rng.standard_normal((3, 8, 8, 3)).astype(np.float32)

    def run_with(policy):
        rec = Recorder(clock=_Tick())
        eng = CnnServeEngine(program=prog, batch_size=2, interpret=True,
                             recorder=rec, policy=policy)
        reqs = [eng.submit(im) for im in imgs]
        eng.run()
        prog.recorder = None  # detach: the shared program must not leak
        return np.stack([r.logits for r in reqs]), rec.to_json()

    got_a, snap_a = run_with(None)
    got_b, snap_b = run_with(ServePolicy())
    np.testing.assert_array_equal(got_a, got_b)
    assert snap_a == snap_b


# -- PH002 lint covers the fault harness --------------------------------------


def _lint():
    root = pathlib.Path(__file__).resolve().parents[1]
    spec = importlib.util.spec_from_file_location(
        "lint_phantom", root / "tools" / "lint_phantom.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_lint_ph002_covers_serve_faults(tmp_path):
    mod = _lint()
    bad = tmp_path / "repro" / "serve" / "faults.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(
        "import numpy as np\n"
        "def draw():\n"
        "    return np.random.default_rng().random()\n"
    )
    out = mod.lint_file(bad, tmp_path)
    assert len(out) == 1 and "[PH002]" in out[0] and "unseeded" in out[0]
    # …and the real harness is clean under the same rule
    root = pathlib.Path(__file__).resolve().parents[1]
    real = root / "src" / "repro" / "serve" / "faults.py"
    assert mod.lint_file(real, root) == []


# -- hypothesis sweep (slow tier; the deterministic grid above always runs) --

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 containers without the dev extra
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @given(
        rate=st.floats(0.0, 0.8),
        corrupt=st.floats(0.0, 0.4),
        slots=st.integers(1, 4),
        nreq=st.integers(1, 5),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_transient_differential_property(rate, corrupt, slots, nreq, seed):
        """For ANY seeded all-transient FaultPlan: every accepted request
        completes (degradation guarantees progress) with outputs
        bit-identical to the fault-free run."""
        prompts = PROMPTS[:nreq]
        _, clean = _run(prompts, batch_size=slots)
        plan = FaultPlan(seed=seed, transient_rate=rate, corrupt_rate=corrupt)
        _, reqs = _run(
            prompts,
            batch_size=slots,
            policy=ServePolicy(faults=plan, max_retries=16, degrade_after=4),
        )
        assert all(r.done for r in reqs)
        assert _outputs(reqs) == _outputs(clean)
