"""Shared fixtures + suite tiering.

Tier-1 (`PYTHONPATH=src python -m pytest -x -q`) must stay fast (<120 s on
CPU): tests marked ``slow`` — full property sweeps and whole-network phantom
runs — are skipped unless an explicit ``-m`` expression is given
(``-m slow`` runs only them, ``-m "slow or not slow"`` runs everything).

NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device; only
the dry-run forces 512 placeholder devices (and it does so in its own
process, repro/launch/dryrun.py lines 1–3).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataflow import ConvSpec, FCSpec


def toy_cnn(rng):
    """Four-layer toy CNN (conv → depthwise s2 → pointwise → GAP-FC) with
    0.4-density pruned weights — shared by the conv-parity and serve tests."""
    layers = [
        ConvSpec("c1", 3, 16, 8, 8, 3, 3, (1, 1)),
        ConvSpec("c2-dw", 16, 16, 8, 8, 3, 3, (2, 2), depthwise=True),
        ConvSpec("c2-pw", 16, 32, 4, 4, 1, 1, (1, 1)),
        FCSpec("fc", 32, 10, pool="gap"),
    ]
    params = {}
    for l in layers:
        if isinstance(l, ConvSpec):
            wshape = (l.kh, l.kw, 1 if l.depthwise else l.in_ch, l.out_ch)
            bshape = (l.out_ch,)
        else:
            wshape, bshape = (l.in_dim, l.out_dim), (l.out_dim,)
        w = rng.standard_normal(wshape).astype(np.float32) * 0.1
        w *= rng.random(wshape) < 0.4
        params[l.name] = {
            "w": jnp.asarray(w),
            "b": jnp.asarray(rng.standard_normal(bshape).astype(np.float32) * 0.1),
        }
    return layers, params


def pytest_collection_modifyitems(config, items):
    if config.getoption("-m"):
        return  # explicit marker expression takes over tier selection
    skip_slow = pytest.mark.skip(reason="slow tier: run with -m slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
