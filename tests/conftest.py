"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only the dry-run forces 512 placeholder devices (and it does
so in its own process, repro/launch/dryrun.py lines 1–3)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
