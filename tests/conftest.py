"""Shared fixtures + suite tiering.

Tier-1 (`PYTHONPATH=src python -m pytest -x -q`) must stay fast (<120 s on
CPU): tests marked ``slow`` — full property sweeps and whole-network phantom
runs — are skipped unless an explicit ``-m`` expression is given
(``-m slow`` runs only them, ``-m "slow or not slow"`` runs everything).

NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device; only
the dry-run forces 512 placeholder devices (and it does so in its own
process, repro/launch/dryrun.py lines 1–3).
"""
import numpy as np
import pytest


def pytest_collection_modifyitems(config, items):
    if config.getoption("-m"):
        return  # explicit marker expression takes over tier selection
    skip_slow = pytest.mark.skip(reason="slow tier: run with -m slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
