"""Reference parity for the im2col block-sparse conv path (interpret mode).

Oracle is ``jax.lax.conv_general_dilated`` on the same (pruned) weight —
kept tiles compute exactly, τ=0 activation gating only skips exact-zero
tiles, so the dense op is the ground truth (``ref.ref_phantom_conv``).
"""
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataflow import ConvSpec, FCSpec
from repro.kernels import phantom_conv as pc
from repro.kernels.ref import ref_phantom_conv
from repro.models import cnn

BLK = (16, 16, 16)


def _sparse(rng, shape, density):
    a = rng.standard_normal(shape).astype(np.float32)
    if density < 1.0:
        a *= rng.random(shape) < density
    return a


def _conv_case(rng, *, b=1, h=7, w=7, cin=8, cout=16, kh=3, kw=3,
               stride=(1, 1), padding="SAME", groups=1, w_density=1.0,
               a_density=1.0, blk=BLK):
    wt = _sparse(rng, (kh, kw, cin // groups, cout), w_density)
    x = _sparse(rng, (b, h, w, cin), a_density)
    pcw = pc.prepare_conv_weight(
        wt, batch=b, in_hw=(h, w), stride=stride, padding=padding,
        groups=groups, block=blk,
    )
    return jnp.asarray(x), jnp.asarray(wt), pcw


def _assert_parity(x, wt, pcw, tol=1e-4):
    y = pc.phantom_conv_call(x, pcw, interpret=True)
    yref = ref_phantom_conv(x, wt, pcw.stride, pcw.padding, pcw.groups)
    assert y.shape == yref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=tol, rtol=1e-3)


# One case per point of the issue's sweep axes: stride x padding x kernel,
# plus the weight/activation sparsity grid on the 3x3 s1 SAME base case.
GEOMS = [
    (kh, stride, padding)
    for kh in (1, 3)
    for stride in ((1, 1), (2, 2))
    for padding in ("SAME", "VALID")
]


@pytest.mark.parametrize("kh,stride,padding", GEOMS, ids=str)
def test_conv_geometry_parity(kh, stride, padding):
    rng = np.random.default_rng(zlib.crc32(repr((kh, stride, padding)).encode()))
    x, wt, pcw = _conv_case(
        rng, kh=kh, kw=kh, stride=stride, padding=padding,
        w_density=0.5, a_density=0.5,
    )
    _assert_parity(x, wt, pcw)


@pytest.mark.parametrize("w_density", [1.0, 0.5, 0.1], ids=lambda d: f"wd{d}")
@pytest.mark.parametrize("a_density", [1.0, 0.5, 0.1], ids=lambda d: f"ad{d}")
def test_conv_sparsity_parity(w_density, a_density):
    rng = np.random.default_rng(7)
    x, wt, pcw = _conv_case(rng, w_density=w_density, a_density=a_density)
    _assert_parity(x, wt, pcw)


def test_conv_depthwise_and_grouped():
    rng = np.random.default_rng(3)
    for groups, cin, cout, stride in ((32, 32, 32, (2, 2)), (4, 8, 16, (1, 1))):
        x, wt, pcw = _conv_case(
            rng, cin=cin, cout=cout, groups=groups, stride=stride, w_density=0.6,
        )
        _assert_parity(x, wt, pcw)
        if groups == cin:  # depthwise block-diagonal weight compacts away
            assert pcw.density() < 1.0


def test_vgg16_conv_layer_at_70pct_weight_sparsity():
    """Acceptance: VGG16-style 3x3 stride-1 conv (conv4: 128→128) ≤1e-4."""
    rng = np.random.default_rng(11)
    x, wt, pcw = _conv_case(
        rng, h=8, w=8, cin=128, cout=128, stride=(1, 1), w_density=0.3,
        a_density=0.4, blk=(32, 32, 32),
    )
    _assert_parity(x, wt, pcw, tol=1e-4)


def test_mobilenet_stride2_conv_at_70pct_weight_sparsity():
    """Acceptance: MobileNet-style stride-2 convs (conv1 3→32 and a
    depthwise s2 layer) ≤1e-4."""
    rng = np.random.default_rng(13)
    x, wt, pcw = _conv_case(
        rng, h=16, w=16, cin=3, cout=32, stride=(2, 2), w_density=0.3,
        a_density=0.99, blk=(32, 32, 32),
    )
    _assert_parity(x, wt, pcw, tol=1e-4)
    x, wt, pcw = _conv_case(
        rng, h=8, w=8, cin=64, cout=64, groups=64, stride=(2, 2),
        w_density=0.3, a_density=0.4, blk=(32, 32, 32),
    )
    _assert_parity(x, wt, pcw, tol=1e-4)


def test_conv_act_call_fused_relu_and_output_mask():
    """Fused ``relu(conv(x))`` + §3.8 output tile mask vs the unfused path."""
    from repro.kernels.ref import ref_activation_block_mask

    rng = np.random.default_rng(23)
    x, wt, pcw = _conv_case(rng, w_density=0.5, a_density=0.5)
    y, ymask = pc.phantom_conv_act_call(x, pcw, activation="relu", interpret=True)
    yref = jnp.maximum(ref_phantom_conv(x, wt, pcw.stride, pcw.padding), 0.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-4, rtol=1e-3)
    bm, _, bn = pcw.pw.block
    y2 = np.zeros((ymask.shape[0] * bm, ymask.shape[1] * bn), np.float32)
    flat = np.asarray(yref).reshape(-1, pcw.out_ch)
    y2[: flat.shape[0], : flat.shape[1]] = flat
    mref = np.asarray(ref_activation_block_mask(jnp.asarray(y2), (bm, bn)))
    assert (np.asarray(ymask).astype(bool) == mref).all()


def test_conv_mask_flow_matches_value_derived_bits():
    """§3.8 flow: bits from the producer's element mask == bits from values,
    and the gated output is identical."""
    rng = np.random.default_rng(5)
    x, wt, pcw = _conv_case(rng, w_density=0.5, a_density=0.3)
    y_values = pc.phantom_conv_call(x, pcw, interpret=True)
    y_mask = pc.phantom_conv_call(x, pcw, x_mask=(x != 0), interpret=True)
    np.testing.assert_array_equal(np.asarray(y_values), np.asarray(y_mask))


def _toy_params(rng, spec):
    params = {}
    for n, d in spec.items():
        params[n] = {
            k: jnp.asarray(_sparse(rng, p.shape, 0.4 if k == "w" else 1.0) * 0.1)
            for k, p in d.items()
        }
    return params


def test_cnn_phantom_forward_toy_net():
    """Tier-1 end-to-end: conv → depthwise s2 → pointwise → FC through the
    phantom path matches the dense forward, masks flowing between layers."""
    rng = np.random.default_rng(17)
    layers = [
        ConvSpec("c1", 3, 16, 8, 8, 3, 3, (1, 1)),
        ConvSpec("c2-dw", 16, 16, 8, 8, 3, 3, (2, 2), depthwise=True),
        ConvSpec("c2-pw", 16, 32, 4, 4, 1, 1, (1, 1)),
        FCSpec("fc", 32, 10, pool="gap"),
    ]
    params = {}
    for l in layers:
        if isinstance(l, ConvSpec):
            wshape = (l.kh, l.kw, 1 if l.depthwise else l.in_ch, l.out_ch)
            bshape = (l.out_ch,)
        else:
            wshape, bshape = (l.in_dim, l.out_dim), (l.out_dim,)
        params[l.name] = {
            "w": jnp.asarray(_sparse(rng, wshape, 0.4) * 0.1),
            "b": jnp.asarray(_sparse(rng, bshape, 1.0) * 0.1),
        }
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    y_dense = cnn.cnn_forward(params, x, layers)
    prepared = cnn.prepare_cnn_phantom(params, layers, batch=2, block=BLK)
    y_ph = cnn.cnn_forward_phantom(params, prepared, x, layers, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y_ph), np.asarray(y_dense), atol=1e-4, rtol=1e-3
    )


@pytest.mark.slow
@pytest.mark.parametrize("name,hw", [("vgg16", 16), ("mobilenet", 32)])
def test_cnn_phantom_forward_full_network(name, hw):
    """Whole-network parity (all 16 VGG16 / 28 MobileNet layers) at reduced
    resolution — every conv and FC goes through the Phantom core."""
    rng = np.random.default_rng(0)
    spec, layers = cnn.cnn_spec(name, input_hw=hw)
    params = _toy_params(rng, spec)
    x = jnp.asarray(rng.standard_normal((1, hw, hw, 3)).astype(np.float32))
    y_dense = cnn.cnn_forward(params, x, layers)
    prepared = cnn.prepare_cnn_phantom(params, layers, batch=1, block=(32, 32, 32))
    y_ph = cnn.cnn_forward_phantom(params, prepared, x, layers, interpret=True)
    scale = max(1.0, float(jnp.abs(y_dense).max()))
    np.testing.assert_allclose(
        np.asarray(y_ph) / scale, np.asarray(y_dense) / scale, atol=2e-6
    )
