"""Reference parity for both conv lowerings (interpret mode).

Oracle is ``jax.lax.conv_general_dilated`` on the same (pruned) weight —
kept tiles compute exactly, τ=0 activation gating only skips exact-zero
tiles, so the dense op is the ground truth (``ref.ref_phantom_conv``).
Every parity case runs the grid twice: ``mode="direct"`` (implicit im2col,
patch gather in-kernel) and ``mode="im2col"`` (explicit patch matrix), and
asserts direct == im2col == lax.conv.
"""
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import phantom_conv as pc
from repro.kernels.ref import ref_phantom_conv
from repro.models import cnn

BLK = (16, 16, 16)
MODES = ("direct", "im2col")


def _sparse(rng, shape, density):
    a = rng.standard_normal(shape).astype(np.float32)
    if density < 1.0:
        a *= rng.random(shape) < density
    return a


def _conv_data(rng, *, b=1, h=7, w=7, cin=8, cout=16, kh=3, kw=3,
               groups=1, w_density=1.0, a_density=1.0):
    wt = _sparse(rng, (kh, kw, cin // groups, cout), w_density)
    x = _sparse(rng, (b, h, w, cin), a_density)
    return jnp.asarray(x), jnp.asarray(wt)


def _conv_case(rng, *, b=1, h=7, w=7, cin=8, cout=16, kh=3, kw=3,
               stride=(1, 1), padding="SAME", groups=1, w_density=1.0,
               a_density=1.0, blk=BLK, mode="direct"):
    x, wt = _conv_data(rng, b=b, h=h, w=w, cin=cin, cout=cout, kh=kh, kw=kw,
                       groups=groups, w_density=w_density, a_density=a_density)
    pcw = pc.prepare_conv_weight(
        np.asarray(wt), batch=b, in_hw=(h, w), stride=stride, padding=padding,
        groups=groups, block=blk, mode=mode,
    )
    return x, wt, pcw


def _assert_parity(x, wt, pcw, tol=1e-4):
    y = pc.phantom_conv_call(x, pcw, interpret=True)
    yref = ref_phantom_conv(x, wt, pcw.stride, pcw.padding, pcw.groups)
    assert y.shape == yref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=tol, rtol=1e-3)
    return y


def _assert_tri_parity(rng, tol=1e-4, b=1, h=7, w=7, stride=(1, 1),
                       padding="SAME", blk=BLK, **data_kw):
    """direct == im2col == lax.conv on one sampled case (same data)."""
    x, wt = _conv_data(rng, b=b, h=h, w=w, **data_kw)
    ys = {}
    for mode in MODES:
        pcw = pc.prepare_conv_weight(
            np.asarray(wt), batch=b, in_hw=(h, w), stride=stride,
            padding=padding, groups=data_kw.get("groups", 1), block=blk,
            mode=mode,
        )
        ys[mode] = _assert_parity(x, wt, pcw, tol)
    np.testing.assert_allclose(
        np.asarray(ys["direct"]), np.asarray(ys["im2col"]), atol=tol, rtol=1e-3
    )


# The issue's parity grid: stride x padding x kernel x groups at odd H/W,
# plus the weight/activation density product on the 3x3 s1 SAME base case.
GEOMS = [
    (kh, stride, padding, grouped)
    for kh in (1, 3, 5)
    for stride in ((1, 1), (2, 2))
    for padding in ("SAME", "VALID")
    for grouped in (False, True)
]


@pytest.mark.parametrize("kh,stride,padding,grouped", GEOMS, ids=str)
def test_conv_geometry_parity(kh, stride, padding, grouped):
    seed = zlib.crc32(repr((kh, stride, padding, grouped)).encode())
    rng = np.random.default_rng(seed)
    cin = 8
    _assert_tri_parity(
        rng, h=9, w=9, cin=cin, cout=16, kh=kh, kw=kh, stride=stride,
        padding=padding, groups=cin if grouped else 1,
        w_density=0.5, a_density=0.5, blk=(8, 8, 8),
    )


DENSITIES = [0.0, 0.1, 0.5, 1.0]


@pytest.mark.parametrize("w_density", DENSITIES, ids=lambda d: f"wd{d}")
@pytest.mark.parametrize("a_density", DENSITIES, ids=lambda d: f"ad{d}")
def test_conv_sparsity_parity(w_density, a_density):
    rng = np.random.default_rng(7)
    _assert_tri_parity(rng, w_density=w_density, a_density=a_density)


def test_direct_equals_im2col_bit_exactly():
    """Small-integer data, Cin a multiple of bk: both paths tile K into the
    identical tap-aligned blocks and accumulate in the identical queue order,
    and fp32 arithmetic on small integers is exact — so direct, im2col, and
    ``lax.conv`` must agree bit for bit."""
    rng = np.random.default_rng(29)
    wt = rng.integers(-3, 4, (3, 3, 8, 16)).astype(np.float32)
    x = rng.integers(-3, 4, (2, 9, 9, 8)).astype(np.float32)
    wt[0, 0, 0, :] = 1.0  # no accidental all-zero k-tile rows
    ys = []
    for mode in MODES:
        pcw = pc.prepare_conv_weight(
            wt, batch=2, in_hw=(9, 9), block=(8, 8, 8), mode=mode
        )
        ys.append(np.asarray(pc.phantom_conv_call(jnp.asarray(x), pcw, interpret=True)))
    yref = np.asarray(ref_phantom_conv(jnp.asarray(x), jnp.asarray(wt), (1, 1), "SAME"))
    np.testing.assert_array_equal(ys[0], ys[1])
    np.testing.assert_array_equal(ys[0], yref)


def test_direct_materializes_no_patch_matrix():
    """The direct plan's runtime activation footprint is the phase-decomposed
    padded input — a constant-factor copy — never the kh·kw× patch matrix."""
    rng = np.random.default_rng(31)
    for stride in ((1, 1), (2, 2)):
        _, _, pcw = _conv_case(rng, h=16, w=16, cin=16, cout=16, stride=stride,
                               w_density=0.5, blk=(16, 16, 16))
        ph, b, hq, wq, cp = pcw.plan.phase_shape
        oh, ow = pcw.out_hw
        sh, sw = pcw.stride
        h, w = pcw.in_hw
        _, _, pads = pc.conv_geometry(h, w, pcw.kh, pcw.kw, stride, pcw.padding)
        hp, wp = h + sum(pads[0]), w + sum(pads[1])
        patch_elems = pcw.batch * oh * ow * pcw.kh * pcw.kw * cp
        phase_elems = ph * b * hq * wq * cp
        # Phase array ≈ padded input (up to per-phase rounding), never the
        # kh·kw/(sh·sw)×-redundant patch matrix.
        assert phase_elems <= pcw.batch * (hp + sh) * (wp + sw) * cp
        assert phase_elems < patch_elems
        # Stride-1: the phase array IS the padded input, shape for shape.
        if stride == (1, 1):
            assert (ph, hq, wq) == (1, hp, wp)


def test_vgg16_conv_layer_at_70pct_weight_sparsity():
    """Acceptance: VGG16-style 3x3 stride-1 conv (conv4: 128→128) ≤1e-4,
    both lowerings."""
    rng = np.random.default_rng(11)
    _assert_tri_parity(
        rng, h=8, w=8, cin=128, cout=128, stride=(1, 1), w_density=0.3,
        a_density=0.4, blk=(32, 32, 32), tol=1e-4,
    )


def test_mobilenet_stride2_conv_at_70pct_weight_sparsity():
    """Acceptance: MobileNet-style stride-2 convs (conv1 3→32 and a
    depthwise s2 layer) ≤1e-4, both lowerings."""
    rng = np.random.default_rng(13)
    _assert_tri_parity(
        rng, h=16, w=16, cin=3, cout=32, stride=(2, 2), w_density=0.3,
        a_density=0.99, blk=(32, 32, 32), tol=1e-4,
    )
    _assert_tri_parity(
        rng, h=8, w=8, cin=64, cout=64, groups=64, stride=(2, 2),
        w_density=0.3, a_density=0.4, blk=(32, 32, 32), tol=1e-4,
    )


def test_depthwise_weight_compacts():
    """Depthwise block-diagonal weight compacts away in both lowerings."""
    rng = np.random.default_rng(3)
    for mode in MODES:
        _, _, pcw = _conv_case(
            rng, cin=32, cout=32, groups=32, stride=(2, 2), w_density=0.6,
            mode=mode,
        )
        assert pcw.density() < 1.0


@pytest.mark.parametrize("mode", MODES)
def test_conv_act_call_fused_relu_and_output_mask(mode):
    """Fused ``relu(conv(x))`` + §3.8 output tile mask vs the unfused path."""
    from repro.kernels.ref import ref_activation_block_mask

    rng = np.random.default_rng(23)
    x, wt, pcw = _conv_case(rng, w_density=0.5, a_density=0.5, mode=mode)
    y, ymask = pc.phantom_conv_act_call(x, pcw, activation="relu", interpret=True)
    yref = jnp.maximum(ref_phantom_conv(x, wt, pcw.stride, pcw.padding), 0.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-4, rtol=1e-3)
    bm, bn = pcw.mask_block
    y2 = np.zeros((ymask.shape[0] * bm, ymask.shape[1] * bn), np.float32)
    flat = np.asarray(yref).reshape(-1, pcw.out_ch)
    y2[: flat.shape[0], : flat.shape[1]] = flat
    mref = np.asarray(ref_activation_block_mask(jnp.asarray(y2), (bm, bn)))
    assert (np.asarray(ymask).astype(bool) == mref).all()


def test_output_mask_identical_across_modes():
    """§3.8: the direct path's output-encoding tile mask equals the im2col
    path's bit for bit (integer data keeps the arithmetic exact, so even
    would-be rounding ties are ruled out)."""
    rng = np.random.default_rng(41)
    wt = rng.integers(-2, 3, (3, 3, 8, 16)).astype(np.float32)
    wt *= rng.random(wt.shape) < 0.4
    x = (rng.integers(-2, 3, (2, 9, 9, 8)) * (rng.random((2, 9, 9, 8)) < 0.4)).astype(np.float32)
    masks, ys = [], []
    for mode in MODES:
        pcw = pc.prepare_conv_weight(
            wt, batch=2, in_hw=(9, 9), block=(8, 8, 8), mode=mode
        )
        y, m = pc.phantom_conv_act_call(
            jnp.asarray(x), pcw, activation="relu", interpret=True
        )
        ys.append(np.asarray(y))
        masks.append(np.asarray(m))
    np.testing.assert_array_equal(ys[0], ys[1])
    np.testing.assert_array_equal(masks[0], masks[1])


@pytest.mark.parametrize("mode", MODES)
def test_conv_mask_flow_matches_value_derived_bits(mode):
    """§3.8 flow: bits from the producer's element mask == bits from values,
    and the gated output is identical."""
    rng = np.random.default_rng(5)
    x, wt, pcw = _conv_case(rng, w_density=0.5, a_density=0.3, mode=mode)
    y_values = pc.phantom_conv_call(x, pcw, interpret=True)
    y_mask = pc.phantom_conv_call(x, pcw, x_mask=(x != 0), interpret=True)
    np.testing.assert_array_equal(np.asarray(y_values), np.asarray(y_mask))


def _toy_params(rng, spec):
    params = {}
    for n, d in spec.items():
        params[n] = {
            k: jnp.asarray(_sparse(rng, p.shape, 0.4 if k == "w" else 1.0) * 0.1)
            for k, p in d.items()
        }
    return params


@pytest.mark.parametrize("conv_mode", MODES)
def test_cnn_phantom_forward_toy_net(conv_mode):
    """Tier-1 end-to-end: conv → depthwise s2 → pointwise → FC through the
    phantom path matches the dense forward, masks flowing between layers."""
    from conftest import toy_cnn

    rng = np.random.default_rng(17)
    layers, params = toy_cnn(rng)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    y_dense = cnn.cnn_forward(params, x, layers)
    prepared = cnn.prepare_cnn_phantom(
        params, layers, batch=2, block=BLK, conv_mode=conv_mode
    )
    y_ph = cnn.cnn_forward_phantom(params, prepared, x, layers, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y_ph), np.asarray(y_dense), atol=1e-4, rtol=1e-3
    )


@pytest.mark.parametrize("tau", [0.02, 0.1], ids=lambda t: f"tau{t}")
def test_cnn_phantom_forward_toy_net_tau_mode_parity(tau):
    """τ > 0 (the lossy serving knob) applies identically in both conv
    lowerings AND at the GAP mask re-encode: the direct and im2col programs
    gate the same tiles, so their outputs agree at grid tolerance even when
    both diverge from the un-thresholded dense forward."""
    import phantom
    from conftest import toy_cnn

    rng = np.random.default_rng(19)
    layers, params = toy_cnn(rng)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    ys = {}
    for conv_mode in MODES:
        cfg = phantom.PhantomConfig(
            enabled=True, block=BLK, act_threshold=tau, conv_mode=conv_mode
        )
        ys[conv_mode] = np.asarray(
            phantom.compile(layers, params, cfg, batch=2)(x, interpret=True)
        )
    np.testing.assert_allclose(ys["direct"], ys["im2col"], atol=1e-4, rtol=1e-3)


@pytest.mark.slow
@pytest.mark.parametrize("conv_mode", MODES)
@pytest.mark.parametrize("name,hw", [("vgg16", 16), ("mobilenet", 32)])
def test_cnn_phantom_forward_full_network(name, hw, conv_mode):
    """Whole-network parity (all 16 VGG16 / 28 MobileNet layers) at reduced
    resolution — every conv and FC goes through the Phantom core, compiled
    as one ``phantom.compile`` program."""
    import phantom

    rng = np.random.default_rng(0)
    spec, layers = cnn.cnn_spec(name, input_hw=hw)
    params = _toy_params(rng, spec)
    x = jnp.asarray(rng.standard_normal((1, hw, hw, 3)).astype(np.float32))
    y_dense = cnn.cnn_forward(params, x, layers)
    cfg = phantom.PhantomConfig(enabled=True, block=(32, 32, 32), conv_mode=conv_mode)
    prog = phantom.compile(layers, params, cfg, batch=1)
    y_ph = prog(x, interpret=True)
    scale = max(1.0, float(jnp.abs(y_dense).max()))
    np.testing.assert_allclose(
        np.asarray(y_ph) / scale, np.asarray(y_dense) / scale, atol=2e-6
    )
