"""Observability layer (DESIGN.md §11): Recorder semantics, warmup-correct
``timeit``, Chrome-trace export, program instrumentation, and the two CI
gates (``check_regression`` structural bands, ``check_durations`` budget).

Every timing assertion drives the injectable clock — wall-clock flakiness
never decides a tier-1 test.  The one genuinely wall-clock claim (<5%
recorder overhead on a whole-network forward) lives in
``benchmarks.kernel_bench.obs_overhead_rows`` where min-over-trials makes
it robust.
"""
import json
import pathlib
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import toy_cnn

import phantom
from repro.obs import Recorder, timeit, to_chrome_trace, validate_chrome_trace

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks import check_durations, check_regression  # noqa: E402

BLK = (16, 16, 16)
CFG = phantom.PhantomConfig(enabled=True, block=BLK)


class FakeClock:
    """Deterministic recorder clock: reads return the current virtual time;
    tests advance it explicitly."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# -- Recorder primitives ------------------------------------------------------


def test_counters_gauges_histograms_and_labels():
    rec = Recorder(clock=FakeClock())
    assert rec.inc("reqs") == 1.0
    assert rec.inc("reqs", 2.0) == 3.0
    rec.inc("reqs", engine="cnn")  # labelled: distinct series
    assert rec.counters == {"reqs": 3.0, "reqs{engine=cnn}": 1.0}
    rec.gauge("depth", 4)
    rec.gauge("depth", 2)  # gauges hold the latest value
    assert rec.gauges["depth"] == 2.0
    rec.observe("lat", 0.5)
    rec.observe("lat", 1.5)
    assert rec.hists["lat"] == [0.5, 1.5]
    # label order never matters: sorted into one stable key
    rec.inc("x", a=1, b=2)
    rec.inc("x", b=2, a=1)
    assert rec.counters["x{a=1,b=2}"] == 2.0


def test_span_measures_recorder_clock_and_emits_trace_event():
    clk = FakeClock()
    rec = Recorder(clock=clk)
    clk.advance(10.0)  # epoch offset: trace ts must be relative, not absolute
    with rec.span("layer/c1", kind="conv") as sp:
        clk.advance(2.5)
    assert sp.dur == 2.5
    assert rec.hists["layer/c1{kind=conv}"] == [2.5]
    (ev,) = rec.events
    assert ev["name"] == "layer/c1" and ev["ph"] == "X"
    assert ev["ts"] == pytest.approx(10.0 * 1e6)
    assert ev["dur"] == pytest.approx(2.5 * 1e6)
    assert ev["args"] == {"kind": "conv"}


def test_percentiles_nearest_rank():
    rec = Recorder(clock=FakeClock())
    for v in range(101):  # 0..100: nearest-rank indices land exactly
        rec.observe("lat", float(v))
    p = rec.percentiles("lat")
    assert p == {"p50": 50.0, "p95": 95.0, "p99": 99.0}
    rec.observe("one", 7.0)
    assert rec.percentiles("one") == {"p50": 7.0, "p95": 7.0, "p99": 7.0}


def test_percentiles_empty_histogram_returns_none():
    # Absence is not an error: readout code polls histograms that may not
    # have fired yet (serve engine before its first request).
    rec = Recorder(clock=FakeClock())
    assert rec.percentiles("missing") is None
    rec.observe("lat", 1.0, engine="cnn")
    assert rec.percentiles("lat") is None  # same name, different labels
    assert rec.percentiles("lat", engine="cnn") == {
        "p50": 1.0, "p95": 1.0, "p99": 1.0,
    }


def test_snapshot_to_json_and_clear(tmp_path):
    clk = FakeClock()
    rec = Recorder(clock=clk)
    rec.inc("n", 3)
    rec.gauge("g", 1.5)
    rec.observe("h", 2.0)
    rec.observe("h", 4.0)
    snap = json.loads(rec.to_json(str(tmp_path / "metrics.json")))
    assert snap == json.loads((tmp_path / "metrics.json").read_text())
    assert snap["counters"] == {"n": 3.0}
    assert snap["gauges"] == {"g": 1.5}
    h = snap["histograms"]["h"]
    assert h["count"] == 2 and h["sum"] == 6.0 and h["mean"] == 3.0
    assert h["min"] == 2.0 and h["max"] == 4.0
    rec.clear()
    assert rec.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}
    assert rec.events == []


def test_chrome_trace_valid_and_saved(tmp_path):
    clk = FakeClock()
    rec = Recorder(clock=clk)
    with rec.span("a", tid=1):
        clk.advance(0.25)
    rec.mark("rejected", reason="shape")
    trace = rec.chrome_trace()
    validate_chrome_trace(trace)  # must not raise
    assert trace["displayTimeUnit"] == "ms"
    assert [e["ph"] for e in trace["traceEvents"]] == ["X", "i"]
    path = rec.save_trace(str(tmp_path / "trace.json"))
    loaded = json.loads(pathlib.Path(path).read_text())
    validate_chrome_trace(loaded)
    assert loaded == json.loads(json.dumps(trace))  # file == in-memory trace


@pytest.mark.parametrize(
    "event, err",
    [
        ({"ph": "X", "ts": 0, "dur": 1}, "name"),
        ({"name": "a", "ph": "Q", "ts": 0}, "ph"),
        ({"name": "a", "ph": "X", "ts": "soon", "dur": 1}, "ts"),
        ({"name": "a", "ph": "X", "ts": 0, "dur": -1}, "dur"),
        ({"name": "a", "ph": "X", "ts": 0, "dur": 1, "pid": True}, "pid"),
        ({"name": "a", "ph": "i", "ts": 0, "args": {"x": object()}}, "args"),
    ],
)
def test_validate_chrome_trace_rejects_malformed(event, err):
    with pytest.raises(ValueError, match=err):
        validate_chrome_trace(to_chrome_trace([event]))


# -- timeit: the one timing loop ---------------------------------------------


def test_timeit_excludes_warmup_and_averages_reps():
    clk = FakeClock()
    costs = iter([100.0, 1.0, 2.0, 3.0])  # first call is "compilation"

    def fn():
        clk.advance(next(costs))
        return 42

    out, us = timeit(fn, reps=3, warmup=1, clock=clk)
    assert out == 42
    # the 100s warmup call is excluded; (1+2+3)/3 seconds per timed call
    assert us == pytest.approx(2.0 * 1e6)


def test_timeit_no_warmup_times_cold_call():
    clk = FakeClock()

    def fn():
        clk.advance(7.0)

    _, us = timeit(fn, reps=1, warmup=0, clock=clk)
    assert us == pytest.approx(7.0 * 1e6)


def test_timeit_records_into_recorder_and_validates():
    clk = FakeClock()
    rec = Recorder(clock=clk)

    def fn():
        clk.advance(1.0)

    timeit(fn, reps=2, warmup=0, clock=clk, recorder=rec, name="bench/fn")
    assert rec.hists["bench/fn"] == [pytest.approx(1e6)]
    with pytest.raises(ValueError, match="reps"):
        timeit(fn, reps=0)
    with pytest.raises(ValueError, match="warmup"):
        timeit(fn, warmup=-1)


def test_timeit_blocks_on_jax_results():
    """The timed window must cover execution, not dispatch: a jitted call's
    result is block_until_ready'd inside timeit (smoke: result is concrete
    and correct)."""
    import jax

    f = jax.jit(lambda a: a * 2)
    out, us = timeit(f, jnp.ones((4,)), reps=1, warmup=1)
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones(4))
    assert us >= 0.0


# -- program instrumentation --------------------------------------------------


def _compiled(rng, rec):
    layers, params = toy_cnn(rng)
    prog = phantom.compile(layers, params, CFG, batch=2, recorder=rec)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    return layers, params, prog, x


def test_program_records_one_span_per_layer_and_valid_trace():
    """The ISSUE acceptance: a whole-network forward with a recorder exports
    a valid Chrome trace whose per-layer span count equals the layer count."""
    rng = np.random.default_rng(17)
    rec = Recorder()
    layers, params, prog, x = _compiled(rng, rec)
    prog(x, interpret=True)
    layer_spans = [
        e for e in rec.events if e["ph"] == "X" and e["name"].startswith("layer/")
    ]
    assert len(layer_spans) == len(layers)
    assert [e["name"] for e in layer_spans] == [f"layer/{l.name}" for l in layers]
    assert {e["args"]["kind"] for e in layer_spans} <= {"conv", "fc"}
    validate_chrome_trace(rec.chrome_trace())
    # one program/call wrapping span; one program/lower from compile
    names = [e["name"] for e in rec.events if e["ph"] == "X"]
    assert names.count("program/call") == 1 and names.count("program/lower") == 1
    assert rec.counters["program/calls"] == 1.0
    assert rec.counters["program/lowerings"] == 1.0
    # second call: layer spans double, no new lowering
    prog(x, interpret=True)
    assert (
        len([e for e in rec.events if e["name"].startswith("layer/")])
        == 2 * len(layers)
    )
    assert rec.counters["program/lowerings"] == 1.0


def test_program_records_static_per_layer_and_per_core_metrics():
    rng = np.random.default_rng(19)
    rec = Recorder()
    layers, params = toy_cnn(rng)
    cores = 2
    cfg = phantom.PhantomConfig(enabled=True, block=BLK, cores=cores)
    phantom.compile(layers, params, cfg, batch=2, recorder=rec)
    for l in layers:
        lab = f"{{batch=2,layer={l.name}}}"
        assert rec.gauges[f"layer/steps{lab}"] >= 0
        assert rec.gauges[f"layer/dense_steps{lab}"] >= rec.gauges[f"layer/steps{lab}"]
        assert rec.gauges[f"layer/makespan{lab}"] > 0
        assert rec.gauges[f"layer/imbalance{lab}"] >= 1.0
        work = [
            rec.gauges[f"layer/core_work{{batch=2,core={c},layer={l.name}}}"]
            for c in range(cores)
        ]
        assert rec.gauges[f"layer/imbalance{lab}"] == pytest.approx(
            max(work) / (sum(work) / cores)
        )


def test_program_runtime_recorder_accounts_executed_steps():
    """Recorder(runtime=True) adds the §10 per-call accounting — and the
    numbers equal what stats(sample=...) reports for the same input."""
    rng = np.random.default_rng(23)
    layers, params = toy_cnn(rng)
    cfg = phantom.PhantomConfig(enabled=True, block=BLK, lookahead=4)
    rec = Recorder(runtime=True)
    prog = phantom.compile(layers, params, cfg, batch=2, recorder=rec)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    prog(x, interpret=True)
    ref = prog.stats(sample=x, interpret=True)
    for l in layers:
        assert (
            rec.gauges[f"layer/executed_steps{{layer={l.name}}}"]
            == ref[l.name]["executed_steps"]
        )
        assert rec.hists[f"layer/utilization{{layer={l.name}}}"] == [
            pytest.approx(ref[l.name]["utilization"])
        ]


def test_recorder_attachment_never_changes_outputs():
    rng = np.random.default_rng(29)
    layers, params = toy_cnn(rng)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    plain = phantom.compile(layers, params, CFG, batch=2)
    recd = phantom.compile(
        layers, params, CFG, batch=2, recorder=Recorder(runtime=True)
    )
    np.testing.assert_array_equal(
        np.asarray(plain(x, interpret=True)), np.asarray(recd(x, interpret=True))
    )


# -- check_regression: the structural perf gate -------------------------------

BASE_POINT = {
    "direct_us": 8000.0,
    "im2col_us": 9500.0,
    "speedup_direct_over_im2col": 1.19,
    "direct_patch_bytes": 0,
    "im2col_patch_bytes": 451584,
    "activation_bytes_ratio": 0.145,
    "multicore_naive_makespan": 96,
    "multicore_balanced_makespan": 52,
    "multicore_naive_work_makespan": 96,
    "multicore_balanced_work_makespan": 52,
    "multicore_naive_imbalance": 3.2,
    "multicore_balanced_imbalance": 1.733,
    "multicore_balance_speedup": 1.846,
    "lookahead": 8,
    "lookahead_gated_us": 7700.0,
    "lookahead_compacted_us": 7300.0,
    "lookahead_queue_steps": 154,
    "lookahead_executed_steps": 82,
    "lookahead_step_reduction": 1.878,
    "lookahead_utilization": 1.0,
}


def test_check_point_passes_on_identical_point():
    failures, notes = check_regression.check_point(dict(BASE_POINT), BASE_POINT)
    assert failures == []
    assert any("multicore_balanced_work_makespan" in n for n in notes)


def test_check_point_fails_on_balanced_makespan_regression():
    """The ISSUE acceptance: a doctored balanced-makespan regression must
    fail the gate."""
    fresh = dict(BASE_POINT)
    fresh["multicore_balanced_work_makespan"] = 96  # balance stopped working
    fresh["multicore_balanced_makespan"] = 96
    fresh["multicore_balance_speedup"] = 1.0
    failures, _ = check_regression.check_point(fresh, BASE_POINT)
    joined = "\n".join(failures)
    assert "multicore_balanced_work_makespan: 52 -> 96" in joined
    assert "multicore_balance_speedup" in joined


def test_check_point_direction_and_band_semantics():
    # improvements pass
    better = dict(BASE_POINT, lookahead_executed_steps=60,
                  multicore_balanced_work_makespan=40)
    assert check_regression.check_point(better, BASE_POINT)[0] == []
    # within-band noise passes (2% on a 5% band)
    noisy = dict(BASE_POINT, lookahead_step_reduction=1.878 * 0.98)
    assert check_regression.check_point(noisy, BASE_POINT)[0] == []
    # beyond-band regression fails
    worse = dict(BASE_POINT, lookahead_step_reduction=1.878 * 0.9)
    assert len(check_regression.check_point(worse, BASE_POINT)[0]) == 1
    # wall time is advisory: a 10x slowdown alone never fails the gate
    slow = dict(BASE_POINT, direct_us=80000.0, lookahead_compacted_us=73000.0)
    assert check_regression.check_point(slow, BASE_POINT)[0] == []
    # losing the zero-patch-bytes property fails at zero tolerance
    mat = dict(BASE_POINT, direct_patch_bytes=451584)
    assert len(check_regression.check_point(mat, BASE_POINT)[0]) == 1
    # a structural metric that vanishes from the fresh run fails
    gone = dict(BASE_POINT)
    del gone["lookahead_executed_steps"]
    failures, _ = check_regression.check_point(gone, BASE_POINT)
    assert failures and "missing" in failures[0]


def test_check_regression_main_gates_doctored_baseline(tmp_path, monkeypatch, capsys):
    """End-to-end gate flow without re-running the bench: fresh_point is
    stubbed, baseline files are doctored on disk."""
    fresh = dict(BASE_POINT)
    monkeypatch.setattr(check_regression, "fresh_point", lambda: fresh)
    base = tmp_path / "BENCH.json"
    out = tmp_path / "fresh.json"
    # healthy baseline → exit 0, metrics artifact written
    base.write_text(json.dumps([BASE_POINT]))
    rc = check_regression.main(["--baseline", str(base), "--out", str(out)])
    assert rc == 0
    assert json.loads(out.read_text()) == json.loads(json.dumps(fresh))
    # doctored baseline whose balanced makespan was better → fresh run is a
    # regression → exit 1 and the failing metric is named
    doctored = dict(BASE_POINT, multicore_balanced_work_makespan=40,
                    multicore_balanced_makespan=40)
    base.write_text(json.dumps([BASE_POINT, doctored]))  # gate uses last point
    rc = check_regression.main(["--baseline", str(base)])
    assert rc == 1
    assert "multicore_balanced_work_makespan" in capsys.readouterr().out


# -- check_durations: the per-test time budget --------------------------------

PYTEST_LOG = """\
============================= slowest durations ==============================
12.34s call     tests/test_program.py::test_save_load_fresh_process
0.50s setup    tests/test_obs.py::test_percentiles_nearest_rank
0.01s teardown tests/test_obs.py::test_percentiles_nearest_rank
(0.00 durations hidden.  Use -vv to show these durations.)
=========================== short test summary info ===========================
"""


def test_parse_durations_extracts_phases():
    rows = check_durations.parse_durations(PYTEST_LOG)
    assert rows == [
        (12.34, "call", "tests/test_program.py::test_save_load_fresh_process"),
        (0.50, "setup", "tests/test_obs.py::test_percentiles_nearest_rank"),
        (0.01, "teardown", "tests/test_obs.py::test_percentiles_nearest_rank"),
    ]
    assert check_durations.parse_durations("no durations here") == []


def test_check_durations_main_budget(tmp_path, capsys):
    log = tmp_path / "pytest.log"
    log.write_text(PYTEST_LOG)
    assert check_durations.main([str(log), "--budget", "60"]) == 0
    assert check_durations.main([str(log), "--budget", "10"]) == 1
    assert "OVER BUDGET 12.34s call" in capsys.readouterr().out
    log.write_text("nothing parseable")
    assert check_durations.main([str(log)]) == 1
