"""Balancers + simulator invariants (paper §4)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core import balance, blocksparse, dataflow as df, simulator


@given(st.integers(1, 40), st.integers(2, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_inter_core_schedule_conserves_work(n_jobs, workers, seed):
    rng = np.random.default_rng(seed)
    costs = rng.integers(1, 50, n_jobs).astype(float)
    for balanced in (False, True):
        s = balance.inter_core_schedule(costs, workers, balanced=balanced)
        jobs = sorted(j for w in s.assignment for j in w)
        assert jobs == list(range(n_jobs))  # every job exactly once
        assert s.makespan >= costs.sum() / workers - 1e-9  # LPT lower bound
    b = balance.inter_core_schedule(costs, workers, balanced=True)
    u = balance.inter_core_schedule(costs, workers, balanced=False)
    assert b.makespan <= u.makespan + 1e-9  # balancing never hurts


@given(st.integers(1, 30), st.integers(2, 5), st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_intra_shift_roundtrip(n, pes, seed):
    rng = np.random.default_rng(seed)
    entries = rng.random((n, pes, 3)) < 0.5
    shifted, shifts = balance.intra_core_shift(entries)
    back = balance.intra_core_unshift_maps(shifted, shifts)
    assert np.array_equal(back, entries)
    # Work is conserved per entry.
    assert np.array_equal(shifted.sum((1, 2)), entries.sum((1, 2)))


def test_simulator_small_net_sanity():
    layers = [df.ConvSpec("c1", 8, 8, 14, 14), df.FCSpec("f1", 72, 16)]
    wd, ad = np.array([0.3, 0.3]), np.array([0.4, 0.4])
    variants = simulator.default_variants(6)
    res = simulator.simulate_network(layers, wd, ad, variants,
                                     simulator.SimOptions(job_frac=1.0))
    for r in res:
        assert r.cycles["dense"] >= r.cycles["tds_oo"] > 0
        assert r.cycles["tds_oo"] <= r.cycles["tds_io"] * 1.001
        assert 0 < r.utilization["tds_oo"] <= 1


def test_blocksparse_queue_complete():
    """Every effectual weight tile appears exactly once (TDS completeness)."""
    rng = np.random.default_rng(1)
    w = rng.random((6, 5)) < 0.4
    q = blocksparse.build_work_queue(w, m_tiles=3)
    trips = set(zip(q.mi.tolist(), q.ki.tolist(), q.ni.tolist()))
    expect = {
        (mi, ki, ni)
        for mi in range(3)
        for ki in range(6)
        for ni in range(5)
        if w[ki, ni]
    }
    assert trips == expect
    # start/last bracket each (mi, ni) run
    assert q.start.sum() == q.last.sum()
    # empty columns are reported for §3.8 zero outputs
    empty_cols = {ni for ni in range(5) if not w[:, ni].any()}
    assert {tuple(e) for e in q.empty_out.tolist()} == {
        (mi, ni) for mi in range(3) for ni in empty_cols
    }


@given(st.integers(2, 6), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_balance_columns_is_permutation(shards, seed):
    rng = np.random.default_rng(seed)
    w = rng.random((8, 12)) < 0.5
    perm = blocksparse.balance_columns(w, shards)
    assert sorted(perm.tolist()) == list(range(12))
