"""Property suite for runtime lookahead compaction (DESIGN.md §10).

The queue compactor (:func:`repro.kernels.compaction.compact_queue`) is a
pure schedule transformation; these tests pin its two load-bearing
invariants over random ``activation bits × cores × lookahead`` draws:

* **gated-oracle popcount semantics** — each row's kept-entry count equals
  :func:`repro.core.tds.batch_cycles` (``threads=1, policy="inorder"``) on
  that row's per-segment activation popcounts: the executed grid bound is
  exactly the §3.4 TDS cycle count, per core;
* **inert-tail invariant** — past the kept count, every compacted field
  repeats the last kept entry and every flag (``start``/``last``/``abit``)
  is zero, so the padded grid steps re-execute an already-flushed block
  (same trick as the multi-core makespan padding, §4.6).

Plus the structural bookkeeping that makes the compacted queue a *queue*:
all effectual entries survive, each segment keeps exactly one ``start`` and
one ``last``, and compaction is stable (original order preserved).

A deterministic random grid runs in tier-1; the hypothesis sweep follows
the repo convention (``slow`` marker, skipped without hypothesis).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tds
from repro.kernels import compaction

# -- shared helpers -----------------------------------------------------------


def _random_queue(rng, cores, qpad):
    """Random per-core queues: segment starts, activation bits, real
    lengths (multi-core rows are makespan-padded past ``real``)."""
    start = np.zeros((cores, qpad), np.int32)
    abit = np.zeros((cores, qpad), np.int32)
    real = np.zeros(cores, np.int64)
    for r in range(cores):
        real[r] = rng.integers(1, qpad + 1)
        s = (rng.random(qpad) < 0.3).astype(np.int32)
        s[0] = 1  # first real entry always opens a segment
        start[r, : real[r]] = s[: real[r]]
        abit[r, : real[r]] = rng.integers(0, 2, int(real[r]))
    return start, abit, real


def _oracle_count(abit_row, start_row, real, la):
    """Gated-oracle popcount semantics: TDS cycles over the row's segments."""
    a = abit_row[:real]
    starts = np.flatnonzero(start_row[:real] == 1)
    segs = np.split(a, starts[1:]) if len(starts) else [a]
    lengths = np.asarray([len(s) for s in segs], dtype=np.int64)
    pops = np.zeros((len(segs), int(lengths.max())), np.int32)
    for i, s in enumerate(segs):
        pops[i, : len(s)] = s
    cyc = tds.batch_cycles(pops, lengths, lookahead=la, threads=1, policy="inorder")
    return int(cyc.sum()), len(segs)


def _check_invariants(start, abit, real, la):
    cores, qpad = start.shape
    meta = compaction.compaction_meta(
        start if cores > 1 else start[0],
        real if cores > 1 else None,
    )
    fields = {"mi": np.tile(np.arange(qpad, dtype=np.int32), (cores, 1))}
    if cores == 1:
        fields = {"mi": fields["mi"][0]}
        args = (fields, start[0], np.zeros(qpad, np.int32), abit[0])
        real = np.full(1, qpad, np.int64)  # 1-D queues have no padding
    else:
        args = (fields, start, np.zeros_like(start), abit)
    with jax.disable_jit():  # eager: shapes vary per example, skip XLA
        out, start_c, last_c, abit_c, count = compaction.compact_queue(
            *args, meta["seg_base"], meta["seg_end"], meta["pad"], lookahead=la
        )
    mi = np.atleast_2d(np.asarray(out["mi"]))
    start_c = np.atleast_2d(np.asarray(start_c))
    last_c = np.atleast_2d(np.asarray(last_c))
    abit_c = np.atleast_2d(np.asarray(abit_c))
    counts = np.atleast_1d(np.asarray(count))
    for r in range(cores):
        n = int(counts[r])
        want, n_segs = _oracle_count(abit[r], start[r], int(real[r]), la)
        # 1. per-core executed count == the TDS cycle oracle
        assert n == want, (r, la, abit[r].tolist(), start[r].tolist())
        # 2. inert tail: fields repeat the last kept entry, flags are zero
        assert np.all(mi[r, n:] == mi[r, n - 1])
        assert not start_c[r, n:].any() and not last_c[r, n:].any()
        assert not abit_c[r, n:].any()
        # 3. every effectual entry survives compaction
        assert int(abit_c[r, :n].sum()) == int(abit[r, : real[r]].sum())
        # 4. one start and one last per surviving segment
        assert int(start_c[r, :n].sum()) == n_segs
        assert int(last_c[r, :n].sum()) == n_segs
        # 5. stable: kept entries keep their original relative order
        assert np.all(np.diff(mi[r, :n]) > 0)


# -- deterministic tier-1 grid ------------------------------------------------


@pytest.mark.parametrize("cores", [1, 2, 3])
@pytest.mark.parametrize("la", [1, 2, 5])
def test_compaction_invariants_random_grid(cores, la):
    rng = np.random.default_rng(cores * 31 + la)
    for trial in range(4):
        qpad = int(rng.integers(2, 18))
        start, abit, real = _random_queue(rng, cores, qpad)
        _check_invariants(start, abit, real, la)


def test_all_dead_queue_keeps_pacing_steps_only():
    """Zero activations: each segment of length d survives as exactly
    ceil(d / L) §3.8 zero-writer pacing steps."""
    start = np.zeros((1, 12), np.int32)
    start[0, [0, 5, 9]] = 1  # segments of length 5, 4, 3
    abit = np.zeros((1, 12), np.int32)
    real = np.array([12], np.int64)
    meta = compaction.compaction_meta(start[0])
    with jax.disable_jit():
        _, _, _, abit_c, count = compaction.compact_queue(
            {"mi": np.arange(12, dtype=np.int32)},
            start[0], np.zeros(12, np.int32), abit[0],
            meta["seg_base"], meta["seg_end"], meta["pad"], lookahead=4,
        )
    assert int(count) == 2 + 1 + 1  # ceil(5/4) + ceil(4/4) + ceil(3/4)
    assert not np.asarray(abit_c).any()
    _check_invariants(start, abit, real, 4)


def test_all_live_queue_is_identity_schedule():
    """Full activations: nothing compacts — every entry is its cycle's MAC."""
    start = np.zeros((1, 8), np.int32)
    start[0, [0, 3]] = 1
    abit = np.ones((1, 8), np.int32)
    _check_invariants(start, abit, np.array([8], np.int64), 3)
    meta = compaction.compaction_meta(start[0])
    with jax.disable_jit():
        out, start_c, _, _, count = compaction.compact_queue(
            {"mi": np.arange(8, dtype=np.int32)},
            start[0], np.zeros(8, np.int32), abit[0],
            meta["seg_base"], meta["seg_end"], meta["pad"], lookahead=3,
        )
    assert int(count) == 8
    np.testing.assert_array_equal(np.asarray(out["mi"]), np.arange(8))
    np.testing.assert_array_equal(np.asarray(start_c), start[0])


# -- hypothesis sweep (slow tier; the deterministic grid above always runs) --

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 containers without the dev extra
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def queue_case(draw):
        cores = draw(st.integers(1, 3))
        qpad = draw(st.integers(1, 24))
        la = draw(st.integers(1, 8))
        seed = draw(st.integers(0, 2**31 - 1))
        return cores, qpad, la, seed

    @pytest.mark.slow
    @given(queue_case())
    @settings(max_examples=60, deadline=None)
    def test_compaction_invariants_property(case):
        cores, qpad, la, seed = case
        rng = np.random.default_rng(seed)
        start, abit, real = _random_queue(rng, cores, qpad)
        _check_invariants(start, abit, real, la)

    @pytest.mark.slow
    @given(st.integers(1, 24), st.integers(1, 8), st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_single_core_lookahead_bounds(qpad, la, seed):
        """Executed count is bracketed: every live entry needs a MAC step
        (live <= count) and compaction never exceeds the gated grid
        (count <= qpad)."""
        rng = np.random.default_rng(seed)
        start, abit, real = _random_queue(rng, 1, qpad)
        meta = compaction.compaction_meta(start[0])
        with jax.disable_jit():
            _, _, _, _, count = compaction.compact_queue(
                {"mi": np.arange(qpad, dtype=np.int32)},
                start[0], np.zeros(qpad, np.int32), abit[0],
                meta["seg_base"], meta["seg_end"], meta["pad"], lookahead=la,
            )
        n = int(count)
        live = int(abit[0].sum())
        assert max(live, 1) <= n <= qpad
