"""Property tests for the conv work-queue and the dense-reproduction
guarantee (paper §3: no zero-weight work is ever scheduled; sparsity
machinery is semantics-free when nothing is sparse)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.kernels import phantom_conv as pc
from repro.kernels.ref import ref_phantom_conv

pytestmark = pytest.mark.slow  # full property suite runs with -m slow


@st.composite
def conv_config(draw):
    kh = draw(st.sampled_from([1, 3]))
    stride = draw(st.sampled_from([(1, 1), (2, 2)]))
    padding = draw(st.sampled_from(["SAME", "VALID"]))
    h = draw(st.integers(kh, 9))
    cin = draw(st.sampled_from([4, 8]))
    cout = draw(st.sampled_from([4, 16]))
    density = draw(st.floats(0.0, 1.0))
    seed = draw(st.integers(0, 2**31 - 1))
    return kh, stride, padding, h, cin, cout, density, seed


@given(conv_config())
@settings(max_examples=40, deadline=None)
def test_conv_work_queue_never_emits_zero_weight_tile(cfg):
    """Every valid queue step points at a packed weight tile with at least
    one nonzero — zero tiles (pruned or structurally zero) never cost a
    grid step (the TDS guarantee, §3.4)."""
    kh, stride, padding, h, cin, cout, density, seed = cfg
    rng = np.random.default_rng(seed)
    wt = rng.standard_normal((kh, kh, cin, cout)).astype(np.float32)
    wt *= rng.random(wt.shape) < density
    pcw = pc.prepare_conv_weight(
        wt, batch=1, in_hw=(h, h), stride=stride, padding=padding, block=(8, 8, 8)
    )
    pw = pcw.pw
    packed = np.asarray(pw.packed)
    valid = pw.valid.astype(bool)
    for step in np.flatnonzero(valid):
        assert packed[pw.wq[step]].any(), "queue step references a zero weight tile"
    # And conversely the queue covers exactly the kept tiles per output col:
    kept = int(pw.w_bmask.sum()) * pw.grid_tiles[0]
    assert int(valid.sum()) == kept


@given(
    st.sampled_from([1, 3]),
    st.sampled_from([(1, 1), (2, 2)]),
    st.sampled_from(["SAME", "VALID"]),
    st.integers(3, 8),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_dense_conv_reproduces_dense_op_bit_exactly(kh, stride, padding, h, seed):
    """Dense input x dense weight with small-integer values: fp32 arithmetic
    is exact, so the phantom path must equal ``lax.conv_general_dilated``
    bit for bit regardless of accumulation order."""
    rng = np.random.default_rng(seed)
    cin, cout = 4, 8
    wt = rng.integers(-3, 4, (kh, kh, cin, cout)).astype(np.float32)
    x = rng.integers(-3, 4, (1, h, h, cin)).astype(np.float32)
    wt[wt == 0] = 1.0  # dense weight: no accidental zero tiles
    x[x == 0] = 1.0
    pcw = pc.prepare_conv_weight(
        wt, batch=1, in_hw=(h, h), stride=stride, padding=padding, block=(8, 8, 8)
    )
    y = pc.phantom_conv_call(jnp.asarray(x), pcw, interpret=True)
    yref = ref_phantom_conv(jnp.asarray(x), jnp.asarray(wt), stride, padding)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yref))
