"""Property tests for the conv work-queues and the dense-reproduction
guarantee (paper §3: no zero-weight work is ever scheduled; sparsity
machinery is semantics-free when nothing is sparse), for both the explicit
im2col lowering and the direct (implicit-im2col) kernel."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.kernels import phantom_conv as pc
from repro.kernels.ref import ref_phantom_conv

pytestmark = pytest.mark.slow  # full property suite runs with -m slow


@st.composite
def conv_config(draw):
    kh = draw(st.sampled_from([1, 3]))
    stride = draw(st.sampled_from([(1, 1), (2, 2)]))
    padding = draw(st.sampled_from(["SAME", "VALID"]))
    h = draw(st.integers(kh, 9))
    cin = draw(st.sampled_from([4, 8]))
    cout = draw(st.sampled_from([4, 16]))
    density = draw(st.floats(0.0, 1.0))
    seed = draw(st.integers(0, 2**31 - 1))
    return kh, stride, padding, h, cin, cout, density, seed


@given(conv_config())
@settings(max_examples=40, deadline=None)
def test_conv_work_queue_never_emits_zero_weight_tile(cfg):
    """Every valid queue step points at a packed weight tile with at least
    one nonzero — zero tiles (pruned or structurally zero) never cost a
    grid step (the TDS guarantee, §3.4)."""
    kh, stride, padding, h, cin, cout, density, seed = cfg
    rng = np.random.default_rng(seed)
    wt = rng.standard_normal((kh, kh, cin, cout)).astype(np.float32)
    wt *= rng.random(wt.shape) < density
    for mode in ("im2col", "direct"):
        pcw = pc.prepare_conv_weight(
            wt, batch=1, in_hw=(h, h), stride=stride, padding=padding,
            block=(8, 8, 8), mode=mode,
        )
        pw = pcw.pw if mode == "im2col" else pcw.plan
        packed = np.asarray(pw.packed)
        valid = pw.valid.astype(bool)
        for step in np.flatnonzero(valid):
            assert packed[pw.wq[step]].any(), "queue step references a zero weight tile"
        # And conversely the queue covers exactly the kept tiles per output col:
        kept = int(pw.w_bmask.sum()) * pw.grid_tiles[0]
        assert int(valid.sum()) == kept


@given(
    st.sampled_from([1, 3]),
    st.sampled_from([(1, 1), (2, 2)]),
    st.sampled_from(["SAME", "VALID"]),
    st.integers(3, 8),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_dense_conv_reproduces_dense_op_bit_exactly(kh, stride, padding, h, seed):
    """Dense input x dense weight with small-integer values: fp32 arithmetic
    is exact, so the phantom path must equal ``lax.conv_general_dilated``
    bit for bit regardless of accumulation order."""
    rng = np.random.default_rng(seed)
    cin, cout = 4, 8
    wt = rng.integers(-3, 4, (kh, kh, cin, cout)).astype(np.float32)
    x = rng.integers(-3, 4, (1, h, h, cin)).astype(np.float32)
    wt[wt == 0] = 1.0  # dense weight: no accidental zero tiles
    x[x == 0] = 1.0
    for mode in ("im2col", "direct"):
        pcw = pc.prepare_conv_weight(
            wt, batch=1, in_hw=(h, h), stride=stride, padding=padding,
            block=(8, 8, 8), mode=mode,
        )
        y = pc.phantom_conv_call(jnp.asarray(x), pcw, interpret=True)
        yref = ref_phantom_conv(jnp.asarray(x), jnp.asarray(wt), stride, padding)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(yref))


@given(conv_config())
@settings(max_examples=25, deadline=None)
def test_direct_never_diverges_from_reference(cfg):
    """Random geometry/density: the direct (implicit-im2col) kernel always
    matches the dense reference and the explicit im2col lowering."""
    kh, stride, padding, h, cin, cout, density, seed = cfg
    rng = np.random.default_rng(seed)
    wt = rng.standard_normal((kh, kh, cin, cout)).astype(np.float32)
    wt *= rng.random(wt.shape) < density
    x = rng.standard_normal((1, h, h, cin)).astype(np.float32)
    x *= rng.random(x.shape) < density
    yref = ref_phantom_conv(jnp.asarray(x), jnp.asarray(wt), stride, padding)
    ys = {}
    for mode in ("direct", "im2col"):
        pcw = pc.prepare_conv_weight(
            wt, batch=1, in_hw=(h, h), stride=stride, padding=padding,
            block=(8, 8, 8), mode=mode,
        )
        ys[mode] = np.asarray(pc.phantom_conv_call(jnp.asarray(x), pcw, interpret=True))
        np.testing.assert_allclose(ys[mode], np.asarray(yref), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(ys["direct"], ys["im2col"], atol=1e-5, rtol=1e-4)


@given(
    st.sampled_from([1, 3]),
    st.sampled_from([(1, 1), (2, 2)]),
    st.sampled_from(["SAME", "VALID"]),
    st.integers(3, 8),
    st.floats(0.1, 1.0),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_output_mask_identical_across_modes(kh, stride, padding, h, density, seed):
    """§3.8: the output-encoding tile mask emitted by the direct path equals
    the im2col path's bit for bit.  Small-integer data keeps fp32 arithmetic
    exact, so differing accumulation orders cannot flip a zero/nonzero bit."""
    rng = np.random.default_rng(seed)
    cin, cout = 8, 16
    wt = rng.integers(-2, 3, (kh, kh, cin, cout)).astype(np.float32)
    wt *= rng.random(wt.shape) < density
    x = rng.integers(-2, 3, (1, h, h, cin)).astype(np.float32)
    x *= rng.random(x.shape) < density
    masks = []
    for mode in ("direct", "im2col"):
        pcw = pc.prepare_conv_weight(
            wt, batch=1, in_hw=(h, h), stride=stride, padding=padding,
            block=(8, 8, 8), mode=mode,
        )
        _, m = pc.phantom_conv_act_call(
            jnp.asarray(x), pcw, activation="relu", interpret=True
        )
        masks.append(np.asarray(m))
    np.testing.assert_array_equal(masks[0], masks[1])
