"""Engine ↔ simulator consistency: the functional engine's effectual-MAC
count must equal the mask-level count the cycle simulator schedules for the
same masks (paper §5.1 — "only this information is needed to efficiently
represent the MAC operations needed per layer").  Guards the cycle model
against drifting from real execution: both sides are driven from one seeded
layer, with no sampling, so the counts must agree exactly."""
import numpy as np

from repro.core import dataflow as df, engine


def _mask_level_macs(a_mask, w_vec, kh, kw, stride):
    """Ground truth: Σ over output positions of |window ∧ weight| (VALID)."""
    windows = df.im2col_mask(a_mask, kh, kw, stride, pad="valid")
    return int((windows & w_vec[None, :]).sum())


def test_engine_valid_macs_equals_mask_level_count():
    """Single-channel conv: engine.valid_macs == im2col-mask popcount, for
    unit and non-unit strides (goal G3 both ways)."""
    rng = np.random.default_rng(42)
    act = rng.standard_normal((9, 9)) * (rng.random((9, 9)) < 0.4)
    flt = rng.standard_normal((3, 3)) * (rng.random((3, 3)) < 0.6)
    for stride in ((1, 1), (2, 2)):
        res = engine.phantom_conv2d(act, flt, stride=stride)
        expect = _mask_level_macs(act != 0, (flt != 0).reshape(-1), 3, 3, stride)
        assert res.stats.valid_macs == expect
        # The §3.8 output mask covers every nonzero output.
        assert np.all(res.out_mask[res.outputs != 0])


def test_engine_valid_macs_equals_simulator_layer_work():
    """Depthwise layer, full sampling: Σ per-channel engine valid_macs ==
    the simulator's scheduled valid_macs for identical masks — the cycle
    model never times work the functional engine would not execute."""
    rng = np.random.default_rng(7)
    c, h = 4, 9
    spec = df.ConvSpec("dw", c, c, h, h, 3, 3, (1, 1), depthwise=True, pad="valid")
    act = rng.standard_normal((h, h, c)) * (rng.random((h, h, c)) < 0.5)
    flt = rng.standard_normal((3, 3, c)) * (rng.random((3, 3, c)) < 0.7)

    work = df.layer_work(spec, flt != 0, act != 0, df.Phantom2DConfig(), df.FULL)
    sim_macs = sum(cw.valid_macs for rows in work.jobs for cw in rows)
    assert all(cw.scale == 1.0 for rows in work.jobs for cw in rows)

    eng_macs = sum(
        engine.phantom_conv2d(act[:, :, ch], flt[:, :, ch]).stats.valid_macs
        for ch in range(c)
    )
    assert eng_macs == sim_macs

    # And both equal the raw mask-level ground truth.
    expect = sum(
        _mask_level_macs(act[:, :, ch] != 0, (flt[:, :, ch] != 0).reshape(-1), 3, 3, (1, 1))
        for ch in range(c)
    )
    assert eng_macs == expect


def test_engine_valid_macs_equals_simulator_regular_conv():
    """Regular conv (1 input channel, several filters): per-filter engine
    runs vs the simulator's filter-broadcast decomposition."""
    rng = np.random.default_rng(11)
    h, cout = 8, 3
    spec = df.ConvSpec("conv", 1, cout, h, h, 3, 3, (1, 1), pad="valid")
    act = rng.standard_normal((h, h, 1)) * (rng.random((h, h, 1)) < 0.5)
    flt = rng.standard_normal((3, 3, 1, cout)) * (rng.random((3, 3, 1, cout)) < 0.6)

    work = df.layer_work(spec, flt != 0, act != 0, df.Phantom2DConfig(), df.FULL)
    sim_macs = sum(cw.valid_macs for rows in work.jobs for cw in rows)

    eng_macs = sum(
        engine.phantom_conv2d(act[:, :, 0], flt[:, :, 0, f]).stats.valid_macs
        for f in range(cout)
    )
    assert eng_macs == sim_macs
