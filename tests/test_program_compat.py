"""Deprecation-shim guard for the pre-program entry points (tier-1).

DeprecationWarning is *an error* in this module, so the contract is sharp:
each old entry point (``prepare_cnn_phantom``, ``cnn_forward_phantom``, the
legacy ``CnnServeEngine(params, layers, ...)`` form) warns exactly once per
process — the first call raises here (caught by ``pytest.warns``), every
later call is silent (any second emission would fail the test under the
error filter) — and all of them delegate to the program machinery
bit-for-bit at ``Cin % bk == 0``.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

import phantom
from repro import program as program_mod
from repro.core.dataflow import ConvSpec, FCSpec
from repro.models import cnn
from repro.serve import CnnServeEngine

pytestmark = pytest.mark.filterwarnings("error::DeprecationWarning")

BLK = (8, 8, 8)


def _aligned_net(rng):
    """Channels are multiples of bk=8 ⇒ both paths tile K identically, so
    shim-vs-program agreement must be bit-for-bit (DESIGN.md §3)."""
    layers = [
        ConvSpec("c1", 8, 16, 8, 8, 3, 3, (1, 1)),
        ConvSpec("c2", 16, 16, 8, 8, 3, 3, (1, 1)),
        FCSpec("fc", 16, 8, pool="gap"),
    ]
    params = {}
    for l in layers:
        wshape = (
            (l.kh, l.kw, l.in_ch, l.out_ch)
            if isinstance(l, ConvSpec)
            else (l.in_dim, l.out_dim)
        )
        w = rng.standard_normal(wshape).astype(np.float32) * 0.1
        w *= rng.random(wshape) < 0.4
        params[l.name] = {
            "w": jnp.asarray(w),
            "b": jnp.asarray(rng.standard_normal(wshape[-1]).astype(np.float32) * 0.1),
        }
    return layers, params


@pytest.fixture(autouse=True)
def _rearmed_warnings():
    """Each test sees freshly-armed once-per-process warnings."""
    program_mod.reset_deprecation_warnings()
    yield
    program_mod.reset_deprecation_warnings()


def test_old_entry_points_warn_exactly_once():
    rng = np.random.default_rng(1)
    layers, params = _aligned_net(rng)
    x = jnp.asarray(rng.standard_normal((1, 8, 8, 8)).astype(np.float32))

    with pytest.warns(DeprecationWarning, match="prepare_cnn_phantom") as rec:
        prepared = cnn.prepare_cnn_phantom(params, layers, batch=1, block=BLK)
    assert sum(r.category is DeprecationWarning for r in rec) == 1
    with pytest.warns(DeprecationWarning, match="cnn_forward_phantom") as rec:
        cnn.cnn_forward_phantom(params, prepared, x, layers, interpret=True)
    assert sum(r.category is DeprecationWarning for r in rec) == 1
    with pytest.warns(DeprecationWarning, match="CnnServeEngine") as rec:
        CnnServeEngine(params, layers, batch_size=1, block=BLK, interpret=True)
    assert sum(r.category is DeprecationWarning for r in rec) == 1

    # Second calls are silent: under the error filter any further emission
    # would raise out of these statements.
    prepared = cnn.prepare_cnn_phantom(params, layers, batch=1, block=BLK)
    cnn.cnn_forward_phantom(params, prepared, x, layers, interpret=True)
    CnnServeEngine(params, layers, batch_size=1, block=BLK, interpret=True)


def test_program_form_never_warns():
    rng = np.random.default_rng(2)
    layers, params = _aligned_net(rng)
    prog = phantom.compile(
        layers, params, phantom.PhantomConfig(enabled=True, block=BLK), batch=1
    )
    eng = CnnServeEngine(program=prog, batch_size=1, interpret=True)
    eng.submit(np.zeros((8, 8, 8), np.float32))
    eng.run()  # error filter active: any DeprecationWarning fails the test


def test_shims_delegate_bit_for_bit():
    """Old prepare+forward == program forward, and the legacy engine ==
    the program-backed engine, bit for bit at Cin % bk == 0."""
    rng = np.random.default_rng(3)
    layers, params = _aligned_net(rng)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 8)).astype(np.float32))

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        prepared = cnn.prepare_cnn_phantom(params, layers, batch=2, block=BLK)
        y_old = cnn.cnn_forward_phantom(params, prepared, x, layers, interpret=True)

    prog = phantom.compile(
        layers, params, phantom.PhantomConfig(enabled=True, block=BLK), batch=2
    )
    np.testing.assert_array_equal(
        np.asarray(y_old), np.asarray(prog(x, interpret=True))
    )

    imgs = rng.standard_normal((3, 8, 8, 8)).astype(np.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng_old = CnnServeEngine(params, layers, batch_size=2, block=BLK, interpret=True)
    reqs_old = [eng_old.submit(im) for im in imgs]
    eng_old.run()
    eng_new = CnnServeEngine(program=prog, batch_size=2, interpret=True)
    reqs_new = [eng_new.submit(im) for im in imgs]
    eng_new.run()
    np.testing.assert_array_equal(
        np.stack([r.logits for r in reqs_old]),
        np.stack([r.logits for r in reqs_new]),
    )
