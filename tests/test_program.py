"""The program API: ``phantom.compile`` → :class:`PhantomProgram`.

Covers the DESIGN.md §8 contract: compile-once parity with the dense
forward, the per-batch-size plan cache (no re-lowering on repeat calls),
save/load round-trips that are bit-identical with identical ``stats()``
(in-process and across a fresh interpreter), τ-consistent GAP mask
re-encoding, padded-slot gating through the program-backed serve engine,
and single-registration extensibility (the FFN layer kind).
"""
import hashlib
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import toy_cnn

import phantom
from repro.core.dataflow import ConvSpec, FCSpec
from repro.models import cnn

BLK = (16, 16, 16)
CFG = phantom.PhantomConfig(enabled=True, block=BLK)


def _rand_params(rng, layers, w_density=0.4, bias_scale=0.1):
    params = {}
    for l in layers:
        if isinstance(l, ConvSpec):
            wshape = (l.kh, l.kw, 1 if l.depthwise else l.in_ch, l.out_ch)
            bshape = (l.out_ch,)
        else:
            wshape, bshape = (l.in_dim, l.out_dim), (l.out_dim,)
        w = rng.standard_normal(wshape).astype(np.float32) * 0.1
        w *= rng.random(wshape) < w_density
        params[l.name] = {
            "w": jnp.asarray(w),
            "b": jnp.asarray(
                rng.standard_normal(bshape).astype(np.float32) * bias_scale
            ),
        }
    return params


def _vggish(rng):
    """VGG16-in-miniature: conv stack with an inter-conv max-pool, then the
    pool5→flatten FC head and a second (last, linear) FC."""
    layers = [
        ConvSpec("c1", 3, 16, 8, 8, 3, 3, (1, 1)),
        ConvSpec("c2", 16, 32, 4, 4, 3, 3, (1, 1)),  # 8→4 via maxpool glue
        FCSpec("fc1", 2 * 2 * 32, 32, pool="pool5"),
        FCSpec("fc2", 32, 10),
    ]
    return layers, _rand_params(rng, layers)


def _mobilenetish(rng):
    """MobileNet-in-miniature: conv → depthwise s2 → pointwise → GAP FC
    (the conftest toy net)."""
    return toy_cnn(rng)


NETS = {"vggish": _vggish, "mobilenetish": _mobilenetish}


@pytest.mark.parametrize("net", NETS, ids=str)
def test_program_matches_dense(net):
    rng = np.random.default_rng(11)
    layers, params = NETS[net](rng)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    prog = phantom.compile(layers, params, CFG, batch=2)
    y = prog(x, interpret=True)
    ref = cnn.cnn_forward(params, x, layers)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4, rtol=1e-3)


def test_batch_plan_cache_no_relowering():
    """at_batch(1/3/8) each match the lax.conv reference; repeat calls are
    cache hits (same plan object, lowering counter frozen)."""
    rng = np.random.default_rng(5)
    layers, params = toy_cnn(rng)
    prog = phantom.compile(layers, params, CFG, batch=(1, 3, 8))
    assert prog.lowerings == 3 and prog.batch_sizes == (1, 3, 8)
    for b in (1, 3, 8):
        x = jnp.asarray(rng.standard_normal((b, 8, 8, 3)).astype(np.float32))
        y = prog(x, interpret=True)
        ref = cnn.cnn_forward(params, x, layers)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4, rtol=1e-3)
    # Repeat calls: cache hit — identical plan dict, no new lowerings.
    before = {b: prog.at_batch(b) for b in (1, 3, 8)}
    assert prog.lowerings == 3
    for b in (1, 3, 8):
        assert prog.at_batch(b) is before[b]
    assert prog.lowerings == 3
    # stats never lowers and is per-batch.
    s1, s8 = prog.stats(1), prog.stats(8)
    assert s8["c1"]["valid_macs"] == 8 * s1["c1"]["valid_macs"]
    assert prog.lowerings == 3


def test_program_engine_padded_slot_gating():
    """Program-backed CnnServeEngine: padded slots stay gated (slot mask
    defeats relu(0 + b)) and live rows match the dense forward."""
    from repro.serve import CnnServeEngine

    rng = np.random.default_rng(31)
    layers, params = toy_cnn(rng)
    prog = phantom.compile(layers, params, CFG, batch=2)
    eng = CnnServeEngine(program=prog, batch_size=2, interpret=True)
    imgs = rng.standard_normal((3, 8, 8, 3)).astype(np.float32)
    reqs = [eng.submit(im) for im in imgs]
    eng.run()
    assert (eng.batches_run, eng.images_served, eng.padded_slots) == (2, 3, 1)
    ref = np.asarray(cnn.cnn_forward(params, jnp.asarray(imgs), layers))
    np.testing.assert_allclose(
        np.stack([r.logits for r in reqs]), ref, atol=1e-4, rtol=1e-3
    )
    assert eng.stats()["fc"]["kind"] == "fc"
    # Direct slot-mask check: a dead slot's logits collapse to the bias.
    x = np.zeros((2, 8, 8, 3), np.float32)
    x[0] = imgs[0]
    y = prog(jnp.asarray(x), slot_mask=jnp.asarray([1.0, 0.0]), interpret=True)
    np.testing.assert_array_equal(
        np.asarray(y)[1], np.asarray(params[layers[-1].name]["b"])
    )


@pytest.mark.parametrize("net", NETS, ids=str)
def test_save_load_roundtrip(net, tmp_path):
    """load(save(p)) is bit-identical: outputs, stats, and the raw packed
    payloads/queues/masks — with zero re-lowerings.  Two cached batch sizes
    are saved; the batch-invariant payloads are deduplicated in the npz but
    must restore identically for both plans."""
    rng = np.random.default_rng(7)
    layers, params = NETS[net](rng)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    x3 = jnp.asarray(rng.standard_normal((3, 8, 8, 3)).astype(np.float32))
    prog = phantom.compile(layers, params, CFG, batch=(2, 3))
    y = np.asarray(prog(x, interpret=True))
    y3 = np.asarray(prog(x3, interpret=True))

    prog.save(str(tmp_path / "prog"))
    q = phantom.PhantomProgram.load(str(tmp_path / "prog"))
    assert q.lowerings == 0 and q.batch_sizes == (2, 3)
    np.testing.assert_array_equal(np.asarray(q(x, interpret=True)), y)
    np.testing.assert_array_equal(np.asarray(q(x3, interpret=True)), y3)
    assert q.lowerings == 0  # the forwards reused the restored plans
    assert q.stats(2) == prog.stats(2)
    # Raw artifact identity: queues, packed payloads, weight masks.
    for name, plan in prog.at_batch(2).items():
        loaded = q.at_batch(2)[name]
        if isinstance(plan, type(loaded)) and hasattr(plan, "pw"):  # conv
            a = plan.pw if plan.pw is not None else plan.plan
            b = loaded.pw if loaded.pw is not None else loaded.plan
        else:
            a, b = plan, loaded
        for field in ("packed", "mi", "ni", "wq", "start", "last", "valid", "w_bmask"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, field)), np.asarray(getattr(b, field))
            )


def test_save_load_roundtrip_bfloat16(tmp_path):
    """Extension dtypes survive the npz round-trip (stored as byte views):
    a bfloat16-packed program — including bfloat16 *param* leaves — reloads
    with the same dtypes and bit-identical outputs."""
    rng = np.random.default_rng(29)
    layers, params = toy_cnn(rng)
    for p in params.values():
        p["b"] = p["b"].astype(jnp.bfloat16)
    cfg = phantom.PhantomConfig(enabled=True, block=BLK, dtype="bfloat16")
    prog = phantom.compile(layers, params, cfg, batch=1)
    x = jnp.asarray(rng.standard_normal((1, 8, 8, 3)).astype(np.float32))
    y = np.asarray(prog(x, interpret=True))
    prog.save(str(tmp_path / "prog"))
    q = phantom.PhantomProgram.load(str(tmp_path / "prog"))
    assert q.at_batch(1)["c1"].plan.packed.dtype == jnp.bfloat16
    assert q.params["c1"]["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(q(x, interpret=True)), y)


def test_save_load_fresh_process(tmp_path):
    """A saved program reloaded in a *fresh interpreter* serves batches
    through CnnServeEngine bit-identically with lowerings == 0 — the
    weight-load-time transformation ran once per fleet, not per process."""
    rng = np.random.default_rng(13)
    layers, params = toy_cnn(rng)
    prog = phantom.compile(layers, params, CFG, batch=2)
    imgs = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
    np.save(tmp_path / "imgs.npy", imgs)
    ref = np.asarray(
        prog(jnp.asarray(imgs), slot_mask=jnp.asarray([1.0, 1.0]), interpret=True)
    )
    prog.save(str(tmp_path / "prog"))

    script = f"""
import hashlib, numpy as np
import phantom
from repro.serve import CnnServeEngine

prog = phantom.PhantomProgram.load({str(tmp_path / "prog")!r})
assert prog.lowerings == 0, "load must not re-lower"
eng = CnnServeEngine(program=prog, batch_size=2, interpret=True)
assert prog.lowerings == 0, "engine reused the restored batch plan"
imgs = np.load({str(tmp_path / "imgs.npy")!r})
reqs = [eng.submit(im) for im in imgs]
eng.run()
out = np.stack([r.logits for r in reqs])
assert prog.lowerings == 0, "serving must not re-lower"
print("DIGEST", hashlib.sha256(out.tobytes()).hexdigest())
"""
    res = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,
    )
    assert res.returncode == 0, res.stderr
    digest = res.stdout.strip().split("DIGEST ")[-1]
    assert digest == hashlib.sha256(ref.tobytes()).hexdigest()


def test_gap_mask_applies_tau():
    """The GAP re-encode uses the producer rule ``x > τ`` (the old forward
    used ``x != 0`` there): with every pooled activation in (0, τ], the FC
    consumer sees a fully-gated input and its logits collapse to the bias
    exactly; the dense forward (no τ) disagrees — τ is genuinely lossy."""
    rng = np.random.default_rng(3)
    layers = [ConvSpec("c1", 3, 16, 8, 8, 3, 3, (1, 1)), FCSpec("fc", 16, 10, pool="gap")]
    params = _rand_params(rng, layers, w_density=1.0)
    # Tiny conv weights ⇒ GAP outputs ≪ τ but nonzero; inputs ~N(0,1) ≫ τ so
    # the first layer's own value-derived gating stays fully live.
    params["c1"]["w"] = params["c1"]["w"] * 1e-3
    params["c1"]["b"] = jnp.zeros_like(params["c1"]["b"])
    tau = 0.05
    cfg = phantom.PhantomConfig(enabled=True, block=BLK, act_threshold=tau)
    x = jnp.asarray(np.abs(rng.standard_normal((1, 8, 8, 3))).astype(np.float32))
    prog = phantom.compile(layers, params, cfg, batch=1)
    y = np.asarray(prog(x, interpret=True))
    np.testing.assert_array_equal(y[0], np.asarray(params["fc"]["b"]))
    # Sanity: the un-thresholded network does NOT collapse to the bias.
    dense = np.asarray(cnn.cnn_forward(params, x, layers))
    assert np.abs(dense[0] - np.asarray(params["fc"]["b"])).max() > 0


def test_ffn_spec_is_one_registration():
    """A net containing the FFN layer kind (registered once in
    models/layers.py) compiles and matches the dense reference — no forward
    loop was edited to support it."""
    from repro.models.layers import ACT, FFNSpec

    rng = np.random.default_rng(23)
    layers = [FFNSpec("ffn", 24, 32, 16, act="silu"), FCSpec("head", 16, 10)]
    params = {
        "ffn": {
            "wg": jnp.asarray(rng.standard_normal((24, 32)).astype(np.float32) * 0.2),
            "wu": jnp.asarray(rng.standard_normal((24, 32)).astype(np.float32) * 0.2),
            "wd": jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32) * 0.2),
            "b": jnp.asarray(rng.standard_normal((16,)).astype(np.float32) * 0.1),
        },
        "head": {
            "w": jnp.asarray(rng.standard_normal((16, 10)).astype(np.float32) * 0.2),
            "b": jnp.asarray(np.zeros(10, np.float32)),
        },
    }
    x = jnp.asarray(rng.standard_normal((3, 24)).astype(np.float32))
    prog = phantom.compile(layers, params, phantom.PhantomConfig(enabled=True, block=(8, 8, 8)), batch=3)
    y = prog(x, interpret=True)

    import jax as _jax

    p = params["ffn"]
    h = ACT["silu"](x @ p["wg"]) * (x @ p["wu"])
    ref = _jax.nn.relu(h @ p["wd"] + p["b"])  # non-last layer gets the relu epilogue
    ref = ref @ params["head"]["w"] + params["head"]["b"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4, rtol=1e-3)
    assert prog.stats(3)["ffn"]["kind"] == "ffn"


def test_save_load_preserves_spec_field_types(tmp_path):
    """Regression (load coerced every list-valued spec field to a tuple): a
    registered spec with a genuinely list-typed field round-trips with equal
    *types* — list stays list, tuple-annotated fields still come back as
    tuples."""
    import dataclasses

    from repro.program import register_layer_kind
    from repro.program.plans import FCKind

    @dataclasses.dataclass(frozen=True)
    class TaggedFCSpec(FCSpec):
        tags: list = dataclasses.field(default_factory=list)

    register_layer_kind(TaggedFCSpec, FCKind())
    rng = np.random.default_rng(37)
    layers = [
        ConvSpec("c1", 3, 16, 8, 8, 3, 3, (1, 1)),
        TaggedFCSpec("fc", 16, 10, pool="gap", tags=["serving", "v2"]),
    ]
    params = _rand_params(rng, layers)
    prog = phantom.compile(layers, params, CFG, batch=1)
    prog.save(str(tmp_path / "prog"))
    q = phantom.PhantomProgram.load(str(tmp_path / "prog"))
    conv, fc = q.layers
    assert type(fc) is TaggedFCSpec and fc == layers[1]
    assert isinstance(fc.tags, list) and fc.tags == ["serving", "v2"]
    assert isinstance(conv.stride, tuple) and conv == layers[0]
    x = jnp.asarray(rng.standard_normal((1, 8, 8, 3)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(q(x, interpret=True)), np.asarray(prog(x, interpret=True))
    )


def test_save_is_identical_with_recorder_attached(tmp_path):
    """A Recorder is a runtime-only sink (DESIGN.md §11): attaching one —
    even with runtime accounting on, after real calls — changes nothing the
    program persists.  Saved manifests and every array payload are identical
    to the recorder-free program's, the reloaded program has no recorder,
    and stats(sample=...) is unchanged."""
    from repro.obs import Recorder

    rng = np.random.default_rng(41)
    layers, params = toy_cnn(rng)
    cfg = phantom.PhantomConfig(enabled=True, block=BLK, lookahead=4)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    plain = phantom.compile(layers, params, cfg, batch=2)
    rec = Recorder(runtime=True)
    recd = phantom.compile(layers, params, cfg, batch=2, recorder=rec)
    y = np.asarray(plain(x, interpret=True))
    np.testing.assert_array_equal(np.asarray(recd(x, interpret=True)), y)
    assert rec.events  # the recorder did observe the call...
    plain.save(str(tmp_path / "plain"))
    recd.save(str(tmp_path / "recd"))
    # ...but the persisted artifacts are identical, bit for bit.
    dirs = {}
    for name in ("plain", "recd"):
        (step_dir,) = [
            p for p in (tmp_path / name).iterdir() if p.name.startswith("step_")
        ]
        dirs[name] = step_dir
    a, b = dirs["plain"], dirs["recd"]
    assert sorted(p.name for p in a.iterdir()) == sorted(p.name for p in b.iterdir())
    import json as _json

    ma = _json.loads((a / "manifest.json").read_text())
    mb = _json.loads((b / "manifest.json").read_text())
    ma.pop("time"), mb.pop("time")  # wall-clock stamp is the only delta
    assert ma == mb
    with np.load(a / "arrays.npz") as za, np.load(b / "arrays.npz") as zb:
        assert sorted(za.files) == sorted(zb.files)
        for k in za.files:
            np.testing.assert_array_equal(za[k], zb[k])
    # round-trip: loaded program carries no recorder, runs bit-identically,
    # and the runtime accounting (stats with a sample) is unchanged
    loaded = phantom.PhantomProgram.load(str(tmp_path / "recd"))
    assert loaded.recorder is None and loaded.lowerings == 0
    np.testing.assert_array_equal(np.asarray(loaded(x, interpret=True)), y)
    st_plain = plain.stats(sample=x, interpret=True)
    st_loaded = loaded.stats(sample=x, interpret=True)
    assert st_plain == st_loaded


def test_serve_engine_threads_program_to_model():
    """ServeEngine passes the program to models whose decode_step opts in."""
    import jax

    from repro.serve import ServeEngine

    seen = {}

    class FakeModel:
        def init_cache(self, b, max_len):
            return {"kv": jnp.zeros((1, b, max_len))}

        def decode_step(self, params, cache, tokens, index, *, program=None):
            seen["program"] = program
            logits = jnp.zeros((tokens.shape[0], 1, 4)).at[:, 0, 1].set(1.0)
            return logits, cache

    rng = np.random.default_rng(0)
    layers, params = toy_cnn(rng)
    prog = phantom.compile(layers, params, CFG, batch=1)
    eng = ServeEngine(FakeModel(), {}, batch_size=1, max_len=8, program=prog)
    assert eng.program is prog
    req = eng.submit([1, 2], max_new_tokens=1)
    eng.run()
    assert req.done and seen["program"] is prog
