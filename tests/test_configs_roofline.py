"""Config-registry exactness (deliverable f) + roofline parser units."""
import jax
import pytest

from repro import configs, roofline
from repro.configs import shapes as shp

# Exact published numbers from the assignment table.
EXPECT = {
    "qwen2_vl_7b": dict(n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
                        d_ff=18944, vocab=152064),
    "zamba2_2p7b": dict(n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
                        d_ff=10240, vocab=32000, ssm_state=64),
    "deepseek_coder_33b": dict(n_layers=62, d_model=7168, n_heads=56,
                               n_kv_heads=8, d_ff=19200, vocab=32256),
    "qwen2_0p5b": dict(n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
                       d_ff=4864, vocab=151936, qkv_bias=True),
    "smollm_360m": dict(n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
                        d_ff=2560, vocab=49152),
    "internlm2_20b": dict(n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
                          d_ff=16384, vocab=92544),
    "seamless_m4t_medium": dict(n_layers=12, enc_layers=12, d_model=1024,
                                n_heads=16, n_kv_heads=16, d_ff=4096,
                                vocab=256206),
    "moonshot_v1_16b_a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                n_kv_heads=16, moe_d_ff=1408, vocab=163840,
                                n_experts=64, top_k=6),
    "grok_1_314b": dict(n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
                        d_ff=32768, vocab=131072, n_experts=8, top_k=2),
    "mamba2_2p7b": dict(n_layers=64, d_model=2560, vocab=50280, ssm_state=128),
}


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_exact_published_config(arch):
    cfg = configs.get_config(arch)
    for k, v in EXPECT[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_match_model_size():
    """N within ~20% of the advertised size (dense/MoE bookkeeping sanity)."""
    approx = {
        "qwen2_0p5b": 0.5e9, "smollm_360m": 0.36e9, "internlm2_20b": 20e9,
        "deepseek_coder_33b": 33e9, "grok_1_314b": 314e9,
        # the ASSIGNED moonshot numbers (64e × d_ff 1408 × 48L) imply ~28B
        # total; the A3B active count is what must match (below)
        "moonshot_v1_16b_a3b": 28e9, "mamba2_2p7b": 2.7e9,
        "zamba2_2p7b": 2.7e9, "qwen2_vl_7b": 7e9,
    }
    for arch, n in approx.items():
        got = configs.get_config(arch).param_count()
        assert 0.7 * n < got < 1.45 * n, (arch, got, n)
    # MoE active params: moonshot 16B-A3B ⇒ ~3B active.
    a3b = configs.get_config("moonshot_v1_16b_a3b").active_param_count()
    assert 1.8e9 < a3b < 4.5e9, a3b


def test_shape_grid_matches_assignment():
    grids = {a: configs.shape_grid(a) for a in configs.ARCHS}
    # long_500k only for the sub-quadratic families
    assert grids["zamba2_2p7b"][-1] == "long_500k"
    assert grids["mamba2_2p7b"][-1] == "long_500k"
    for a in configs.ARCHS:
        if a not in ("zamba2_2p7b", "mamba2_2p7b"):
            assert "long_500k" not in grids[a]
    assert sum(len(g) for g in grids.values()) == 32  # the dry-run cell count


@pytest.mark.parametrize("arch", ["qwen2_0p5b", "seamless_m4t_medium", "mamba2_2p7b"])
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_input_specs_are_abstract(arch, shape):
    cfg = configs.get_config(arch)
    specs = shp.input_specs(cfg, shape)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)  # no allocation
    sp = shp.SHAPES[shape]
    if sp.kind != "decode":
        assert specs["tokens"].shape == (sp.global_batch, sp.seq_len)
    else:
        assert specs["tokens"].shape == (sp.global_batch, 1)


def test_collective_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128] %x), dimensions={0}
  %ar = f32[896]{0} all-reduce(f32[896]{0} %y), to_apply=%add
  %rs = (f32[4,4]{1,0}, f32[4,4]{1,0}) reduce-scatter(f32[16,4] %a, f32[16,4] %b)
  %cp = u8[100]{0} collective-permute(u8[100]{0} %z)
  %ard = f32[2]{0} all-reduce-done(f32[2]{0} %w)
  %ignored = f32[9]{0} add(f32[9]{0} %p, f32[9]{0} %q)
"""
    out = roofline.collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 896 * 4  # -done not double counted
    assert out["reduce-scatter"] == 2 * 16 * 4
    assert out["collective-permute"] == 100
    assert out["all-to-all"] == 0


def test_model_flops_kinds():
    cfg = configs.get_config("smollm_360m")
    tr = roofline.model_flops(cfg, shp.SHAPES["train_4k"])
    pf = roofline.model_flops(cfg, shp.SHAPES["prefill_32k"])
    dc = roofline.model_flops(cfg, shp.SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert tr == 6.0 * n * 4096 * 256
    assert pf == 2.0 * n * 32768 * 32
    assert dc == 2.0 * n * 128
