"""Integration: training loop learns, checkpoint kill→resume is bit-exact,
serving engine with continuous batching, Phantom serving path."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.core.phantom_linear import PhantomConfig
from repro.data import DataConfig, SyntheticTokens
from repro.models.registry import build
from repro.serve import ServeEngine
from repro.train import TrainConfig, Trainer


def _smoke_trainer(tmp=None, steps=8, arch="smollm_360m", micro=1):
    cfg = configs.get_smoke(arch)
    model = build(cfg)
    data = SyntheticTokens(
        DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, noise=0.01)
    )
    ocfg = optim.AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=200)
    return cfg, Trainer(
        model, data, ocfg, TrainConfig(micro_batches=micro, ckpt_every=4),
        ckpt_dir=tmp,
    )


@pytest.mark.slow
def test_training_reduces_loss():
    cfg, tr = _smoke_trainer(steps=60)
    p, o = tr.init_state()
    p, o = tr.run(p, o, 60)
    first = np.mean([h["loss"] for h in tr.history[:3]])
    last = np.mean([h["loss"] for h in tr.history[-3:]])
    assert last < first - 0.05, (first, last)


@pytest.mark.slow
def test_grad_accum_matches_single_batch():
    cfg = configs.get_smoke("smollm_360m")
    cfg = dataclasses.replace(cfg, act_dtype="float32", param_dtype="float32")
    model = build(cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(0), (8, 32), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab),
    }
    params = model.init(jax.random.PRNGKey(2))
    from repro.train.trainer import make_train_step

    ocfg = optim.AdamWConfig(lr=1e-3)
    s1 = make_train_step(model, ocfg, TrainConfig(micro_batches=1))
    s4 = make_train_step(model, ocfg, TrainConfig(micro_batches=4))
    # train steps donate params/opt — give each call its own copies
    import copy as _copy

    pa = jax.tree.map(jnp.copy, params)
    pb = jax.tree.map(jnp.copy, params)
    p1, _, m1 = s1(pa, optim.init_opt_state(pa), batch)
    p4, _, m4 = s4(pb, optim.init_opt_state(pb), batch)
    d = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4))
    )
    assert d < 1e-5, d


@pytest.mark.slow
def test_kill_and_resume_is_deterministic():
    with tempfile.TemporaryDirectory() as tmp:
        # Uninterrupted 8-step run.
        _, tr_ref = _smoke_trainer()
        p, o = tr_ref.init_state()
        p_ref, _ = tr_ref.run(p, o, 8)
        # Interrupted: 4 steps (checkpoint), new trainer resumes 4 more.
        _, tr_a = _smoke_trainer(tmp=tmp)
        p, o = tr_a.init_state()
        p, o = tr_a.run(p, o, 4)
        _, tr_b = _smoke_trainer(tmp=tmp)
        p0, o0 = tr_b.init_state()
        p0, o0 = tr_b.maybe_restore(p0, o0)
        assert tr_b.start_step == 4
        p_res, _ = tr_b.run(p0, o0, 4)
        d = max(
            float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
            for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_res))
        )
        assert d < 1e-5, d


def test_serving_continuous_batching():
    cfg = configs.get_smoke("qwen2_0p5b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=3, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [
        eng.submit(rng.integers(0, cfg.vocab, size=n).tolist(), max_new_tokens=5)
        for n in (4, 9, 6, 3, 7)  # more requests than slots
    ]
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 5 for r in done)


def test_phantom_serving_matches_masked_dense():
    """The masked phantom path must equal dense matmul with pruned weights."""
    cfg = dataclasses.replace(
        configs.get_smoke("smollm_360m"),
        phantom=PhantomConfig(enabled=True, mode="masked", block=(8, 8, 8),
                              weight_density=0.5),
        act_dtype="float32", param_dtype="float32",
    )
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.launch.serve import phantomize

    params = phantomize(model, params, 0.5)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits = model.forward(params, {"tokens": toks})
    assert bool(jnp.isfinite(logits).all())
    # Dense model with pre-multiplied weights gives identical logits.
    cfg_d = dataclasses.replace(cfg, phantom=PhantomConfig(enabled=False))
    model_d = build(cfg_d)
    import copy

    def premul(p):
        if isinstance(p, dict):
            if "wmask" in p and "w" in p:
                p = dict(p)
                p["w"] = p["w"] * p["wmask"]
                p.pop("wmask")
                return {k: premul(v) for k, v in p.items()}
            return {k: premul(v) for k, v in p.items()}
        return p

    logits_d = model_d.forward(premul(params), {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(logits_d), atol=1e-5, rtol=1e-5
    )
