"""Autotuning subsystem (DESIGN.md §12): search, cost model, cache, compile.

The contracts under test:

* **candidate space** — the base config is always candidate 0, overrides
  are minimal diffs, structural pruning never drops a distinct schedule;
* **cost model exactness** — predicted queue/executed steps equal the real
  prepared plan's (same queue builders), which is what makes the
  never-worse guarantee provable rather than statistical;
* **cache keying** — hits on identical geometry, misses (not stale hits)
  on density-bucket / backend changes, full invalidation on a schema bump;
* **compile integration** — ``tune="cached"`` with a warm cache performs
  zero searches and compiles *bit-identically* to passing the same
  overrides explicitly; programs with overrides save/load/serve
  bit-identically;
* **never-worse acceptance** — on the skewed bench layer set the tuned
  executed makespan is ≤ the default on every layer, < on at least one
  (asserted inside ``kernel_bench.autotune_rows``, exercised here).
"""
import json
import os

import numpy as np
import pytest

import phantom
from repro.core.dataflow import ConvSpec, FCSpec
from repro.core.phantom_linear import PhantomConfig
from repro.core.sparsity import block_prune
from repro.kernels import ops
from repro.tune import (
    BENCH_SPACE,
    DEFAULT_SPACE,
    TUNE_SCHEMA,
    SearchSpace,
    TuneCache,
    candidate_cost,
    candidates,
    density_bucket,
    search_layer,
    synth_act_bits,
    tune_overrides,
)

CFG = PhantomConfig(enabled=True, block=(16, 16, 16))
SPEC = ConvSpec("c1", in_ch=16, out_ch=64, in_h=14, in_w=14, kh=3, kw=3)


def pruned_w(shape, density, rng, block=(16, 16)):
    w = rng.standard_normal(shape).astype(np.float32)
    w2 = w.reshape(-1, shape[-1])
    return (w2 * block_prune(w2, density, block)).reshape(shape)


@pytest.fixture()
def conv_params():
    return {"w": pruned_w((3, 3, 16, 64), 0.3, np.random.default_rng(0))}


# -- candidate space ----------------------------------------------------------


def test_candidates_base_config_is_always_first():
    for base in (CFG, CFG.with_overrides(cores=4, lookahead=8)):
        cands = candidates(SPEC, base, DEFAULT_SPACE)
        assert cands[0] == {}  # the never-worse anchor
        assert len(cands) == len({json.dumps(c, sort_keys=True) for c in cands})


def test_candidates_overrides_are_minimal_diffs():
    base = CFG.with_overrides(cores=2)
    for ov in candidates(SPEC, base, DEFAULT_SPACE):
        eff = base.with_overrides(**ov)
        for field, val in ov.items():
            assert getattr(eff, field) == val
            assert getattr(base, field) != val  # diff fields only


def test_candidates_prunes_impossible_and_degenerate():
    # 64 out_ch / bn=16 → nt=4: cores=8 impossible; cores=1 balance variants
    # cannot differ from the base.
    space = SearchSpace(cores=(1, 8), balance=("none", "inter", "full"),
                        lookahead=None, conv_mode=None)
    cands = candidates(SPEC, CFG, space)
    assert cands == [{}]
    # FC specs never get conv_mode overrides
    fc = FCSpec("f", 64, 64)
    assert all("conv_mode" not in ov
               for ov in candidates(fc, CFG, DEFAULT_SPACE))


def test_with_overrides_validates_fields():
    assert CFG.with_overrides() is CFG
    assert CFG.with_overrides(block=[32, 32, 32]).block == (32, 32, 32)
    with pytest.raises(ValueError, match="unknown PhantomConfig override"):
        CFG.with_overrides(corez=4)


# -- cost model ---------------------------------------------------------------


def test_cost_model_matches_real_plan_steps(conv_params):
    """The pre-filter shares the real queue builders: predicted queue steps
    equal the prepared plan's for both lowerings, single- and multi-core."""
    from repro.kernels import phantom_conv

    for ov in ({}, {"conv_mode": "im2col"}, {"cores": 2}, {"cores": 4}):
        cfg = CFG.with_overrides(**ov)
        m = candidate_cost(SPEC, conv_params["w"], 1, cfg)
        pcw = phantom_conv.prepare_conv_weight(
            np.asarray(conv_params["w"]), batch=1, in_hw=(14, 14), config=cfg
        )
        art = pcw.pw if pcw.pw is not None else pcw.plan
        # single core: the queue length; multi-core: the per-core max (the
        # §4.6 lock-step makespan), while plan.steps sums across cores.
        real = (int(art.core_steps.max()) if getattr(art, "cores", 1) > 1
                else pcw.steps)
        assert m["queue_steps"] == real, ov
        # Dense activations: every queue step executes.
        assert m["executed_makespan"] == real, ov


def test_cost_model_lookahead_reduces_executed_steps(conv_params):
    dense = candidate_cost(SPEC, conv_params["w"], 1, CFG, act_density=0.5)
    la = candidate_cost(
        SPEC, conv_params["w"], 1, CFG.with_overrides(lookahead=8),
        act_density=0.5,
    )
    assert la["executed_makespan"] < dense["executed_makespan"]
    assert la["queue_steps"] == dense["queue_steps"]


def test_synth_act_bits_density_and_determinism():
    bits = synth_act_bits(8, 16, 0.5)
    assert bits.shape == (8, 16)
    assert abs(bits.mean() - 0.5) < 0.02  # low-discrepancy ≈ exact
    np.testing.assert_array_equal(bits, synth_act_bits(8, 16, 0.5))
    assert synth_act_bits(4, 4, 1.0).all()


def test_cost_artifact_rejects_cores_exceeding_columns(conv_params):
    with pytest.raises(ValueError, match="cores"):
        candidate_cost(SPEC, conv_params["w"], 1, CFG.with_overrides(cores=8))


# -- search -------------------------------------------------------------------


def test_search_never_worse_and_improves_skewed_fc():
    # The §4.2 skewed layer: heavy column every 4th position — a 4-core
    # balanced schedule beats the single-core default ~4x.
    rng = np.random.default_rng(0)
    kt, nt, bk, bn = 12, 8, 16, 16
    w = np.zeros((kt * bk, nt * bn), np.float32)
    for c in range(nt):
        kept = kt if c % 4 == 0 else 1
        w[: kept * bk, c * bn : (c + 1) * bn] = rng.standard_normal(
            (kept * bk, bn)
        ).astype(np.float32)
    spec = FCSpec("skew", kt * bk, nt * bn)
    res = search_layer(spec, {"w": w}, 16, CFG, space=BENCH_SPACE)
    assert res.best["cost"] <= res.default["cost"]
    assert res.best["executed_makespan"] < res.default["executed_makespan"]
    assert res.override.get("cores", 1) > 1
    # candidate 0 of the trial list is the default config
    assert res.trials[0].override == {} or res.default["cost"] >= min(
        t.metrics["cost"] for t in res.trials
    )


def test_bench_layer_set_never_worse():
    """The BENCH_conv.json acceptance row, executed directly: tuned
    executed makespan ≤ default on every layer, < on at least one (the
    asserts live inside autotune_rows)."""
    from benchmarks import kernel_bench

    _, result = kernel_bench.autotune_rows(np.random.default_rng(0))
    assert result["layers_improved"] >= 1
    assert result["tuned_cost"] <= result["default_cost"]
    for name, r in result["layers"].items():
        assert r["tuned_makespan"] <= r["default_makespan"], name


# -- cache --------------------------------------------------------------------


def test_cache_hit_miss_and_persistence(tmp_path, conv_params):
    path = str(tmp_path / "tc.json")
    cache = TuneCache(path, backend="cpu:test:jax0")
    key = cache.key_for(SPEC, 1, CFG, w_density=0.3)
    assert cache.get(key) is None and cache.misses == 1
    cache.put(key, {"cores": 4}, cost=1.0)
    assert cache.get(key)["override"] == {"cores": 4} and cache.hits == 1
    cache.save()
    warm = TuneCache(path, backend="cpu:test:jax0")
    assert len(warm) == 1
    assert warm.get(key)["override"] == {"cores": 4}


def test_cache_schema_bump_invalidates(tmp_path):
    path = str(tmp_path / "tc.json")
    cache = TuneCache(path, backend="b")
    cache.put("k", {"cores": 2})
    cache.save()
    stale = TuneCache(path, schema=TUNE_SCHEMA + 1, backend="b")
    assert len(stale) == 0 and stale.invalidations == 1
    assert stale.get("k") is None  # re-search, never trust old semantics
    # an unreadable file is treated exactly like a schema mismatch
    with open(path, "w") as f:
        f.write("{not json")
    broken = TuneCache(path, backend="b")
    assert len(broken) == 0 and broken.invalidations == 1


def test_cache_key_scopes_backend_and_density_bucket(conv_params):
    a = TuneCache("unused.json", backend="cpu:A:jax1")
    b = TuneCache("unused.json", backend="tpu:B:jax1")
    ka = a.key_for(SPEC, 1, CFG, w_density=0.25)
    assert ka != b.key_for(SPEC, 1, CFG, w_density=0.25)  # backend change
    # same density bucket → same key; crossing a bucket edge → miss
    assert ka == a.key_for(SPEC, 1, CFG, w_density=0.27)
    assert ka != a.key_for(SPEC, 1, CFG, w_density=0.5)
    assert density_bucket(0.25) == density_bucket(0.27) == "d0.2-0.3"
    assert density_bucket(0.5) == "d0.45-0.6"
    # batch and non-searched base knobs are part of the signature...
    assert ka != a.key_for(SPEC, 2, CFG, w_density=0.25)
    tau = CFG.with_overrides(act_threshold=0.1)
    assert ka != a.key_for(SPEC, 1, tau, w_density=0.25)
    # ...but searched fields are not: a base with different cores finds the
    # same entry (the stored override supersedes them anyway).
    assert ka == a.key_for(SPEC, 1, CFG.with_overrides(cores=4), w_density=0.25)


def test_tune_overrides_cached_mode_never_searches(tmp_path, conv_params):
    cache = TuneCache(str(tmp_path / "tc.json"), backend="b")
    got = tune_overrides(
        [SPEC], {"c1": conv_params}, 1, CFG, cache=cache, mode="cached"
    )
    assert got == {} and cache.searches == 0 and cache.misses == 1
    assert not os.path.exists(cache.path)  # nothing searched, nothing saved
    with pytest.raises(ValueError, match="tune mode"):
        tune_overrides([SPEC], {"c1": conv_params}, 1, CFG,
                       cache=cache, mode="bogus")


# -- compile integration ------------------------------------------------------


def toy_net(rng):
    layers = [
        ConvSpec("c1", 8, 32, 14, 14, 3, 3),
        FCSpec("f1", 32 * 7 * 7, 16, pool="pool5"),
    ]
    params = {
        "c1": {
            "w": pruned_w((3, 3, 8, 32), 0.4, rng),
            "b": np.zeros(32, np.float32),
        },
        "f1": {
            "w": pruned_w((32 * 7 * 7, 16), 0.3, rng),
            "b": np.zeros(16, np.float32),
        },
    }
    return layers, params


def test_compile_tune_search_then_cached_is_deterministic(tmp_path):
    """The acceptance chain: search populates the cache; a warm-cache
    ``tune="cached"`` compile performs ZERO searches and is bit-identical
    to compiling with the same overrides passed explicitly."""
    layers, params = toy_net(np.random.default_rng(1))
    path = str(tmp_path / "tc.json")
    x = np.maximum(
        np.random.default_rng(2).standard_normal((2, 14, 14, 8)), 0
    ).astype(np.float32)

    cache = TuneCache(path)
    prog = phantom.compile(layers, params, CFG, batch=2, tune="search",
                           tune_cache=cache)
    assert cache.searches == len(layers) and os.path.exists(path)
    y = np.asarray(prog(x))

    warm = TuneCache(path)
    cached = phantom.compile(layers, params, CFG, batch=2, tune="cached",
                             tune_cache=warm)
    assert warm.searches == 0 and warm.misses == 0
    assert warm.hits == len(layers)
    assert cached.overrides == prog.overrides

    explicit = phantom.compile(layers, params, CFG, batch=2,
                               overrides=prog.overrides)
    for name in ("c1", "f1"):
        assert explicit.effective_cfg(name) == cached.effective_cfg(name)
    np.testing.assert_array_equal(np.asarray(cached(x)), y)
    np.testing.assert_array_equal(np.asarray(explicit(x)), y)


def test_program_with_overrides_saves_loads_serves_bit_identically(tmp_path):
    layers, params = toy_net(np.random.default_rng(3))
    overrides = {"c1": {"cores": 2, "balance": "none", "lookahead": 8}}
    prog = phantom.compile(layers, params, CFG, batch=2, overrides=overrides)
    assert prog.effective_cfg("c1").cores == 2
    assert prog.effective_cfg("f1") == CFG
    assert prog.stats(2)["c1"]["override"] == overrides["c1"]
    x = np.maximum(
        np.random.default_rng(4).standard_normal((2, 14, 14, 8)), 0
    ).astype(np.float32)
    y = np.asarray(prog(x))

    path = str(tmp_path / "prog")
    prog.save(path)
    loaded = phantom.PhantomProgram.load(path)
    assert loaded.lowerings == 0
    assert loaded.overrides == prog.overrides
    np.testing.assert_array_equal(np.asarray(loaded(x)), y)
    # a NEW batch size lowers with the per-layer configs, not the base
    assert loaded.effective_cfg("c1").cores == 2
    y3 = loaded(x[:1])
    assert np.asarray(y3).shape == (1, 16)


def test_override_outputs_match_default_config_outputs(tmp_path):
    """Scheduling knobs are numerics-preserving: a multi-core + lookahead
    override computes bit-identical outputs to the default schedule."""
    layers, params = toy_net(np.random.default_rng(5))
    x = np.maximum(
        np.random.default_rng(6).standard_normal((2, 14, 14, 8)), 0
    ).astype(np.float32)
    base = phantom.compile(layers, params, CFG, batch=2)
    tuned = phantom.compile(
        layers, params, CFG, batch=2,
        overrides={"c1": {"cores": 2, "lookahead": 8},
                   "f1": {"cores": 2, "balance": "none"}},
    )
    np.testing.assert_array_equal(np.asarray(base(x)), np.asarray(tuned(x)))


def test_compile_rejects_bad_tune_args():
    layers, params = toy_net(np.random.default_rng(7))
    with pytest.raises(ValueError, match="tune must be"):
        phantom.compile(layers, params, CFG, batch=1, tune="always")
    with pytest.raises(KeyError, match="unknown layer"):
        phantom.compile(layers, params, CFG, batch=1,
                        overrides={"nope": {"cores": 2}})
    with pytest.raises(ValueError, match="unknown PhantomConfig override"):
        phantom.compile(layers, params, CFG, batch=1,
                        overrides={"c1": {"corez": 2}})
