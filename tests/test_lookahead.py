"""Runtime lookahead compaction (DESIGN.md §10): activation-dead steps are
squeezed out of the executed grid without changing a single output bit.

The contract under test:

* **bit-identity** — compaction is a pure schedule transformation: for every
  ``{fc, direct conv, im2col conv} × cores × activation pattern × lookahead``
  cell, the compacted output equals the gated (``lookahead=0``) oracle bit
  for bit — including the all-zero-activation edge case, where every
  surviving step is a §3.8 zero-writer;
* **engine↔simulator consistency** — the kernel's traced grid bound (the
  compacted kept-entry count) equals :func:`repro.core.tds.batch_cycles`
  with ``threads=1, policy="inorder"`` on the same per-segment popcounts,
  per core, and :func:`repro.kernels.ops.lookahead_stats` reports exactly
  that number (the DESIGN.md §5 contract extended to runtime compaction);
* **program surface** — ``PhantomConfig(lookahead=...)`` flows through
  ``phantom.compile`` → plans → save/load, and
  ``program.stats(sample=...)`` exposes the executed-step accounting.
"""
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import toy_cnn

from repro.core import sparsity, tds
from repro.core.phantom_linear import PhantomConfig
from repro.kernels import compaction, ops
from repro.kernels import phantom_conv as pc
from repro.program.program import PhantomProgram, compile as phantom_compile

BLK = (8, 8, 8)


def _pruned_fc(rng, k=96, n=80, density=0.4):
    w = rng.standard_normal((k, n)).astype(np.float32)
    w *= sparsity.block_prune(w, density, BLK[1:])
    return w


def _pruned_conv(rng, cin=8, cout=16, kh=3, density=0.4):
    w = rng.standard_normal((kh, kh, cin, cout)).astype(np.float32)
    w2 = w.reshape(-1, cout)
    w2 *= sparsity.block_prune(w2, density, BLK[1:])
    return w2.reshape(w.shape)


def _acts(rng, shape, pattern):
    x = rng.standard_normal(shape).astype(np.float32)
    if pattern == "zero":
        return np.zeros(shape, np.float32)
    if pattern == "half":  # ~50% of tiles activation-dead
        x *= rng.random(shape) < 0.35
        x[..., shape[-1] // 2 :] = 0.0
        return x
    return x  # "live"


# -- bit-identity grid --------------------------------------------------------


@pytest.mark.parametrize("cores", [1, 2])
@pytest.mark.parametrize("pattern", ["half", "zero", "live"])
@pytest.mark.parametrize("la", [2, 64])
def test_fc_compaction_parity(cores, pattern, la):
    rng = np.random.default_rng(0)
    w = _pruned_fc(rng)
    x = jnp.asarray(_acts(rng, (24, w.shape[0]), pattern))
    pw0 = ops.prepare_weight(w, m=24, block=BLK, cores=cores)
    pwl = ops.prepare_weight(w, m=24, block=BLK, cores=cores, lookahead=la)
    ref = np.asarray(ops.phantom_matmul(x, pw0, interpret=True))
    got = np.asarray(ops.phantom_matmul(x, pwl, interpret=True))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("cores", [1, 2])
@pytest.mark.parametrize("mode", ["direct", "im2col"])
@pytest.mark.parametrize("pattern", ["half", "zero"])
def test_conv_compaction_parity(cores, mode, pattern):
    rng = np.random.default_rng(1)
    w = _pruned_conv(rng)
    x = jnp.asarray(_acts(rng, (2, 6, 6, 8), pattern))
    kw = dict(batch=2, in_hw=(6, 6), block=BLK, mode=mode, cores=cores)
    p0 = pc.prepare_conv_weight(w, **kw)
    pl = pc.prepare_conv_weight(w, **kw, lookahead=4)
    ref = np.asarray(pc.phantom_conv_call(x, p0, interpret=True))
    got = np.asarray(pc.phantom_conv_call(x, pl, interpret=True))
    np.testing.assert_array_equal(got, ref)


def test_fused_linear_act_compaction_parity():
    rng = np.random.default_rng(2)
    w = _pruned_fc(rng)
    x = jnp.asarray(_acts(rng, (24, w.shape[0]), "half"))
    pw0 = ops.prepare_weight(w, m=24, block=BLK)
    pwl = ops.prepare_weight(w, m=24, block=BLK, lookahead=8)
    y0, m0 = ops.phantom_linear_act(x, pw0, activation="relu", interpret=True)
    yl, ml = ops.phantom_linear_act(x, pwl, activation="relu", interpret=True)
    np.testing.assert_array_equal(np.asarray(yl), np.asarray(y0))
    np.testing.assert_array_equal(np.asarray(ml), np.asarray(m0))


# -- engine↔simulator consistency --------------------------------------------


def _tds_executed(pw, bits, la):
    """Independent per-core cycle counts straight from
    :func:`repro.core.tds.batch_cycles` on the queue's segment popcounts."""
    bits = np.asarray(bits).reshape(-1)
    fa = np.atleast_2d(np.asarray(pw.flat_ak))
    va = np.atleast_2d(np.asarray(pw.valid))
    st = np.atleast_2d(np.asarray(pw.start))
    reals = (
        np.asarray(pw.core_steps)
        if getattr(pw, "cores", 1) > 1
        else np.full(fa.shape[0], fa.shape[1])
    )
    out = []
    for r in range(fa.shape[0]):
        real = int(reals[r])
        a = (bits[fa[r, :real]] * va[r, :real]).astype(np.int32)
        starts = np.flatnonzero(st[r, :real] == 1)
        segs = np.split(a, starts[1:]) if len(starts) else [a]
        lengths = np.asarray([len(s) for s in segs])
        pops = np.zeros((len(segs), int(lengths.max())), np.int32)
        for i, s in enumerate(segs):
            pops[i, : len(s)] = s
        cyc = tds.batch_cycles(pops, lengths, lookahead=la, threads=1, policy="inorder")
        out.append(int(cyc.sum()))
    return out


@pytest.mark.parametrize("cores", [1, 2])
@pytest.mark.parametrize("la", [1, 4])
def test_compacted_count_matches_tds(cores, la):
    rng = np.random.default_rng(3)
    w = _pruned_fc(rng)
    x = jnp.asarray(_acts(rng, (24, w.shape[0]), "half"))
    pw = ops.prepare_weight(w, m=24, block=BLK, cores=cores, lookahead=la)
    bits = ops.activation_tile_bits(ops._pad2(x, BLK[0], BLK[1]), BLK[:2])
    abit = (
        bits.reshape(-1)[jnp.asarray(pw.flat_ak)] * jnp.asarray(pw.valid)
    ).astype(jnp.int32)
    fields = dict(mi=pw.mi, ni=pw.ni, ki=pw.ki, wq=pw.wq)
    _, _, _, _, count = ops._compact(fields, pw, abit)
    sim = _tds_executed(pw, bits, la)
    stats = ops.lookahead_stats(pw, bits)
    if cores > 1:
        assert list(np.asarray(count)) == sim
        assert stats["per_core_executed"] == sim
    else:
        assert int(np.asarray(count)) == sim[0]
    assert stats["executed_steps"] == max(sim)
    assert stats["lookahead"] == la
    # utilization: effectual-MAC steps per executed grid slot, computed from
    # the same popcounts the cycle model consumed
    live = sum(
        int((np.asarray(bits).reshape(-1)[np.atleast_2d(pw.flat_ak)[r, :real]]
             * np.atleast_2d(pw.valid)[r, :real]).sum())
        for r, real in enumerate(
            np.asarray(pw.core_steps) if cores > 1
            else [np.atleast_2d(pw.flat_ak).shape[1]]
        )
    )
    slots = cores * stats["executed_steps"]
    assert stats["utilization"] == pytest.approx(live / slots)


def test_compaction_reduces_steps_at_half_density():
    rng = np.random.default_rng(4)
    w = _pruned_fc(rng, density=0.6)
    x = _acts(rng, (24, w.shape[0]), "live")
    x[:, w.shape[0] // 2 :] = 0.0  # kill half the k-tiles exactly
    pw = ops.prepare_weight(w, m=24, block=BLK, lookahead=8)
    bits = ops.activation_tile_bits(ops._pad2(jnp.asarray(x), BLK[0], BLK[1]), BLK[:2])
    st = ops.lookahead_stats(pw, bits)
    assert st["queue_steps"] / st["executed_steps"] >= 1.5, st
    st0 = ops.lookahead_stats(pw, bits, lookahead=0)
    assert st0["executed_steps"] == st0["queue_steps"]  # gated oracle


def test_all_zero_activation_compacts_to_zero_writers():
    rng = np.random.default_rng(5)
    w = _pruned_fc(rng)
    pw = ops.prepare_weight(w, m=24, block=BLK, lookahead=16)
    bits = jnp.zeros((3, 12), jnp.int32)
    st = ops.lookahead_stats(pw, bits)
    # every (mi, ni) segment collapses to ceil(len/L) pacing steps and the
    # executed grid still flushes every output tile (parity test above
    # checks the zeros actually land); utilization is exactly 0
    assert 0 < st["executed_steps"] < st["queue_steps"]
    assert st["utilization"] == 0.0


def test_compaction_meta_and_queue_validate():
    with pytest.raises(ValueError, match="lookahead"):
        ops.prepare_weight(np.ones((8, 8), np.float32), m=8, block=BLK, lookahead=-1)
    with pytest.raises(ValueError, match="lookahead"):
        compaction.compact_queue(
            {}, np.ones(4, np.int32), np.ones(4, np.int32), np.zeros(4, np.int32),
            np.zeros(4, np.int32), np.zeros(4, np.int32), np.zeros(4, bool),
            lookahead=0,
        )


# -- program surface ----------------------------------------------------------


def test_program_lookahead_parity_stats_and_roundtrip():
    rng = np.random.default_rng(6)
    layers, params = toy_cnn(rng)
    x = jnp.asarray(_acts(rng, (2, 8, 8, 3), "half"))
    cfg = dict(enabled=True, block=BLK)
    p0 = phantom_compile(layers, params, PhantomConfig(**cfg), batch=2)
    pl = phantom_compile(layers, params, PhantomConfig(**cfg, lookahead=8), batch=2)
    y0 = np.asarray(p0(x, interpret=True))
    yl = np.asarray(pl(x, interpret=True))
    np.testing.assert_array_equal(yl, y0)

    st = pl.stats(sample=x, interpret=True)
    for name, s in st.items():
        assert s["lookahead"] == 8
        assert 0 < s["executed_steps"] <= s["queue_steps"]
        assert 0.0 <= s["utilization"] <= 1.0
    # static stats alone carry no runtime fields
    assert "executed_steps" not in pl.stats()[layers[0].name]

    with tempfile.TemporaryDirectory() as d:
        path = pl.save(os.path.join(d, "prog"))
        loaded = PhantomProgram.load(path)
        assert loaded.lowerings == 0
        np.testing.assert_array_equal(np.asarray(loaded(x, interpret=True)), y0)
        st2 = loaded.stats(sample=x, interpret=True)
        assert {n: s["executed_steps"] for n, s in st2.items()} == {
            n: s["executed_steps"] for n, s in st.items()
        }


def test_stats_sample_batch_mismatch_raises():
    rng = np.random.default_rng(7)
    layers, params = toy_cnn(rng)
    prog = phantom_compile(
        layers, params, PhantomConfig(enabled=True, block=BLK, lookahead=2), batch=2
    )
    with pytest.raises(ValueError, match="sample batch"):
        prog.stats(sample=jnp.zeros((3, 8, 8, 3)), interpret=True)
