"""Per-kernel shape/dtype sweeps vs the pure-jnp oracle (interpret mode)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparsity
from repro.kernels import ops, ref

CASES = [
    # (M, K, N, (bm, bk, bn), w_density)
    (64, 128, 96, (32, 32, 32), 0.3),
    (128, 256, 128, (64, 64, 64), 0.15),
    (100, 200, 60, (32, 64, 32), 0.5),  # ragged shapes
    (32, 32, 32, (32, 32, 32), 0.0),  # fully pruned weight
    (48, 64, 64, (16, 32, 64), 1.0),  # dense weight
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(mkn, blk, wd, dtype, seed=0):
    m, k, n = mkn
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((k, n)).astype(np.float32)
    if wd < 1.0:
        w = w * sparsity.block_prune(w, wd, blk[1:])
    x = rng.standard_normal((m, k)).astype(np.float32)
    x[: blk[0], : blk[1]] = 0.0  # force a zero activation tile
    return jnp.asarray(x, dtype), np.asarray(w, np.float32)


@pytest.mark.parametrize("case", CASES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_phantom_spmm_vs_ref(case, dtype):
    m, k, n, blk, wd = case
    x, w = _mk((m, k, n), blk, wd, dtype)
    pw = ops.prepare_weight(w, m=m, block=blk, dtype=dtype)
    y = ops.phantom_matmul(x, pw, interpret=True, out_dtype=jnp.float32)
    mt, kt = math.ceil(m / blk[0]), math.ceil(k / blk[1])
    xp = jnp.zeros((mt * blk[0], kt * blk[1]), x.dtype).at[:m, :k].set(x)
    ab = ref.ref_activation_block_mask(xp, (blk[0], blk[1]))
    yref = ref.ref_phantom_spmm(x, jnp.asarray(w, dtype), jnp.asarray(pw.w_bmask), ab, blk,
                                out_dtype=jnp.float32)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2 * max(1.0, float(jnp.abs(yref).max()))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=tol, rtol=1e-2)


@pytest.mark.parametrize("activation", ["none", "relu", "silu", "gelu"])
def test_phantom_linear_act_vs_ref(activation):
    m, k, n, blk, wd = 64, 128, 96, (32, 32, 32), 0.4
    x, w = _mk((m, k, n), blk, wd, jnp.float32, seed=3)
    pw = ops.prepare_weight(w, m=m, block=blk)
    y, ymask = ops.phantom_linear_act(x, pw, activation=activation, interpret=True)
    mt, kt = math.ceil(m / blk[0]), math.ceil(k / blk[1])
    xp = jnp.zeros((mt * blk[0], kt * blk[1])).at[:m, :k].set(x)
    ab = ref.ref_activation_block_mask(xp, (blk[0], blk[1]))
    yref, ymref = ref.ref_phantom_linear_act(
        x, jnp.asarray(w), jnp.asarray(pw.w_bmask), ab, blk, activation=activation
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-4, rtol=1e-3)
    assert (np.asarray(ymask).astype(bool) == np.asarray(ymref)).all()


def test_queue_compaction_scales_with_density():
    """The TDS analogue: grid steps ∝ weight block density (+ empties)."""
    m = k = n = 256
    blk = (64, 64, 64)
    rng = np.random.default_rng(0)
    w = rng.standard_normal((k, n)).astype(np.float32)
    steps = []
    for wd in (1.0, 0.5, 0.25):
        wp = w * sparsity.block_prune(w, wd, blk[1:]) if wd < 1 else w
        pw = ops.prepare_weight(wp, m=m, block=blk)
        steps.append(pw.steps)
    assert steps[0] > steps[1] > steps[2]
