"""Serving-engine bugfix sweep: input validation, honest exhaustion, and the
batched slot-cache reset — plus the kernel-layer batch-mismatch guard.

Uses a deterministic toy model (next token = prev + 1 mod vocab) so the
engine mechanics are tested without paying for a real transformer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.serve import ServeEngine

VOCAB = 16


class _CountModel:
    """Minimal decode contract: logits one-hot the incremented token, cache
    records the fed token at the slot's index (so resets are observable)."""

    def init_cache(self, batch, max_len):
        return {"k": jnp.zeros((1, batch, max_len, 2), jnp.float32)}

    def decode_step(self, params, cache, tokens, index):
        logits = jax.nn.one_hot((tokens + 1) % VOCAB, VOCAB)
        b = cache["k"].shape[1]
        k = cache["k"].at[0, jnp.arange(b), index, 0].set(
            1.0 + tokens[:, 0].astype(jnp.float32)
        )
        return logits, {"k": k}


def _engine(batch_size=3, max_len=32):
    return ServeEngine(_CountModel(), {}, batch_size=batch_size, max_len=max_len)


def test_empty_prompt_rejected_at_submit():
    eng = _engine()
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([])
    assert not eng.queue  # nothing half-enqueued
    # and a valid request afterwards still serves
    req = eng.submit([3], max_new_tokens=2)
    done = eng.run()
    assert done == [req] and req.output == [4, 5]


def test_run_raises_on_max_steps_exhaustion():
    eng = _engine(batch_size=1)
    r1 = eng.submit([1, 2, 3], max_new_tokens=8)
    r2 = eng.submit([1], max_new_tokens=8)
    with pytest.raises(RuntimeError, match=r"max_steps=2.*incomplete"):
        eng.run(max_steps=2)
    assert not r1.done and not r2.done
    # the engine is still usable: a follow-up run finishes the work
    done = eng.run()
    assert {r.rid for r in done} == {r1.rid, r2.rid}
    assert r1.output == [4, 5, 6, 7, 8, 9, 10, 11]


def test_run_exact_final_step_is_not_an_error():
    eng = _engine(batch_size=1)
    eng.submit([1], max_new_tokens=2)
    # 2 decode steps finish the request; the loop never observes the drain,
    # but nothing is incomplete either — must return, not raise
    done = eng.run(max_steps=2)
    assert len(done) == 1 and done[0].output == [2, 3]


def test_fill_pass_resets_all_slots_in_one_traversal():
    eng = _engine(batch_size=3)
    calls = []
    orig = eng._reset_slot_caches
    eng._reset_slot_caches = lambda slots: (calls.append(list(slots)), orig(slots))[1]
    # dirty every slot's cache so the reset is observable
    eng.cache = jax.tree.map(lambda t: t + 7.0, eng.cache)
    for p in ([1], [2], [3]):
        eng.submit(p, max_new_tokens=1)
    eng._fill_slots()
    assert calls == [[0, 1, 2]]  # one batched reset, not one per slot
    assert float(jnp.abs(eng.cache["k"]).max()) == 0.0


def test_partial_fill_resets_only_freed_slots():
    eng = _engine(batch_size=3)
    eng.cache = jax.tree.map(lambda t: t + 7.0, eng.cache)
    eng.submit([5], max_new_tokens=1)
    eng._fill_slots()
    k = np.asarray(eng.cache["k"])
    assert np.all(k[:, 0] == 0.0)  # filled slot zeroed
    assert np.all(k[:, 1:] == 7.0)  # untouched slots keep their state


def test_continuous_batching_output_unchanged():
    eng = _engine(batch_size=2)
    reqs = [eng.submit([i + 1], max_new_tokens=3) for i in range(5)]
    done = eng.run()
    assert len(done) == 5
    for r in reqs:
        assert r.output == [(r.prompt[0] + j) % VOCAB for j in (1, 2, 3)]


# -- serving metrics (DESIGN.md §11): edge cases on the recorder surface ------


class _Tick:
    """Deterministic engine clock: every read advances by 1 second."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def _recorded_engine(batch_size=3, max_len=32):
    from repro.obs import Recorder

    rec = Recorder(clock=_Tick())
    eng = ServeEngine(
        _CountModel(), {}, batch_size=batch_size, max_len=max_len, recorder=rec
    )
    return eng, rec


def test_single_request_batch_records_latency_and_steps():
    """One request alone in the batch: latency is recorder-clock positive,
    p50 == p95 == p99 (a single sample), steps-per-request equals the decode
    steps the request actually consumed (prefill + generation)."""
    eng, rec = _recorded_engine(batch_size=3)
    req = eng.submit([3], max_new_tokens=2)
    assert rec.counters["serve/submitted"] == 1.0
    assert rec.gauges["serve/queue_depth"] == 1.0
    done = eng.run()
    assert done == [req]
    assert rec.counters["serve/completed"] == 1.0
    (lat,) = rec.hists["serve/request_latency_s"]
    assert lat > 0.0  # clock at completion − clock at submit, both fake
    p = rec.percentiles("serve/request_latency_s")
    assert p["p50"] == p["p95"] == p["p99"] == lat
    # prompt [3] is fed in the same step that generates token 4, then one
    # more step generates token 5: index reached 2
    assert rec.hists["serve/steps_per_request"] == [2.0]
    assert rec.counters["serve/decode_steps"] == 2.0
    # one live slot out of 3 on both steps
    assert rec.hists["serve/slot_occupancy"] == [1 / 3, 1 / 3]
    assert rec.gauges["serve/queue_depth"] == 0.0


def test_empty_prompt_rejection_is_counted():
    eng, rec = _recorded_engine()
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([])
    assert rec.counters["serve/rejected_empty_prompt"] == 1.0
    assert "serve/submitted" not in rec.counters  # rejected ≠ submitted
    eng.submit([1], max_new_tokens=1)
    eng.run()
    assert rec.counters["serve/submitted"] == 1.0
    assert rec.counters["serve/completed"] == 1.0


def test_max_steps_exhaustion_is_counted():
    eng, rec = _recorded_engine(batch_size=1)
    eng.submit([1, 2, 3], max_new_tokens=8)
    eng.submit([1], max_new_tokens=8)
    with pytest.raises(RuntimeError, match="incomplete"):
        eng.run(max_steps=2)
    assert rec.counters["serve/exhausted_runs"] == 1.0
    assert "serve/completed" not in rec.counters
    eng.run()  # finishing the work afterwards does not re-count exhaustion
    assert rec.counters["serve/exhausted_runs"] == 1.0
    assert rec.counters["serve/completed"] == 2.0
    # every recorded latency is positive and the histogram is complete
    assert [v > 0 for v in rec.hists["serve/request_latency_s"]] == [True, True]


def test_cnn_engine_records_batch_spans_and_rejections():
    from conftest import toy_cnn

    import phantom
    from repro.obs import Recorder
    from repro.serve import CnnServeEngine

    rng = np.random.default_rng(43)
    layers, params = toy_cnn(rng)
    prog = phantom.compile(
        layers, params, phantom.PhantomConfig(enabled=True, block=(16, 16, 16)),
        batch=2,
    )
    rec = Recorder(clock=_Tick())
    eng = CnnServeEngine(program=prog, batch_size=2, interpret=True, recorder=rec)
    assert prog.recorder is rec  # engine shares its sink with the program
    with pytest.raises(ValueError, match="expected"):
        eng.submit(np.zeros((4, 4, 3), np.float32))
    assert rec.counters["serve_cnn/rejected_shape"] == 1.0
    imgs = rng.standard_normal((3, 8, 8, 3)).astype(np.float32)
    for im in imgs:
        eng.submit(im)
    eng.run()
    assert rec.counters["serve_cnn/submitted"] == 3.0
    assert rec.counters["serve_cnn/completed"] == 3.0
    assert rec.hists["serve_cnn/slot_occupancy"] == [1.0, 0.5]  # full, then half
    assert all(v > 0 for v in rec.hists["serve_cnn/request_latency_s"])
    # one serve_cnn/batch span per engine step, each wrapping the program's
    # per-layer spans on the same timeline
    batch_spans = [e for e in rec.events if e["name"] == "serve_cnn/batch"]
    assert len(batch_spans) == 2
    assert [e["args"]["live"] for e in batch_spans] == [2, 1]
    layer_spans = [e for e in rec.events if e["name"].startswith("layer/")]
    assert len(layer_spans) == 2 * len(layers)


# -- kernel-layer guard: mismatched batch fails fast --------------------------


def test_phantom_matmul_batch_mismatch_message():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((16, 16)).astype(np.float32)
    pw = ops.prepare_weight(w, m=8, block=(8, 8, 8))
    good = ops.phantom_matmul(jnp.ones((8, 16)), pw, interpret=True)
    assert good.shape == (8, 16)
    with pytest.raises(ValueError, match=r"m-tiles.*at_batch"):
        ops.phantom_matmul(jnp.ones((24, 16)), pw, interpret=True)
    with pytest.raises(ValueError, match=r"m-tiles.*at_batch"):
        ops.phantom_linear_act(jnp.ones((24, 16)), pw, interpret=True)
