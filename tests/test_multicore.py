"""Multi-core (Phantom-2D) execution: partitioned per-core queues through the
real kernel path (DESIGN.md §9).

The contract under test, end to end:

* **bit-identity** — partitioning output tile-columns across cores never
  changes numerics: per-tile k-accumulation order is preserved, stitching is
  a pure column permutation, so every ``cores × balance × lowering`` cell
  matches the single-core output bit for bit;
* **scheduling consistency** — the engine's per-core work (from the actual
  queue artifacts) equals :func:`repro.core.balance.inter_core_schedule` on
  the same per-column costs, for both the balanced (LPT) and naive
  (round-robin) policies — the DESIGN.md §5 engine↔simulator contract
  extended to balancing;
* **balancing pays** — on a skewed-density layer the balanced makespan is
  strictly below the naive round-robin one;
* **program surface** — ``phantom.compile(cfg=PhantomConfig(cores=...))``
  is bit-identical to ``cores=1`` on the toy CNN in both conv modes,
  survives save/load, and serves through ``CnnServeEngine`` unchanged.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import toy_cnn

import phantom
from repro.core import balance, sparsity
from repro.core.blocksparse import partition_columns
from repro.kernels import ops
from repro.kernels import phantom_conv as pc
from repro.models import cnn
from repro.serve import CnnServeEngine

BLK = (8, 8, 8)


def _pruned_fc(rng, k=96, n=80, density=0.4):
    w = rng.standard_normal((k, n)).astype(np.float32)
    w *= sparsity.block_prune(w, density, BLK[1:])
    return w


def _pruned_conv(rng, cin=8, cout=16, kh=3, density=0.4):
    w = rng.standard_normal((kh, kh, cin, cout)).astype(np.float32)
    w2 = w.reshape(-1, cout)
    w2 *= sparsity.block_prune(w2, density, BLK[1:])
    return w2.reshape(w.shape)


def _skewed_fc(rng, kt=12, nt=8):
    """Column-block densities skewed so heavy columns collide under naive
    round-robin (heavies at stride-``cores`` positions) but spread under
    LPT."""
    bk, bn = BLK[1:]
    w = np.zeros((kt * bk, nt * bn), np.float32)
    for c in range(nt):
        rows = kt if c % 4 == 0 else 1  # heavy every 4th column
        w[: rows * bk, c * bn : (c + 1) * bn] = rng.standard_normal(
            (rows * bk, bn)
        ).astype(np.float32)
    return w


# -- bit-identity grid: cores × balance × lowering ---------------------------


@pytest.mark.parametrize("cores", [1, 2, 4])
@pytest.mark.parametrize("bal", ["none", "full"])
def test_fc_multicore_parity(cores, bal):
    rng = np.random.default_rng(0)
    w = _pruned_fc(rng)
    x = jnp.asarray(rng.standard_normal((24, w.shape[0])).astype(np.float32))
    pw1 = ops.prepare_weight(w, m=24, block=BLK)
    ref = np.asarray(ops.phantom_matmul(x, pw1, interpret=True))
    pw = ops.prepare_weight(w, m=24, block=BLK, cores=cores, balance=bal)
    got = np.asarray(ops.phantom_matmul(x, pw, interpret=True))
    np.testing.assert_array_equal(got, ref)
    if cores > 1:
        assert pw.cores == cores and pw.mi.shape[0] == cores
        # Work is conserved: per-core MAC steps sum to the single-core count,
        # and `steps` (net of padding-slot writes) stays comparable.
        mt = pw.grid_tiles[0]
        assert int(pw.core_cost.sum()) * mt == mt * int(pw.w_bmask.sum())
        assert pw.steps == pw1.steps


@pytest.mark.parametrize("cores", [1, 2, 4])
@pytest.mark.parametrize("bal", ["none", "full"])
@pytest.mark.parametrize("mode", ["direct", "im2col"])
def test_conv_multicore_parity(cores, bal, mode):
    rng = np.random.default_rng(1)
    w = _pruned_conv(rng)
    x = jnp.asarray(rng.standard_normal((2, 6, 6, 8)).astype(np.float32))
    ref = np.asarray(
        pc.phantom_conv_call(
            x,
            pc.prepare_conv_weight(w, batch=2, in_hw=(6, 6), block=BLK, mode=mode),
            interpret=True,
        )
    )
    pcw = pc.prepare_conv_weight(
        w, batch=2, in_hw=(6, 6), block=BLK, mode=mode, cores=cores, balance=bal
    )
    got = np.asarray(pc.phantom_conv_call(x, pcw, interpret=True))
    np.testing.assert_array_equal(got, ref)


def test_depthwise_strided_multicore_parity():
    """Grouped/strided conv through per-core queues — the structural-zero
    compaction and phase decomposition survive the partition."""
    rng = np.random.default_rng(2)
    w = rng.standard_normal((3, 3, 1, 8)).astype(np.float32)
    x = jnp.asarray(rng.standard_normal((2, 6, 6, 8)).astype(np.float32))
    kw = dict(batch=2, in_hw=(6, 6), stride=(2, 2), groups=8, block=BLK, mode="direct")
    ref = np.asarray(
        pc.phantom_conv_call(x, pc.prepare_conv_weight(w, **kw), interpret=True)
    )
    got = np.asarray(
        pc.phantom_conv_call(
            x, pc.prepare_conv_weight(w, cores=4, balance="full", **kw), interpret=True
        )
    )
    np.testing.assert_array_equal(got, ref)


def test_multicore_fused_linear_act_parity():
    """The fused linear+activation+encoding path: multi-core output and §3.8
    tile mask match the single-core fused kernel."""
    rng = np.random.default_rng(3)
    w = _pruned_fc(rng)
    x = jnp.asarray(rng.standard_normal((24, w.shape[0])).astype(np.float32))
    y1, m1 = ops.phantom_linear_act(
        x, ops.prepare_weight(w, m=24, block=BLK), activation="relu", interpret=True
    )
    pw = ops.prepare_weight(w, m=24, block=BLK, cores=2)
    y2, m2 = ops.phantom_linear_act(x, pw, activation="relu", interpret=True)
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(m1))


def test_makespan_tail_revisits_last_flushed_block():
    """Inert makespan-padding tail steps must repeat the core's last real
    step's indices with all flags zero: on compiled TPU the end-of-window
    output writeback rewrites the just-flushed block with identical VMEM
    contents — tails that pointed at block (0, 0) would smear a stale
    buffer over it (invisible in interpret mode, fatal compiled)."""
    rng = np.random.default_rng(9)
    pw = ops.prepare_weight(
        _skewed_fc(rng), m=16, block=BLK, cores=4, balance="full"
    )
    qmax = pw.mi.shape[1]
    assert (pw.core_steps < qmax).any()  # the skew guarantees short queues
    for c, real in enumerate(pw.core_steps):
        if real == qmax:
            continue
        for name in ("mi", "ni", "ki", "wq"):
            arr = getattr(pw, name)[c]
            np.testing.assert_array_equal(arr[real:], arr[real - 1])
        for name in ("start", "last", "valid"):
            assert not getattr(pw, name)[c][real:].any()


# -- scheduling consistency: engine queues ↔ simulator schedule --------------


@pytest.mark.parametrize("bal", ["none", "full"])
def test_partition_matches_inter_core_schedule(bal):
    """The engine's column buckets and per-core costs are exactly the
    simulator's :func:`inter_core_schedule` on the per-column popcounts —
    same assignment lists, same loads (capacity = the equal-slab cap)."""
    rng = np.random.default_rng(4)
    cores = 4
    w = _skewed_fc(rng)
    pw = ops.prepare_weight(w, m=16, block=BLK, cores=cores, balance=bal)
    dens = pw.w_bmask.sum(axis=0).astype(np.float64)
    nt = pw.w_bmask.shape[1]
    sched = balance.inter_core_schedule(
        dens, cores, balanced=bal == "full", capacity=-(-nt // cores)
    )
    buckets = partition_columns(pw.w_bmask, cores, bal)
    assert [list(b) for b in buckets] == [list(a) for a in sched.assignment]
    loads = np.array([dens[a].sum() if a else 0.0 for a in sched.assignment])
    np.testing.assert_array_equal(pw.core_cost, loads.astype(np.int64))
    if bal == "full":  # balanced finish times are the per-core loads
        np.testing.assert_allclose(np.sort(sched.finish_times), np.sort(loads))
        assert int(max(loads)) == int(sched.makespan)


def test_program_stats_report_per_core_schedule():
    """stats() surfaces cores/per-core work/makespan/imbalance, consistent
    with inter_core_schedule on the same costs (the §5 contract extended)."""
    rng = np.random.default_rng(5)
    w = _skewed_fc(rng)
    from repro.core.dataflow import FCSpec

    layers = [FCSpec("fc1", w.shape[0], w.shape[1]), FCSpec("fc2", w.shape[1], 8)]
    params = {
        "fc1": {"w": jnp.asarray(w), "b": jnp.zeros(w.shape[1], jnp.float32)},
        "fc2": {
            "w": jnp.asarray(_pruned_fc(rng, w.shape[1], 8, 1.0)),
            "b": jnp.zeros(8, jnp.float32),
        },
    }
    cfg = phantom.PhantomConfig(enabled=True, block=BLK, cores=4, balance="full")
    prog = phantom.compile(layers, params, cfg, batch=4)
    s = prog.stats(4)["fc1"]
    assert s["cores"] == 4 and len(s["per_core_work"]) == 4
    art = prog.at_batch(4)["fc1"]
    dens = art.w_bmask.sum(axis=0).astype(np.float64)
    nt = art.w_bmask.shape[1]
    sched = balance.inter_core_schedule(
        dens, 4, balanced=True, capacity=-(-nt // 4)
    )
    mt = art.grid_tiles[0]
    assert sorted(s["per_core_work"]) == sorted(
        int(f) * mt for f in sched.finish_times
    )
    assert s["makespan"] == max(s["per_core_steps"])
    assert s["imbalance"] == pytest.approx(sched.imbalance)


def test_balanced_beats_naive_on_skewed_layer():
    """§4.2 payoff on the real artifacts: densest-first LPT strictly lowers
    both the per-core work makespan and the executed queue makespan vs the
    naive round-robin partition (outputs stay bit-identical)."""
    rng = np.random.default_rng(6)
    w = _skewed_fc(rng)
    x = jnp.asarray(rng.standard_normal((16, w.shape[0])).astype(np.float32))
    pws = {
        bal: ops.prepare_weight(w, m=16, block=BLK, cores=4, balance=bal)
        for bal in ("none", "full")
    }
    np.testing.assert_array_equal(
        np.asarray(ops.phantom_matmul(x, pws["none"], interpret=True)),
        np.asarray(ops.phantom_matmul(x, pws["full"], interpret=True)),
    )
    assert pws["full"].core_cost.max() < pws["none"].core_cost.max()
    assert pws["full"].core_steps.max() <= pws["none"].core_steps.max()


# -- the naive lock-step regression (satellite fix) --------------------------


def test_naive_schedule_partial_final_round():
    """Non-divisible job counts: the final partial round advances *every*
    column (lock-step — idle columns wait for the round), so no worker's
    finish time predates the true end and imbalance is exact."""
    costs = np.array([4.0, 1.0, 1.0, 1.0, 10.0])  # 5 jobs on 3 workers
    s = balance.inter_core_schedule(costs, 3, balanced=False)
    # Rounds: max(4,1,1)=4, then max(1,10)=10 — makespan 14 for everyone.
    assert s.makespan == 14.0
    np.testing.assert_array_equal(s.finish_times, np.full(3, 14.0))
    assert s.imbalance == 1.0  # lock-step: the cost shows up as makespan
    assert s.assignment == [[0, 3], [1, 4], [2]]
    # Balanced on the same jobs beats the lock-step makespan.
    b = balance.inter_core_schedule(costs, 3, balanced=True)
    assert b.makespan <= s.makespan


# -- program surface: toy CNN, save/load, serving ----------------------------


@pytest.mark.parametrize("mode", ["direct", "im2col"])
def test_program_multicore_toy_cnn_parity(mode):
    """The acceptance bar: cores=4 ≡ cores=1 bit-identically on the toy CNN
    (conv → depthwise s2 → pointwise → GAP-FC) in both conv lowerings."""
    rng = np.random.default_rng(7)
    layers, params = toy_cnn(rng)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    mk = lambda cores: phantom.compile(
        layers,
        params,
        phantom.PhantomConfig(
            enabled=True, block=(16, 16, 16), conv_mode=mode, cores=cores
        ),
        batch=2,
    )
    y1 = np.asarray(mk(1)(x, interpret=True))
    y4 = np.asarray(mk(4)(x, interpret=True))
    np.testing.assert_array_equal(y4, y1)
    ref = np.asarray(cnn.cnn_forward(params, x, layers))
    np.testing.assert_allclose(y4, ref, atol=1e-4, rtol=1e-3)


def test_multicore_save_load_serve():
    """A cores=2 program survives save/load (per-core queues, payload
    offsets, column permutation all restored; zero re-lowerings) and serves
    through CnnServeEngine bit-identically."""
    import tempfile

    rng = np.random.default_rng(8)
    layers, params = toy_cnn(rng)
    cfg = phantom.PhantomConfig(enabled=True, block=(16, 16, 16), cores=2)
    prog = phantom.compile(layers, params, cfg, batch=2)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    y = np.asarray(prog(x, interpret=True))
    with tempfile.TemporaryDirectory() as d:
        prog.save(d + "/prog")
        q = phantom.PhantomProgram.load(d + "/prog")
        assert q.lowerings == 0 and q.cfg.cores == 2
        np.testing.assert_array_equal(np.asarray(q(x, interpret=True)), y)
        assert q.stats(2) == prog.stats(2)
        plan = q.at_batch(2)["c1"].plan
        assert plan.cores == 2 and plan.mi.shape[0] == 2
        eng = CnnServeEngine(program=q, batch_size=2, interpret=True)
        reqs = [eng.submit(np.asarray(x)[i]) for i in range(2)]
        eng.run()
        np.testing.assert_array_equal(np.stack([r.logits for r in reqs]), y)
    assert q.lowerings == 0


@pytest.mark.slow
def test_multicore_shards_over_devices():
    """With >1 XLA device the cores axis maps onto a ('cores',) device mesh
    via shard_map — numerics stay bit-identical to the single-device grid.
    Subprocess: fake device count must be set before jax initialises."""
    script = """
import jax, numpy as np, jax.numpy as jnp
assert len(jax.devices()) == 2, jax.devices()
from repro.core import sparsity
from repro.kernels import ops, phantom_conv as pc
from repro.parallel import sharding

rng = np.random.default_rng(0)
blk = (8, 8, 8)
w = rng.standard_normal((96, 80)).astype(np.float32)
w *= sparsity.block_prune(w, 0.4, blk[1:])
x = jnp.asarray(rng.standard_normal((24, 96)).astype(np.float32))
assert sharding.cores_mesh(4) is not None  # 2 devices, 4 cores: shardable
y1 = np.asarray(ops.phantom_matmul(x, ops.prepare_weight(w, m=24, block=blk), interpret=True))
pw = ops.prepare_weight(w, m=24, block=blk, cores=4, balance="full")
yc = np.asarray(ops.phantom_matmul(x, pw, interpret=True))
np.testing.assert_array_equal(yc, y1)

wc = rng.standard_normal((3, 3, 8, 16)).astype(np.float32)
xc = jnp.asarray(rng.standard_normal((2, 6, 6, 8)).astype(np.float32))
p1 = pc.prepare_conv_weight(wc, batch=2, in_hw=(6, 6), block=blk, mode="direct")
p2 = pc.prepare_conv_weight(wc, batch=2, in_hw=(6, 6), block=blk, mode="direct", cores=2)
np.testing.assert_array_equal(
    np.asarray(pc.phantom_conv_call(xc, p2, interpret=True)),
    np.asarray(pc.phantom_conv_call(xc, p1, interpret=True)),
)
print("SHARDED-OK")
"""
    res = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env={
            **os.environ,
            "PYTHONPATH": "src",
            "JAX_PLATFORMS": "cpu",
            "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
        },
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert res.returncode == 0, res.stderr
    assert "SHARDED-OK" in res.stdout
