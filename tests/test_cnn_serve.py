"""Batched CNN serving: fixed-slot batching over the prepared Phantom net.

The conv artifacts are shape-specialised, so the engine pads short batches
with zero images (whose tiles are fully gated) instead of recompiling — the
whole request stream runs through one compiled program."""
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import toy_cnn as _toy_net

from repro.models import cnn
from repro.serve import CnnServeEngine, serve_cnn

BLK = (16, 16, 16)


def test_serve_matches_dense_forward_with_padded_batches():
    """3 requests through batch-2 slots: results equal the dense forward per
    image; the short second batch is padded, not recompiled."""
    rng = np.random.default_rng(17)
    layers, params = _toy_net(rng)
    imgs = rng.standard_normal((3, 8, 8, 3)).astype(np.float32)
    eng = CnnServeEngine(params, layers, batch_size=2, block=BLK, interpret=True)
    reqs = [eng.submit(im) for im in imgs]
    done = eng.run()
    assert [r.rid for r in done] == [0, 1, 2] and all(r.done for r in reqs)
    assert (eng.batches_run, eng.images_served, eng.padded_slots) == (2, 3, 1)
    ref = np.asarray(cnn.cnn_forward(params, jnp.asarray(imgs), layers))
    got = np.stack([r.logits for r in reqs])
    np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-3)


def test_serve_cnn_one_shot_wrapper():
    rng = np.random.default_rng(23)
    layers, params = _toy_net(rng)
    imgs = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
    logits = serve_cnn(params, layers, imgs, batch_size=2, block=BLK, interpret=True)
    ref = np.asarray(cnn.cnn_forward(params, jnp.asarray(imgs), layers))
    np.testing.assert_allclose(logits, ref, atol=1e-4, rtol=1e-3)


def test_slot_mask_keeps_padded_rows_zero():
    """The slot mask defeats relu(0 + bias): a padded slot's activations
    stay exactly zero through every layer, so its flowing §3.8 mask gates
    all of its tiles and its logits collapse to the final-layer bias."""
    rng = np.random.default_rng(31)
    layers, params = _toy_net(rng)
    imgs = np.zeros((2, 8, 8, 3), np.float32)
    imgs[0] = rng.standard_normal((8, 8, 3)).astype(np.float32)
    prepared = cnn.prepare_cnn_phantom(params, layers, batch=2, block=BLK)
    y = cnn.cnn_forward_phantom(
        params, prepared, jnp.asarray(imgs), layers,
        slot_mask=jnp.asarray([1.0, 0.0]), interpret=True,
    )
    np.testing.assert_array_equal(
        np.asarray(y)[1], np.asarray(params[layers[-1].name]["b"])
    )
    # And the live row is untouched by the masking.
    ref = cnn.cnn_forward(params, jnp.asarray(imgs[:1]), layers)
    np.testing.assert_allclose(np.asarray(y)[0], np.asarray(ref)[0], atol=1e-4)


def test_serve_rejects_wrong_shape():
    rng = np.random.default_rng(3)
    layers, params = _toy_net(rng)
    eng = CnnServeEngine(params, layers, batch_size=1, block=BLK, interpret=True)
    with pytest.raises(ValueError):
        eng.submit(np.zeros((4, 4, 3), np.float32))


def _tau_sensitive_net(rng):
    """Conv→GAP-FC net whose pooled activations land in (0, τ]: with τ
    applied the FC consumer sees a fully-gated input and its logits collapse
    to the bias exactly (the test_program GAP-τ construction)."""
    import phantom
    from repro.core.dataflow import ConvSpec, FCSpec

    layers = [ConvSpec("c1", 3, 16, 8, 8, 3, 3, (1, 1)), FCSpec("fc", 16, 10, pool="gap")]
    params = {}
    for l in layers:
        shp = (3, 3, 3, 16) if l.name == "c1" else (16, 10)
        w = rng.standard_normal(shp).astype(np.float32) * (1e-3 if l.name == "c1" else 0.1)
        params[l.name] = {
            "w": jnp.asarray(w),
            "b": jnp.asarray(
                np.zeros(shp[-1], np.float32)
                if l.name == "c1"
                else rng.standard_normal(shp[-1]).astype(np.float32) * 0.1
            ),
        }
    return phantom, layers, params


def test_serve_cnn_threads_act_threshold():
    """Regression (the one-shot API silently dropped τ): ``serve_cnn``
    passes ``act_threshold`` through to the engine — at τ>0 the
    τ-sensitive net's logits collapse to the FC bias, and genuinely differ
    from the τ=0 serve."""
    rng = np.random.default_rng(41)
    phantom, layers, params = _tau_sensitive_net(rng)
    tau = 0.05
    imgs = np.abs(rng.standard_normal((2, 8, 8, 3))).astype(np.float32)
    prog = phantom.compile(
        layers, params, phantom.PhantomConfig(enabled=True, block=BLK), batch=2
    )
    got = serve_cnn(images=imgs, program=prog, batch_size=2,
                    act_threshold=tau, interpret=True)
    np.testing.assert_array_equal(
        got, np.tile(np.asarray(params["fc"]["b"]), (2, 1))
    )
    exact = serve_cnn(images=imgs, program=prog, batch_size=2, interpret=True)
    assert np.abs(exact - got).max() > 0  # τ is genuinely lossy here


def test_legacy_engine_explicit_falsy_knobs():
    """Regression (``conv_mode or "direct"`` / ``act_threshold or 0.0``):
    falsy-but-explicit legacy knobs no longer collapse to the defaults — an
    empty conv_mode is rejected instead of silently running direct, and an
    explicit τ reaches the compiled config."""
    rng = np.random.default_rng(43)
    layers, params = _toy_net(rng)
    with pytest.raises(ValueError, match="direct|im2col"):
        CnnServeEngine(
            params, layers, batch_size=1, block=BLK, conv_mode="", interpret=True
        )
    eng = CnnServeEngine(
        params, layers, batch_size=1, block=BLK, act_threshold=0.25, interpret=True
    )
    assert eng.program.cfg.act_threshold == 0.25
