"""Program verifier + repo lint (DESIGN.md §13): rules, tiers, integration.

The contracts under test:

* **clean pass** — a freshly compiled program has zero findings, and the
  save → load(verify) round trip is silent at every tier: the verifier
  must never flag what the real pipeline produces;
* **mutation killing** — every named rule catches its seeded corruption
  (one case per rule, shared with ``python -m repro.verify --self-check``
  so pytest and CI exercise the same matrix).  A rule that fires on
  nothing is dead code;
* **structured load failures** — unknown format versions, truncated
  payloads, and bit-rot reject with a :class:`VerifyError` naming the
  rule and the artifact path, never a raw ``KeyError``;
* **tiering** — the default load tier stays size-independent (no
  fingerprint hash, no per-step scans); ``verify="full"`` catches
  restamped structural corruption the fast tier intentionally skips;
* **stale tune cache** — a cached override outside the live search space
  warns, counts under ``cache.stale``, and re-searches instead of
  resurrecting a retired config (both tune modes);
* **lint** — ``tools/lint_phantom.py`` flags hand-rolled timing,
  nondeterminism in deterministic code, and partial LayerKind
  registrations, with ``path:line: [PHxxx]`` output.
"""
import importlib.util
import json
import os
import pathlib

import numpy as np
import pytest

import phantom
from repro.core.dataflow import ConvSpec, FCSpec
from repro.core.phantom_linear import PhantomConfig
from repro.program import PhantomProgram
from repro.tune import TuneCache, tune_overrides
from repro.tune.space import DEFAULT_SPACE, override_in_space
from repro.verify import VerifyError, check_program, verify_program
from repro.verify.selfcheck import (
    FILE_MUTATIONS,
    PROGRAM_MUTATIONS,
    _mut_bounds,
    build_mutation_program,
    restamp_fingerprint,
)

# -- clean pass ---------------------------------------------------------------


def test_clean_program_has_no_findings():
    prog = build_mutation_program()
    assert check_program(prog) == []
    assert verify_program(prog) == []


def test_compile_verifies_by_default_and_flag_disables():
    prog = build_mutation_program()  # compiled with verify=False
    assert prog.verify is False
    layers, params, cfg = prog.layers, prog.params, prog.cfg
    prog2 = phantom.compile(
        layers, params, cfg, batch=2, overrides=prog.overrides
    )
    assert prog2.verify is True


def test_at_batch_hook_runs_per_fresh_lowering(monkeypatch):
    import repro.verify

    prog = build_mutation_program()
    prog.verify = True
    calls = []
    monkeypatch.setattr(
        repro.verify, "verify_program",
        lambda p, **kw: calls.append(kw) or [],
    )
    prog.at_batch(4)
    assert len(calls) == 1 and calls[0]["batches"] == (4,)
    assert calls[0]["graph"] is False  # graph rules ran at compile time
    prog.at_batch(4)  # cache hit: no re-lowering, no re-verification
    assert len(calls) == 1
    prog.verify = False
    prog.at_batch(8)
    assert len(calls) == 1  # hook off: fresh lowering goes unchecked


def test_save_load_round_trip_all_tiers(tmp_path):
    prog = build_mutation_program()
    path = str(tmp_path / "prog")
    prog.save(path)
    for tier in (False, True, "full"):
        loaded = PhantomProgram.load(path, verify=tier)
        assert loaded.verify is bool(tier)
    x = np.random.default_rng(0).standard_normal((2, 12, 12, 16)).astype(np.float32)
    ref = np.asarray(prog(x))
    got = np.asarray(PhantomProgram.load(path, verify="full")(x))
    np.testing.assert_array_equal(ref, got)


# -- mutation killing ---------------------------------------------------------


@pytest.mark.parametrize(
    "rule,mut", PROGRAM_MUTATIONS, ids=[r for r, _ in PROGRAM_MUTATIONS]
)
def test_rule_catches_program_mutation(rule, mut):
    prog = build_mutation_program()
    mut(prog)
    findings = check_program(prog)
    assert any(
        f.rule == rule and f.level == "error" for f in findings
    ), f"{rule} did not fire; got {[f.rule for f in findings]}"


@pytest.mark.parametrize(
    "rule,mut", FILE_MUTATIONS, ids=[r for r, _ in FILE_MUTATIONS]
)
def test_rule_catches_file_mutation(rule, mut, tmp_path):
    prog = build_mutation_program()
    path = str(tmp_path / "prog")
    prog.save(path)
    mut(path)
    with pytest.raises(VerifyError) as ei:
        PhantomProgram.load(path, verify="full")
    assert any(f.rule == rule for f in ei.value.findings), str(ei.value)
    assert path in str(ei.value)


# -- structured load failures -------------------------------------------------


def _manifest(path):
    (d,) = [n for n in os.listdir(path) if n.startswith("step_")]
    return os.path.join(path, d, "manifest.json")


def test_unknown_format_version_is_structured_even_unverified(tmp_path):
    path = str(tmp_path / "prog")
    build_mutation_program().save(path)
    mf = _manifest(path)
    doc = json.load(open(mf))
    doc["extra"]["format"] = 99
    json.dump(doc, open(mf, "w"))
    for tier in (False, True, "full"):
        with pytest.raises(VerifyError) as ei:
            PhantomProgram.load(path, verify=tier)
        (f,) = ei.value.findings
        assert f.rule == "artifact/version"
        assert "99" in f.detail and "version 1" in f.detail
        assert path in str(ei.value)


def test_missing_payload_array_is_read_error_not_keyerror(tmp_path):
    path = str(tmp_path / "prog")
    build_mutation_program().save(path)
    (d,) = [n for n in os.listdir(path) if n.startswith("step_")]
    npz = os.path.join(path, d, "arrays.npz")
    with np.load(npz) as z:
        arrays = {k: z[k] for k in z.files}
    victim = next(k for k in sorted(arrays) if k.startswith("plans/"))
    del arrays[victim]
    np.savez(npz, **arrays)
    restamp_fingerprint(path)
    # The unpack guard runs at every tier — a truncated artifact can never
    # deserialise, so even verify=False reports the rule, not a KeyError.
    for tier in (False, True, "full"):
        with pytest.raises(VerifyError) as ei:
            PhantomProgram.load(path, verify=tier)
        assert any(f.rule == "artifact/read" for f in ei.value.findings)


def test_missing_verify_stamp_rejected_when_verifying(tmp_path):
    path = str(tmp_path / "prog")
    build_mutation_program().save(path)
    mf = _manifest(path)
    doc = json.load(open(mf))
    del doc["extra"]["verify"]
    json.dump(doc, open(mf, "w"))
    with pytest.raises(VerifyError) as ei:
        PhantomProgram.load(path)
    assert ei.value.findings[0].rule == "artifact/version"
    PhantomProgram.load(path, verify=False)  # opt-out still reads it


# -- tiering ------------------------------------------------------------------


def test_full_tier_catches_restamped_structural_corruption(tmp_path):
    """A per-step corruption with a *consistent* fingerprint: the fast tier
    accepts it by design (size-independent rules only), the full tier's
    queue scan names the rule."""
    prog = build_mutation_program()
    _mut_bounds(prog)
    path = str(tmp_path / "prog")
    prog.save(path)  # save() stamps the (corrupted) content as-is
    PhantomProgram.load(path, verify=True)
    with pytest.raises(VerifyError) as ei:
        PhantomProgram.load(path, verify="full")
    assert any(f.rule == "queue/bounds" for f in ei.value.findings)


def test_fast_tier_skips_fingerprint_full_tier_checks_it(tmp_path):
    path = str(tmp_path / "prog")
    build_mutation_program().save(path)
    mf = _manifest(path)
    doc = json.load(open(mf))
    doc["extra"]["verify"]["fingerprint"] = "0" * 64
    json.dump(doc, open(mf, "w"))
    PhantomProgram.load(path, verify=True)  # hash not recomputed by default
    with pytest.raises(VerifyError) as ei:
        PhantomProgram.load(path, verify="full")
    assert ei.value.findings[0].rule == "artifact/fingerprint"


# -- CLI ----------------------------------------------------------------------


def test_cli_reports_ok_and_findings(tmp_path, capsys):
    from repro.verify.__main__ import main

    good = str(tmp_path / "good")
    build_mutation_program().save(good)
    assert main([good]) == 0
    assert "OK" in capsys.readouterr().out

    bad = str(tmp_path / "bad")
    prog = build_mutation_program()
    _mut_bounds(prog)
    prog.save(bad)
    assert main([bad]) == 1
    out = capsys.readouterr().out
    assert "[queue/bounds]" in out and bad in out


# -- config/overrides + stale tune cache --------------------------------------


def test_override_in_space_membership():
    cfg = PhantomConfig(enabled=True, block=(16, 16, 16))
    assert override_in_space({}, cfg)
    assert override_in_space({"cores": 4}, cfg)
    assert override_in_space({"lookahead": 8}, cfg)
    assert override_in_space({"block": cfg.block}, cfg)  # base value: in pool
    assert not override_in_space({"cores": 7}, cfg)
    assert not override_in_space({"lookahead": 3}, cfg)
    assert not override_in_space({"lookahead": "soon"}, cfg)
    assert not override_in_space({"warp_factor": 9}, cfg)
    assert not override_in_space({"block": (8, 8, 8)}, cfg)


def test_out_of_space_override_warns_not_errors():
    base = build_mutation_program()
    # lookahead=3 is in the legal value domain but outside DEFAULT_SPACE's
    # pool — compiled in (so the graph rebuild agrees), the verifier flags
    # it at warn level only.
    ov = {"c1": {"cores": 4, "balance": "full", "lookahead": 3}}
    prog = phantom.compile(
        base.layers, base.params, base.cfg, batch=2, overrides=ov,
        verify=False,
    )
    findings = check_program(prog)
    assert any(
        f.rule == "config/overrides" and f.level == "warn" for f in findings
    )
    assert not any(f.level == "error" for f in findings)
    with pytest.warns(UserWarning, match="config/overrides"):
        verify_program(prog)


def _stale_cache_setup(tmp_path):
    spec = ConvSpec("c1", in_ch=16, out_ch=32, in_h=8, in_w=8, kh=3, kw=3)
    cfg = PhantomConfig(enabled=True, block=(16, 16, 16))
    rng = np.random.default_rng(0)
    params = {"c1": {"w": rng.standard_normal((3, 3, 16, 32)).astype(np.float32)}}
    cache = TuneCache(str(tmp_path / "tc.json"), backend="test:cpu:jax0")
    key = cache.key_for(
        spec, 2, cfg, w_density=TuneCache.weight_density(params["c1"]["w"])
    )
    cache.put(key, {"lookahead": 3}, cost=1.0)  # 3 left the space: stale
    return [spec], params, cfg, cache


def test_stale_cache_entry_warns_and_researches(tmp_path):
    layers, params, cfg, cache = _stale_cache_setup(tmp_path)
    with pytest.warns(UserWarning, match="outside the current search space"):
        ov = tune_overrides(layers, params, 2, cfg, cache=cache, mode="search")
    assert ov.get("c1", {}).get("lookahead") != 3
    assert cache.stale == 1 and cache.searches == 1
    assert cache.counters()["stale"] == 1
    # the re-searched winner replaced the stale entry: next lookup is a
    # clean hit with an in-space override
    ov2 = tune_overrides(layers, params, 2, cfg, cache=cache, mode="cached")
    assert cache.stale == 1 and cache.searches == 1
    assert all(override_in_space(o, cfg) for o in ov2.values())


def test_stale_cache_entry_researches_even_in_cached_mode(tmp_path):
    layers, params, cfg, cache = _stale_cache_setup(tmp_path)
    with pytest.warns(UserWarning, match="re-searching"):
        ov = tune_overrides(layers, params, 2, cfg, cache=cache, mode="cached")
    assert cache.stale == 1 and cache.searches == 1  # defect ≠ plain miss
    assert ov.get("c1", {}).get("lookahead") != 3


# -- lint tool ----------------------------------------------------------------


def _lint():
    root = pathlib.Path(__file__).resolve().parents[1]
    spec = importlib.util.spec_from_file_location(
        "lint_phantom", root / "tools" / "lint_phantom.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _lint_src(tmp_path, relpath, source):
    mod = _lint()
    f = tmp_path / relpath
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(source)
    return mod.lint_file(f, tmp_path)


def test_lint_flags_handrolled_timing(tmp_path):
    out = _lint_src(
        tmp_path, "repro/kernels/bad.py",
        "import time\nt0 = time.perf_counter()\n",
    )
    assert len(out) == 1 and "[PH001]" in out[0] and ":2:" in out[0]


def test_lint_timing_allowlist_and_from_import(tmp_path):
    ok = _lint_src(
        tmp_path, "repro/obs/rec.py",
        "import time\nt0 = time.perf_counter()\n",
    )
    assert ok == []
    out = _lint_src(
        tmp_path, "repro/core/bad2.py",
        "from time import perf_counter\nt0 = perf_counter()\n",
    )
    assert len(out) == 1 and "[PH001]" in out[0]


def test_lint_flags_nondeterminism_in_tune(tmp_path):
    src = (
        "import random\nimport numpy as np\n"
        "x = random.random()\n"
        "rng = np.random.default_rng()\n"
        "good = np.random.default_rng(0)\n"
    )
    out = _lint_src(tmp_path, "repro/tune/bad.py", src)
    assert len(out) == 2 and all("[PH002]" in line for line in out)
    assert _lint_src(tmp_path, "repro/kernels/ok.py", src) == []


def test_lint_flags_partial_layerkind_registration(tmp_path):
    src = (
        "class HalfKind:\n"
        "    name = 'half'\n"
        "    def prepare(self): ...\n"
        "    def apply(self): ...\n"
        "register_layer_kind(Spec, HalfKind())\n"
    )
    out = _lint_src(tmp_path, "repro/program/bad.py", src)
    assert len(out) == 1 and "[PH003]" in out[0]
    assert "mask_out" in out[0] and "stats" in out[0]
    full = src.replace(
        "    def apply(self): ...\n",
        "    def apply(self): ...\n"
        "    def mask_out(self): ...\n"
        "    def stats(self): ...\n",
    )
    assert _lint_src(tmp_path, "repro/program/ok.py", full) == []


def test_lint_clean_on_repo_source():
    mod = _lint()
    root = pathlib.Path(__file__).resolve().parents[1]
    findings = []
    for f in sorted((root / "src").rglob("*.py")):
        findings += mod.lint_file(f, root)
    assert findings == []
