"""Per-arch smoke tests (deliverable f): reduced same-family configs run one
forward/train step on CPU, asserting output shapes + no NaNs; decode
consistency against prefill validates caches / SSD math / RoPE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tf
from repro.models.registry import build


def _batch(cfg, b=2, s=16, key=jax.random.PRNGKey(0)):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frontend_embeds"] = (
            jax.random.normal(key, (b, s, cfg.d_model)).astype(cfg.dtype()) * 0.02
        )
    elif cfg.frontend:
        batch["frontend_embeds"] = (
            jax.random.normal(key, (b, 4, cfg.d_model)).astype(cfg.dtype()) * 0.02
        )
    return batch


_HEAVY_SMOKE = {"zamba2_2p7b", "qwen2_vl_7b"}  # 17-25 s each on CPU


@pytest.mark.parametrize(
    "arch",
    [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_SMOKE else a
     for a in configs.ARCHS],
)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch
    )
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize(
    "arch",
    ["smollm_360m", "mamba2_2p7b", "zamba2_2p7b", "moonshot_v1_16b_a3b", "qwen2_vl_7b"],
)
def test_decode_matches_prefill(arch):
    cfg = dataclasses.replace(
        configs.get_smoke(arch),
        act_dtype="float32",
        param_dtype="float32",
        remat=False,
        moe_capacity_factor=8.0,
    )
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    full, _ = tf.lm_forward(params, toks, cfg)
    cache = model.init_cache(b, s)
    step = jax.jit(model.decode_step)
    for t in range(s):
        lg, cache = step(params, cache, toks[:, t : t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, t]), atol=2e-3, rtol=1e-3
        )


def test_vector_index_decode():
    """Continuous batching: per-slot indices behave like per-slot scalars."""
    cfg = dataclasses.replace(
        configs.get_smoke("smollm_360m"), act_dtype="float32",
        param_dtype="float32", remat=False,
    )
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    # Slot 0 runs 4 steps, slot 1 runs 2: replay with a vector index.
    cache = model.init_cache(2, 8)
    lg = None
    for t in range(4):
        idx = jnp.asarray([t, min(t, 1)], jnp.int32)
        tok = jnp.stack([toks[0, t], toks[1, min(t, 1)]])[:, None]
        lg, cache = model.decode_step(params, cache, tok, idx)
    # Reference: slot 0 full 4-token prefill.
    full, _ = tf.lm_forward(params, toks[:1, :4], cfg)
    np.testing.assert_allclose(
        np.asarray(lg[0, 0]), np.asarray(full[0, 3]), atol=2e-3, rtol=1e-3
    )


def test_moe_dispatch_exact_vs_naive():
    from repro.models import moe as moe_mod
    from repro.models.common import init_params
    from repro.models.layers import ACT

    cfg = dataclasses.replace(
        configs.get_smoke("moonshot_v1_16b_a3b"),
        act_dtype="float32", param_dtype="float32", n_shared_experts=0,
    )
    p = init_params(jax.random.PRNGKey(0), moe_mod.moe_spec(cfg))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
    y, aux = moe_mod.moe(p, x, cfg, capacity_factor=10.0)
    assert float(aux["dropped_frac"]) == 0.0
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    gates, ids = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    yref = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        h = ACT[cfg.act](xt @ p["gate"][e]) * (xt @ p["up"][e])
        w = ((ids == e) * gates).sum(-1)
        yref = yref + (h @ p["down"][e]) * w[:, None]
    np.testing.assert_allclose(
        np.asarray(y.reshape(-1, cfg.d_model)), np.asarray(yref), atol=1e-4
    )
