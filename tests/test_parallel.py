"""Distribution tests on fake CPU devices: sharding-rule resolution,
pipeline numerics + grads, elastic re-mesh resume, sharded-vs-single-device
train-step equivalence.  Runs in a subprocess where needed so the 8-device
XLA flag never leaks into other tests."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from jax.sharding import PartitionSpec as P


def run_sub(code: str, devices: int = 8):
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(code)
    )
    r = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_rule_resolution_fallbacks():
    """Divisibility + claimed-axis fallbacks, no fake devices needed."""
    from repro.parallel import sharding as shd

    mesh = shd.compat_make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    # FFN weight: 2-D FSDP × TP.
    assert shd.resolve_tensor((1024, 4096), ("embed", "mlp"), m, shd.PARAM_RULES) == P("data", "model")
    # grok experts: 8 % 16 ≠ 0 → expert falls back, mlp takes 'model'.
    assert shd.resolve_tensor(
        (8, 6144, 32768), ("expert", "embed", "mlp"), m, shd.PARAM_RULES
    ) == P(None, "data", "model")
    # moonshot experts: EP claims 'model'; mlp then must not reuse it.
    assert shd.resolve_tensor(
        (64, 2048, 1408), ("expert", "embed", "mlp"), m, shd.PARAM_RULES
    ) == P("model", "data", None)
    # Indivisible dim → replicate.
    assert shd.resolve_tensor((15, 10), ("vocab", "embed"), m, shd.PARAM_RULES)[0] is None


@pytest.mark.slow  # fresh 8-fake-device JAX subprocess: minutes on CPU
def test_pipeline_matches_sequential():
    run_sub(
        """
        import jax, jax.numpy as jnp
        from repro.parallel import pipeline
        from repro.parallel import sharding as shd
        mesh = shd.compat_make_mesh((4,), ('stage',))
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (8, 16, 16)) * 0.2
        block = lambda w, x: jnp.tanh(x @ w)
        x = jax.random.normal(key, (6, 4, 16))
        with mesh:
            y = pipeline.pipeline_apply(block, pipeline.split_stages(W, 4), x, mesh)
        ref = x
        for i in range(8):
            ref = jnp.tanh(ref @ W[i])
        assert jnp.allclose(y, ref, atol=1e-5), float(jnp.abs(y-ref).max())
        g = jax.grad(lambda Wf: pipeline.pipeline_apply(
            block, pipeline.split_stages(Wf, 4), x, mesh).sum())(W)
        assert bool(jnp.isfinite(g).all())
        print('ok')
        """
    )


@pytest.mark.slow  # fresh 8-fake-device JAX subprocess: minutes on CPU
def test_sharded_train_step_matches_single_device():
    run_sub(
        """
        import dataclasses, jax, jax.numpy as jnp
        from repro import configs, optim
        from repro.models.registry import build
        from repro.train.trainer import make_train_step, TrainConfig
        cfg = dataclasses.replace(configs.get_smoke('smollm_360m'),
                                  act_dtype='float32', param_dtype='float32',
                                  remat=False)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = optim.init_opt_state(params)
        batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab),
                 'labels': jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab)}
        ocfg = optim.AdamWConfig(lr=1e-3)
        p1, o1, m1 = make_train_step(model, ocfg, TrainConfig())(params, opt, batch)
        from repro.parallel import sharding as shd
        mesh = shd.compat_make_mesh((4, 2), ('data', 'model'))
        from repro.models.common import set_mesh_rules
        set_mesh_rules(mesh, shd.act_rules(mesh))
        with mesh:
            params2 = model.init(jax.random.PRNGKey(0))
            opt2 = optim.init_opt_state(params2)
            p2, o2, m2 = make_train_step(model, ocfg, TrainConfig(), mesh)(params2, opt2, batch)
        d = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 2e-4, d
        assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-4
        print('ok', d)
        """
    )


@pytest.mark.slow  # fresh 8-fake-device JAX subprocess: minutes on CPU
def test_elastic_resume_matches_uninterrupted():
    run_sub(
        """
        import numpy as np
        from repro.launch import elastic
        ha, hb = elastic.run(steps_a=4, steps_b=4, batch=8, seq=32)
        # Same steps, uninterrupted, on the phase-A mesh:
        import jax
        from repro import configs, optim
        from repro.data import DataConfig, SyntheticTokens
        from repro.models.registry import build
        from repro.train import Trainer, TrainConfig
        cfg = configs.get_smoke('smollm_360m')
        model = build(cfg)
        data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
        ocfg = optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=8)
        tr = Trainer(model, data, ocfg, TrainConfig(), mesh=elastic.make_mesh(4, 2))
        p, o = tr.init_state()
        tr.run(p, o, 8)
        ref = [h['loss'] for h in tr.history]
        got = [h['loss'] for h in ha] + [h['loss'] for h in hb]
        assert np.allclose(ref, got, atol=2e-4), (ref, got)
        print('ok')
        """
    )


@pytest.mark.slow  # fresh 8-fake-device JAX subprocess: minutes on CPU
def test_compressed_cross_pod_lowering():
    """int8 cross-pod gradient path must trace and reduce like a mean."""
    run_sub(
        """
        import jax, jax.numpy as jnp
        from repro.optim import compressed_psum_grads
        from repro.parallel import sharding as shd
        mesh = shd.compat_make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        g = {'w': jnp.full((8, 8), 3.0)}
        e = {'w': jnp.zeros((8, 8))}
        with mesh:
            out, err = jax.jit(lambda g, e: compressed_psum_grads(g, e, mesh))(g, e)
        # identical grads on every pod -> mean == value (to int8 precision)
        assert float(jnp.abs(out['w'] - 3.0).max()) < 0.05, out['w']
        print('ok')
        """
    )
