"""``phantom`` — the public face of the Phantom program API.

    import phantom
    prog = phantom.compile(layers, params, phantom.PhantomConfig(enabled=True), batch=8)
    logits = prog(x)

Autotuning (DESIGN.md §12) rides on the same call:

    prog = phantom.compile(layers, params, cfg, batch=8, tune="search")
    # later / elsewhere: zero re-search, same per-layer configs
    prog = phantom.compile(layers, params, cfg, batch=8, tune="cached")

Every compile / load statically verifies the artifact by default
(DESIGN.md §13); a rejected artifact raises :class:`VerifyError` naming
the failed rule and layer.  Pass ``verify=False`` to opt out.

Thin alias over :mod:`repro.program` (plus the :class:`TuneCache` handle
from :mod:`repro.tune` and the verifier surface from :mod:`repro.verify`)
so user code does not spell the repro package layout; see DESIGN.md §8.
"""
from repro.program import (  # noqa: F401
    SERVE_DEFAULT,
    LayerKind,
    PhantomConfig,
    PhantomProgram,
    compile,
    register_layer_kind,
)
from repro.tune import TuneCache  # noqa: F401
from repro.verify import VerifyError, verify_program  # noqa: F401

__all__ = [
    "PhantomConfig",
    "PhantomProgram",
    "compile",
    "SERVE_DEFAULT",
    "LayerKind",
    "register_layer_kind",
    "TuneCache",
    "VerifyError",
    "verify_program",
]
