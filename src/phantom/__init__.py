"""``phantom`` — the public face of the Phantom program API.

    import phantom
    prog = phantom.compile(layers, params, phantom.PhantomConfig(enabled=True), batch=8)
    logits = prog(x)

Thin alias over :mod:`repro.program` so user code does not spell the repro
package layout; see DESIGN.md §8.
"""
from repro.program import (  # noqa: F401
    SERVE_DEFAULT,
    LayerKind,
    PhantomConfig,
    PhantomProgram,
    compile,
    register_layer_kind,
)

__all__ = [
    "PhantomConfig",
    "PhantomProgram",
    "compile",
    "SERVE_DEFAULT",
    "LayerKind",
    "register_layer_kind",
]
