"""Roofline analysis from the compiled dry-run artifact (deliverable g).

No real TPU is attached, so wall-time MFU cannot be measured; instead the
three roofline terms are *derived* from the compiled SPMD program:

  compute term    = HLO_FLOPs_per_chip   / peak_FLOP/s          (197 TF bf16)
  memory term     = HLO_bytes_per_chip   / HBM_bw               (819 GB/s)
  collective term = collective_bytes_per_chip / link_bw         (50 GB/s/link)

``compiled.cost_analysis()`` reports the per-chip partitioned program's
FLOPs / bytes.  Collective bytes are not in cost_analysis: the optimized HLO
text is parsed and the output-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op are summed (all-reduce
counted 2× for the ring's reduce-scatter + all-gather phases; a single
active ICI link is assumed — conservative).

``model_flops_ratio`` = MODEL_FLOPS / (HLO_FLOPs × chips) shows how much of
the compiled compute is "useful" (6·N·D for training dense, 6·N_active·D for
MoE, 2·N·D for inference) — catching remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import json
import re

__all__ = ["HW", "RooflineReport", "collective_bytes", "analyze", "model_flops"]

HW = {
    "peak_flops_bf16": 197e12,  # per chip (TPU v5e-class target)
    "hbm_bw": 819e9,  # B/s per chip
    "link_bw": 50e9,  # B/s per ICI link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<out>\(?[^=]*?)\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(fragment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(fragment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind output bytes of every collective op in the (per-chip) HLO."""
    out: dict = {k: 0 for k in
                 ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        frag = m.group(0)
        if "-done(" in frag:  # async pairs: count the start only
            continue
        kind = m.group("kind")
        out[kind] += _shape_bytes(m.group("out"))
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    model_flops_ratio: float
    memory_per_chip_bytes: float

    def to_dict(self):
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (
            f"{self.arch:22s} {self.shape:12s} {self.mesh:10s} "
            f"compute={self.compute_s*1e3:9.3f}ms memory={self.memory_s*1e3:9.3f}ms "
            f"collective={self.collective_s*1e3:9.3f}ms -> {self.dominant:10s} "
            f"useful={self.model_flops_ratio:6.2%}"
        )


def model_flops(cfg, shape_spec) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D inference (N = active params)."""
    n = cfg.active_param_count()
    if shape_spec.kind == "train":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 6.0 * n * tokens
    if shape_spec.kind == "prefill":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape_spec.global_batch  # decode: one token per row


def analyze(compiled, mesh, *, arch: str, shape: str, cfg=None, shape_spec=None) -> RooflineReport:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    chips = mesh.size
    coll = collective_bytes(compiled.as_text())
    coll_bytes = sum(
        v * (2 if k == "all-reduce" else 1) for k, v in coll.items()
    )
    compute_s = flops / HW["peak_flops_bf16"]
    memory_s = byts / HW["hbm_bw"]
    collective_s = coll_bytes / HW["link_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape_spec) if cfg is not None and shape_spec is not None else 0.0
    ratio = mf / (flops * chips) if flops else 0.0
    ma = compiled.memory_analysis()
    mem_per_chip = (
        ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes
        - ma.alias_size_in_bytes
    )
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh="x".join(str(s) for s in mesh.devices.shape),
        chips=chips,
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=byts,
        collective_bytes_per_chip=float(coll_bytes),
        collective_breakdown=coll,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        model_flops_ratio=ratio,
        memory_per_chip_bytes=float(mem_per_chip),
    )
