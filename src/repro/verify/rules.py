"""Static invariant checking for compiled Phantom artifacts (DESIGN.md §13).

A compiled :class:`~repro.program.PhantomProgram` is a web of scheduling
invariants — §3.8 mask flow between layers, §3.4 TDS queue compaction,
§4.2/§4.6 per-core partitioning and makespan padding — that the kernels
*assume* rather than re-check.  A corrupted or stale artifact therefore
fails as a shape error (or silent wrong answer) mid-kernel.  This module is
the compiler-style verifier that closes that gap: every invariant is a
**named rule** that re-derives the expected structure from first principles
(the weight mask, the schedule in :mod:`repro.core.balance`, the compaction
metadata in :mod:`repro.kernels.compaction`) and compares, without executing
any kernel.

Rule catalog (each individually mutation-tested in ``tests/test_verify.py``
and ``python -m repro.verify --self-check``):

==================== =======================================================
``artifact/version``     serialized format tag matches this build's schema
``artifact/read``        every metadata node's payload array exists
``artifact/fingerprint`` content hash over metadata + arrays round-trips
``queue/step-classes``   every step is MAC / zero-write / inert (§3.8, §4.6)
``queue/run-structure``  (mi, ni) runs contiguous, k ascending, flags paired
``queue/coverage``       every output tile flushed exactly once
``queue/bounds``         indices in-bounds; ``wq`` equals the packed-payload
                         id re-derived from the weight mask
``queue/inert-tail``     makespan padding is inert and repeats the last real
                         step (the tail-revisit contract)
``cores/partition``      ``col_perm`` a true permutation; buckets disjoint,
                         capacity-capped, prefix-packed; ``col_inv`` inverse
``cores/gauges``         ``core_cost`` / ``core_steps`` / makespan equal an
                         independent re-derivation (``inter_core_schedule``)
``lookahead/cmeta``      compaction metadata equals ``compaction_meta``
``plan/geometry``        artifact shapes equal the spec-derived geometry
``graph/mask-flow``      node graph equals a rebuild (§3.8 glue, τ-at-
                         producer, last-layer rule); kinds complete
``config/overrides``     per-layer tune overrides name real layers/fields,
                         hold legal values (error) from the live search
                         space (warn)
==================== =======================================================

Findings carry a ``level``: ``"error"`` findings make
:func:`verify_program` raise :class:`VerifyError`; ``"warn"`` findings (an
override value outside the current tune search space — legal, but no longer
reachable by ``tune="search"``) surface as a :class:`UserWarning`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import warnings

import numpy as np

__all__ = [
    "VERIFY_SCHEMA",
    "Finding",
    "VerifyError",
    "artifact_fingerprint",
    "check_artifact",
    "check_program",
    "verify_program",
]

#: Bump on any change to the fingerprint recipe or the serialized-artifact
#: verification contract; stamped into ``meta["verify"]`` by
#: :meth:`PhantomProgram.save`.
VERIFY_SCHEMA = 1

#: Cap on repeated per-step findings from one rule on one artifact — the
#: first offending index plus a count beats 10k identical lines.
_MAX_PER_RULE = 3


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verifier finding: the failed rule, where, and why."""

    rule: str
    detail: str
    layer: str | None = None
    batch: int | None = None
    level: str = "error"  # "error" | "warn"

    def format(self) -> str:
        where = ""
        if self.layer is not None:
            where += f" layer={self.layer}"
        if self.batch is not None:
            where += f" batch={self.batch}"
        return f"[{self.rule}]{where}: {self.detail}"


class VerifyError(ValueError):
    """Raised when verification finds error-level invariant violations.

    Subclasses :class:`ValueError` so pre-verifier callers catching the old
    ``load`` errors keep working.  ``findings`` holds the structured
    :class:`Finding` list; ``path`` names the artifact when verification ran
    at load time.
    """

    def __init__(self, findings, *, path: str | None = None):
        self.findings = list(findings)
        self.path = path
        where = f" for {path}" if path else ""
        lines = "\n".join("  " + f.format() for f in self.findings)
        super().__init__(
            f"Phantom program verification failed{where} "
            f"({len(self.findings)} finding(s)):\n{lines}"
        )


def artifact_fingerprint(meta: dict, arrays: dict) -> str:
    """Deterministic content hash of a serialized program.

    Covers the JSON metadata (minus the ``verify`` block itself, so the
    stamp does not hash its own output) and every payload array's name,
    dtype, shape and bytes, in sorted key order.  Save stamps it into
    ``meta["verify"]["fingerprint"]``; load recomputes and compares
    (``artifact/fingerprint``).
    """
    h = hashlib.sha256()
    clean = {k: v for k, v in meta.items() if k != "verify"}
    h.update(json.dumps(clean, sort_keys=True, separators=(",", ":")).encode())
    for key in sorted(arrays):
        a = np.ascontiguousarray(np.asarray(arrays[key]))
        h.update(key.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


# -- queue-level rules --------------------------------------------------------


class _QView:
    """Normalised view of a queue-carrying artifact (``PhantomWeight`` or
    ``DirectConvPlan``): every queue array as int64 [cores, Qpad], plus the
    derived quantities the rules share.  ``conv_ctx`` (when the artifact
    came wrapped in a ``PhantomConvWeight``) carries the conv geometry the
    offset re-derivation needs."""

    def __init__(self, art, conv_ctx: dict | None):
        self.art = art
        self.conv_ctx = conv_ctx
        self.cores = int(getattr(art, "cores", 1))
        self.grid = tuple(int(v) for v in art.grid_tiles)
        self.mt, self.kt, self.nt = self.grid
        blk = tuple(art.block)
        self.bk, self.bn = int(blk[-2]), int(blk[-1])
        names = ["mi", "ni", "wq", "start", "last", "valid", "flat_ak"]
        if hasattr(art, "ki"):
            names.append("ki")
        if hasattr(art, "ph"):
            names += ["ph", "nb", "r0", "c0", "ch0"]
        self._raw = {
            n: np.atleast_2d(np.asarray(getattr(art, n))) for n in names
        }
        self._fields: dict | None = None
        shapes = {f.shape for f in self._raw.values()}
        self.consistent = len(shapes) == 1
        self.q = next(iter(shapes))[-1] if self.consistent else 0
        self.rows = next(iter(shapes))[0] if self.consistent else 0
        if self.cores > 1:
            self.width = int(art.local_nt)
            self.reals = np.asarray(art.core_steps, dtype=np.int64)
        else:
            self.width = self.nt
            self.reals = np.full(max(self.rows, 1), self.q, dtype=np.int64)
        self.bmask = np.asarray(art.w_bmask, dtype=bool)

    @property
    def fields(self) -> dict:
        """Queue arrays as int64 — converted on first access so the fast
        (``deep=False``) tier, which only reads shapes, never pays the
        O(steps) copies."""
        if self._fields is None:
            self._fields = {
                n: a.astype(np.int64, copy=False)
                for n, a in self._raw.items()
            }
        return self._fields

    def buckets(self) -> list[np.ndarray]:
        """Per-core global-column lists, re-derived from ``col_perm`` (the
        single-core artifact owns every column)."""
        if self.cores <= 1:
            return [np.arange(self.nt, dtype=np.int64)]
        perm = np.asarray(self.art.col_perm, dtype=np.int64)
        w = self.width
        return [
            perm[c * w : (c + 1) * w][perm[c * w : (c + 1) * w] >= 0]
            for c in range(self.cores)
        ]


def _capped(out: list, findings: list[Finding], rule: str):
    """Append ``findings`` to ``out``, collapsing the overflow into a count."""
    out.extend(findings[:_MAX_PER_RULE])
    extra = len(findings) - _MAX_PER_RULE
    if extra > 0:
        f0 = findings[0]
        out.append(
            dataclasses.replace(
                f0, detail=f"... and {extra} more {rule} finding(s)"
            )
        )


def _rule_geometry(v: _QView, mk) -> list[Finding]:
    out = []
    if len(v.grid) != 3 or any(g < 1 for g in v.grid):
        out.append(mk("plan/geometry", f"grid_tiles {v.grid} not 3 positive tile counts"))
        return out
    if v.bmask.shape != (v.kt, v.nt):
        out.append(
            mk("plan/geometry",
               f"w_bmask shape {v.bmask.shape} != (Kt, Nt) = {(v.kt, v.nt)}")
        )
        return out
    packed = np.asarray(v.art.packed)
    if packed.ndim != 3 or packed.shape[1:] != (v.bk, v.bn):
        out.append(
            mk("plan/geometry",
               f"packed shape {packed.shape} != [nnzb, {v.bk}, {v.bn}]")
        )
    if v.cores > 1:
        missing = [
            n for n in ("col_perm", "col_inv", "core_steps", "core_cost")
            if getattr(v.art, n, None) is None
        ]
        if missing:
            out.append(
                mk("plan/geometry",
                   f"multi-core artifact missing {missing} (cores={v.cores})")
            )
            return out
        want_blocks = sum(
            max(1, int(v.bmask[:, b].sum())) for b in v.buckets()
        )
    else:
        want_blocks = max(1, int(v.bmask.sum()))
    if not out and packed.shape[0] != want_blocks:
        out.append(
            mk("plan/geometry",
               f"packed holds {packed.shape[0]} blocks, weight mask implies "
               f"{want_blocks} (Σ per-core max(1, nnz))")
        )
    if not v.consistent:
        shapes = {n: f.shape for n, f in v.fields.items()}
        out.append(
            mk("plan/geometry", f"queue arrays disagree on shape: {shapes}")
        )
    elif v.cores > 1 and v.rows != v.cores:
        out.append(
            mk("plan/geometry",
               f"queue arrays have {v.rows} rows, artifact says cores={v.cores}")
        )
    return out


def _rule_step_classes(v: _QView, mk) -> list[Finding]:
    s, l, va = v.fields["start"], v.fields["last"], v.fields["valid"]
    out, found = [], []
    for name, arr in (("start", s), ("last", l), ("valid", va)):
        bad = (arr != 0) & (arr != 1)
        if bad.any():
            r, t = np.argwhere(bad)[0]
            found.append(
                mk("queue/step-classes",
                   f"{name} not 0/1 at core {r} step {t}: {arr[r, t]}")
            )
    # Legal classes: MAC (valid=1, any flags), zero-write (1,1,0), inert
    # (0,0,0).  (1,0,0) / (0,1,0) would zero-without-flush / flush-stale.
    illegal = (va == 0) & (s != l)
    if illegal.any():
        r, t = np.argwhere(illegal)[0]
        found.append(
            mk("queue/step-classes",
               f"illegal step class (start={s[r, t]}, last={l[r, t]}, "
               f"valid=0) at core {r} step {t}: a valid=0 step must be a "
               f"zero-write (1,1,0) or inert (0,0,0)")
        )
    _capped(out, found, "step-class")
    return out


def _rule_run_structure(v: _QView, mk) -> list[Finding]:
    found = []
    for r in range(v.rows):
        real = int(v.reals[r])
        if real < 1 or real > v.q:
            found.append(
                mk("queue/run-structure",
                   f"core {r}: real step count {real} outside [1, {v.q}]")
            )
            continue
        s = v.fields["start"][r, :real]
        l = v.fields["last"][r, :real]
        if s[0] != 1:
            found.append(
                mk("queue/run-structure", f"core {r}: queue does not open a run (start[0]={s[0]})")
            )
        if l[-1] != 1:
            found.append(
                mk("queue/run-structure",
                   f"core {r}: last real step does not flush (last[{real - 1}]={l[-1]})")
            )
        mism = np.flatnonzero(s[1:] != l[:-1])
        if mism.size:
            t = int(mism[0]) + 1
            found.append(
                mk("queue/run-structure",
                   f"core {r} step {t}: start={s[t]} after last={l[t - 1]} — "
                   f"accumulation runs must be contiguous")
            )
            continue  # derived run shape is unreliable past this point
        cont = np.flatnonzero(s[1:] == 0) + 1  # within-run continuation steps
        if cont.size:
            mi, ni = v.fields["mi"][r], v.fields["ni"][r]
            ki = v.fields.get("ki", [None])
            ki = ki[r] if ki[0] is not None else (
                v.fields["flat_ak"][r] - mi * v.kt
            )
            drift = (mi[cont] != mi[cont - 1]) | (ni[cont] != ni[cont - 1])
            if drift.any():
                t = int(cont[np.argmax(drift)])
                found.append(
                    mk("queue/run-structure",
                       f"core {r} step {t}: (mi, ni) changed mid-run "
                       f"({mi[t - 1]},{ni[t - 1]}) -> ({mi[t]},{ni[t]})")
                )
            nonasc = ki[cont] <= ki[cont - 1]
            if nonasc.any():
                t = int(cont[np.argmax(nonasc)])
                found.append(
                    mk("queue/run-structure",
                       f"core {r} step {t}: k-tile not strictly ascending "
                       f"within its run ({ki[t - 1]} -> {ki[t]})")
                )
    out = []
    _capped(out, found, "run-structure")
    return out


def _rule_coverage(v: _QView, mk) -> list[Finding]:
    found = []
    for r in range(v.rows):
        real = int(min(max(v.reals[r], 0), v.q))
        l = v.fields["last"][r, :real]
        mi = v.fields["mi"][r, :real][l == 1]
        ni = v.fields["ni"][r, :real][l == 1]
        flushed = np.sort(mi * v.width + ni)
        want = np.arange(v.mt * v.width, dtype=np.int64)
        if flushed.shape != want.shape or not np.array_equal(flushed, want):
            cnt = np.bincount(
                flushed[(flushed >= 0) & (flushed < v.mt * v.width)],
                minlength=v.mt * v.width,
            )
            missing = int((cnt == 0).sum())
            dupes = int((cnt > 1).sum())
            found.append(
                mk("queue/coverage",
                   f"core {r}: output tiles not flushed exactly once "
                   f"({len(flushed)} flushes for {v.mt}×{v.width} tiles; "
                   f"{missing} missing, {dupes} duplicated)")
            )
    out = []
    _capped(out, found, "coverage")
    return out


def _rule_bounds(v: _QView, mk) -> list[Finding]:
    found = []
    f = v.fields
    nblocks = int(np.asarray(v.art.packed).shape[0])
    ki_all = f["ki"] if "ki" in f else f["flat_ak"] - f["mi"] * v.kt
    for name, arr, hi in (
        ("mi", f["mi"], v.mt),
        ("ni", f["ni"], v.width),
        ("ki", ki_all, v.kt),
        ("wq", f["wq"], nblocks),
    ):
        bad = (arr < 0) | (arr >= hi)
        if bad.any():
            r, t = np.argwhere(bad)[0]
            found.append(
                mk("queue/bounds",
                   f"{name} out of range at core {r} step {t}: "
                   f"{arr[r, t]} not in [0, {hi})")
            )
    mism = f["flat_ak"] != f["mi"] * v.kt + ki_all
    if mism.any():
        r, t = np.argwhere(mism)[0]
        found.append(
            mk("queue/bounds",
               f"flat_ak inconsistent at core {r} step {t}: "
               f"{f['flat_ak'][r, t]} != mi·Kt + ki = "
               f"{f['mi'][r, t] * v.kt + ki_all[r, t]}")
        )
    if found:  # index fields unreliable: skip the wq / offset re-derivation
        out = []
        _capped(out, found, "bounds")
        return out
    # wq re-derivation: per-core packed-block ids in (ni-major, ki) order
    # over the core's bucket sub-mask, plus the concatenation offset — the
    # exact construction of pack_blocks / pack_multicore_blocks.
    off = 0
    for r, bucket in enumerate(v.buckets()):
        sub = v.bmask[:, bucket]
        wq_id = np.full(sub.shape, -1, dtype=np.int64)
        wq_id.T[sub.T] = np.arange(int(sub.sum()), dtype=np.int64)
        macs = f["valid"][r] == 1
        ni_r, ki_r, wq_r = f["ni"][r][macs], ki_all[r][macs], f["wq"][r][macs]
        dead = ni_r >= sub.shape[1]
        if dead.any():
            t = int(np.flatnonzero(macs)[np.argmax(dead)])
            found.append(
                mk("queue/bounds",
                   f"core {r} step {t}: MAC step on padding column "
                   f"ni={f['ni'][r, t]} (bucket holds {sub.shape[1]} columns)")
            )
        else:
            want = np.where(sub.shape[1] > 0, -1, -1) * np.ones_like(wq_r)
            if sub.shape[1]:
                want = wq_id[ki_r, ni_r]
            on_zero = want < 0
            if on_zero.any():
                t = int(np.flatnonzero(macs)[np.argmax(on_zero)])
                found.append(
                    mk("queue/bounds",
                       f"core {r} step {t}: MAC step addresses a zero weight "
                       f"tile (ki={ki_all[r, t]}, ni={f['ni'][r, t]})")
                )
            else:
                mism = wq_r != want + off
                if mism.any():
                    t = int(np.flatnonzero(macs)[np.argmax(mism)])
                    found.append(
                        mk("queue/bounds",
                           f"core {r} step {t}: wq={f['wq'][r, t]} but the "
                           f"packed payload stores this tile at "
                           f"{int(want[np.argmax(mism)]) + off}")
                    )
        off += max(1, int(sub.sum()))
    if v.conv_ctx is not None:
        found += _conv_offset_findings(v, ki_all, mk)
    out = []
    _capped(out, found, "bounds")
    return out


def _conv_offset_findings(v: _QView, ki_all, mk) -> list:
    """Re-derive the direct-conv per-step source offsets from the k-index
    decomposition ``ki = (ky·kw + kx)·ct + ci`` and the conv geometry —
    exactly ``_prepare_direct``'s lowering."""
    ctx = v.conv_ctx
    kw, ct = ctx["kw"], ctx["ct"]
    sh, sw, oh, bk = ctx["sh"], ctx["sw"], ctx["oh"], v.bk
    f = v.fields
    ky, kx, ci = ki_all // (kw * ct), (ki_all // ct) % kw, ki_all % ct
    want = {
        "ph": (ky % sh) * sw + kx % sw,
        "nb": f["mi"] // oh,
        "r0": f["mi"] % oh + ky // sh,
        "c0": kx // sw,
        "ch0": ci * bk,
    }
    found = []
    for name, w in want.items():
        mism = f[name] != w
        if mism.any():
            r, t = np.argwhere(mism)[0]
            found.append(
                mk("queue/bounds",
                   f"conv offset {name} at core {r} step {t}: "
                   f"{f[name][r, t]} != re-derived {w[r, t]}")
            )
    return found


def _rule_inert_tail(v: _QView, mk) -> list[Finding]:
    found = []
    s, l, va = v.fields["start"], v.fields["last"], v.fields["valid"]
    inert = (s == 0) & (l == 0) & (va == 0)
    idx = np.arange(v.q)
    for r in range(v.rows):
        real = int(v.reals[r])
        in_tail = idx >= real
        early = inert[r] & ~in_tail
        if early.any():
            t = int(np.argmax(early))
            found.append(
                mk("queue/inert-tail",
                   f"core {r} step {t}: inert step inside the real queue "
                   f"(real length {real})")
            )
        live_tail = in_tail & ~inert[r]
        if live_tail.any():
            t = int(np.argmax(live_tail))
            found.append(
                mk("queue/inert-tail",
                   f"core {r} step {t}: makespan-padding step is not inert "
                   f"(start={s[r, t]}, last={l[r, t]}, valid={va[r, t]})")
            )
        if real < v.q and real >= 1:
            for name, arr in v.fields.items():
                if name in ("start", "last", "valid"):
                    continue
                drift = arr[r, real:] != arr[r, real - 1]
                if drift.any():
                    t = real + int(np.argmax(drift))
                    found.append(
                        mk("queue/inert-tail",
                           f"core {r} step {t}: tail {name}={arr[r, t]} does "
                           f"not repeat the last real step's {arr[r, real - 1]}"
                           f" — a tail revisit would smear a stale buffer")
                    )
                    break
    out = []
    _capped(out, found, "inert-tail")
    return out


def _rule_cores(v: _QView, mk) -> list[Finding]:
    if v.cores <= 1:
        return []
    from repro.core.balance import inter_core_schedule

    out = []
    perm = np.asarray(v.art.col_perm, dtype=np.int64)
    inv = np.asarray(v.art.col_inv, dtype=np.int64)
    w = v.width
    want_w = max(1, math.ceil(v.nt / v.cores))
    if w != want_w:
        out.append(
            mk("cores/partition",
               f"local_nt={w} != ceil(Nt / cores) = {want_w}")
        )
    if perm.shape != (v.cores * w,):
        out.append(
            mk("cores/partition",
               f"col_perm shape {perm.shape} != (cores·local_nt,) = "
               f"({v.cores * w},)")
        )
        return out
    if ((perm < -1) | (perm >= v.nt)).any():
        out.append(
            mk("cores/partition",
               f"col_perm entries outside [-1, {v.nt}): "
               f"{perm[(perm < -1) | (perm >= v.nt)][:4].tolist()}")
        )
        return out
    live = perm >= 0
    vals = np.sort(perm[live])
    if not np.array_equal(vals, np.arange(v.nt)):
        out.append(
            mk("cores/partition",
               f"live col_perm entries are not a permutation of the {v.nt} "
               f"output tile-columns (got {vals.tolist()[:8]}...)")
        )
        return out
    seg = live.reshape(v.cores, w)
    ragged = seg[:, 1:] & ~seg[:, :-1]
    if ragged.any():
        c = int(np.argwhere(ragged)[0][0])
        out.append(
            mk("cores/partition",
               f"core {c}: live columns not prefix-packed before the -1 "
               f"padding slots")
        )
    if inv.shape != (v.nt,) or not np.array_equal(
        inv[perm[live]], np.flatnonzero(live)
    ):
        out.append(
            mk("cores/partition",
               "col_inv is not the inverse of col_perm's live entries — the "
               "output stitch would permute columns")
        )
    # Gauges + schedule legality: re-derive everything from the weight mask.
    dens = v.bmask.sum(axis=0).astype(np.int64)
    buckets = v.buckets()
    core_cost = np.asarray(v.art.core_cost, dtype=np.int64)
    core_steps = np.asarray(v.art.core_steps, dtype=np.int64)
    for c, b in enumerate(buckets):
        want_cost = int(dens[b].sum())
        if int(core_cost[c]) != want_cost:
            out.append(
                mk("cores/gauges",
                   f"core {c}: core_cost={int(core_cost[c])} != Σ column "
                   f"popcounts {want_cost}")
            )
        zero_cols = int((dens[b] == 0).sum())
        want_steps = v.mt * (want_cost + zero_cols + (w - len(b)))
        if int(core_steps[c]) != want_steps:
            out.append(
                mk("cores/gauges",
                   f"core {c}: core_steps={int(core_steps[c])} != re-derived "
                   f"MACs + zero-writes + column padding = {want_steps}")
            )
    if v.consistent and int(core_steps.max(initial=0)) != v.q:
        out.append(
            mk("cores/gauges",
               f"queue padded to {v.q} steps but max(core_steps) = "
               f"{int(core_steps.max(initial=0))} — not makespan padding")
        )
    sched = inter_core_schedule(
        dens.astype(np.float64), v.cores, balanced=True, capacity=w
    )
    lpt = all(
        np.array_equal(np.asarray(a, dtype=np.int64), b)
        for a, b in zip(sched.assignment, buckets)
    )
    naive = all(
        np.array_equal(np.arange(c, v.nt, v.cores, dtype=np.int64), b)
        for c, b in enumerate(buckets)
    )
    if not (lpt or naive):
        out.append(
            mk("cores/partition",
               "column buckets match neither the balanced LPT schedule "
               "(inter_core_schedule) nor the naive round-robin — unknown "
               "partition policy")
        )
    return out


def _rule_lookahead(v: _QView, mk, *, deep=True) -> list[Finding]:
    from repro.kernels.compaction import compaction_meta

    la = getattr(v.art, "lookahead", 0)
    cmeta = getattr(v.art, "cmeta", None)
    out = []
    if not isinstance(la, (int, np.integer)) or int(la) < 0:
        out.append(mk("lookahead/cmeta", f"lookahead={la!r} is not an int >= 0"))
        return out
    if int(la) == 0:
        if cmeta is not None:
            out.append(
                mk("lookahead/cmeta",
                   "artifact carries compaction metadata but lookahead=0 "
                   "(the gated path never consumes it)")
            )
        return out
    if not isinstance(cmeta, dict) or set(cmeta) != {"seg_base", "seg_end", "pad"}:
        out.append(
            mk("lookahead/cmeta",
               f"lookahead={int(la)} but cmeta keys are "
               f"{sorted(cmeta) if isinstance(cmeta, dict) else cmeta!r} "
               f"(want seg_base/seg_end/pad)")
        )
        return out
    if not deep:
        # The O(steps) re-derivation below belongs to the deep tier; the
        # presence/shape contract above is the always-on half.
        return out
    start = np.asarray(v.art.start)
    if v.cores > 1:
        want = compaction_meta(start, np.asarray(v.art.core_steps))
    else:
        want = compaction_meta(start)
    for key in ("seg_base", "seg_end", "pad"):
        got = np.asarray(cmeta[key])
        if got.shape != np.asarray(want[key]).shape or not np.array_equal(
            got, want[key]
        ):
            out.append(
                mk("lookahead/cmeta",
                   f"cmeta[{key!r}] differs from compaction_meta re-derivation"
                   f" — runtime compaction would mis-place segments")
            )
    return out


def _queue_findings(
    art, *, conv_ctx=None, layer=None, batch=None, deep=True
) -> list[Finding]:
    """All queue/cores/lookahead/geometry rules over one queue artifact.

    ``deep=False`` restricts to the rules whose cost is independent of the
    queue length (geometry, partition, gauges, the static half of the
    lookahead contract) — the verify-on-load tier, bounded < 5% of load
    time by ``kernel_bench``.  ``deep=True`` adds the per-step scans
    (step classes, run structure, coverage, bounds, inert tail, cmeta
    re-derivation) — the compile-time / CLI / CI tier.
    """

    def mk(rule, detail, level="error"):
        return Finding(rule, detail, layer=layer, batch=batch, level=level)

    v = _QView(art, conv_ctx)
    out = _rule_geometry(v, mk)
    if not v.consistent:
        # Shape-inconsistent queues would turn every later rule into a numpy
        # broadcast crash; report the geometry finding and stop here.
        return out
    if deep:
        out += _rule_step_classes(v, mk)
        out += _rule_run_structure(v, mk)
        out += _rule_coverage(v, mk)
        out += _rule_bounds(v, mk)
        out += _rule_inert_tail(v, mk)
    out += _rule_cores(v, mk)
    out += _rule_lookahead(v, mk, deep=deep)
    return out


# -- artifact dispatch --------------------------------------------------------


def _conv_wrapper_findings(pcw, spec, batch, layer, *, deep=True) -> list[Finding]:
    from repro.kernels.phantom_conv import conv_geometry

    out = []

    def err(rule, detail):
        out.append(Finding(rule, detail, layer=layer, batch=batch))

    if pcw.mode not in ("direct", "im2col"):
        err("plan/geometry", f"unknown conv lowering mode {pcw.mode!r}")
        return out
    inner = pcw.plan if pcw.mode == "direct" else pcw.pw
    other = pcw.pw if pcw.mode == "direct" else pcw.plan
    if inner is None or other is not None:
        err("plan/geometry",
            f"mode={pcw.mode!r} but plan is {'set' if pcw.plan is not None else 'None'}"
            f" and pw is {'set' if pcw.pw is not None else 'None'}")
        return out
    sh, sw = pcw.stride
    try:
        oh, ow, _ = conv_geometry(
            pcw.in_hw[0], pcw.in_hw[1], pcw.kh, pcw.kw, (sh, sw), pcw.padding
        )
    except ValueError as e:
        err("plan/geometry", f"conv geometry no longer resolves: {e}")
        return out
    if tuple(pcw.out_hw) != (oh, ow):
        err("plan/geometry",
            f"out_hw={tuple(pcw.out_hw)} != conv_geometry {(oh, ow)}")
    if spec is not None and hasattr(spec, "kh"):
        want_groups = spec.in_ch if getattr(spec, "depthwise", False) else 1
        for name, got, want in (
            ("kh", pcw.kh, spec.kh),
            ("kw", pcw.kw, spec.kw),
            ("stride", tuple(pcw.stride), tuple(spec.stride)),
            ("in_ch", pcw.in_ch, spec.in_ch),
            ("out_ch", pcw.out_ch, spec.out_ch),
            ("groups", pcw.groups, want_groups),
            ("in_hw", tuple(pcw.in_hw), (spec.in_h, spec.in_w)),
            ("padding", pcw.padding, spec.pad.upper()),
        ):
            if got != want:
                err("plan/geometry",
                    f"conv artifact {name}={got!r} != spec's {want!r}")
    if batch is not None and int(pcw.batch) != int(batch):
        err("plan/geometry",
            f"plan lowered for batch {pcw.batch} but cached under batch {batch}")
    blk = tuple(inner.block)
    bk, bn = int(blk[-2]), int(blk[-1])
    if pcw.mode == "direct":
        ct = int(inner.ct)
        want_ct = math.ceil(pcw.in_ch / bk)
        if ct != want_ct:
            err("plan/geometry", f"ct={ct} != ceil(in_ch / bk) = {want_ct}")
        want_grid = (
            pcw.batch * oh,
            pcw.kh * pcw.kw * ct,
            math.ceil(pcw.out_ch / bn),
        )
        if tuple(inner.grid_tiles) != want_grid:
            err("plan/geometry",
                f"direct grid_tiles {tuple(inner.grid_tiles)} != "
                f"(B·oh, kh·kw·ct, Nt) = {want_grid}")
        want_phase = (
            sh * sw, pcw.batch, oh + (pcw.kh - 1) // sh,
            ow + (pcw.kw - 1) // sw, ct * bk,
        )
        if tuple(inner.phase_shape) != want_phase:
            err("plan/geometry",
                f"phase_shape {tuple(inner.phase_shape)} != {want_phase}")
        ctx = dict(kw=pcw.kw, ct=ct, sh=sh, sw=sw, oh=oh)
        out += _queue_findings(
            inner, conv_ctx=ctx, layer=layer, batch=batch, deep=deep
        )
    else:
        k_rows = pcw.kh * pcw.kw * pcw.in_ch
        if tuple(inner.shape) != (k_rows, pcw.out_ch):
            err("plan/geometry",
                f"im2col pw.shape {tuple(inner.shape)} != "
                f"(kh·kw·Cin, Cout) = {(k_rows, pcw.out_ch)}")
        bm = int(inner.block[0])
        want_grid = (
            math.ceil(pcw.batch * oh * ow / bm),
            math.ceil(k_rows / bk),
            math.ceil(pcw.out_ch / bn),
        )
        if tuple(inner.grid_tiles) != want_grid:
            err("plan/geometry",
                f"im2col grid_tiles {tuple(inner.grid_tiles)} != {want_grid}")
        out += _queue_findings(inner, layer=layer, batch=batch, deep=deep)
    return out


def check_artifact(
    art, *, spec=None, batch=None, layer=None, deep=True
) -> list[Finding]:
    """Run every applicable rule over one prepared plan artifact.

    Dispatches on the artifact type (``PhantomConvWeight`` wrapper,
    ``PhantomWeight`` / ``DirectConvPlan`` queue artifacts, dicts of them —
    the FFN kind); unknown plan types are skipped (custom kinds verify what
    they register).  ``spec`` enables the spec-aware geometry cross-checks.
    ``deep=False`` skips the O(steps) queue scans (see ``_queue_findings``).
    Returns findings; raises nothing.
    """
    from repro.kernels.ops import PhantomWeight
    from repro.kernels.phantom_conv import DirectConvPlan, PhantomConvWeight

    if isinstance(art, PhantomConvWeight):
        return _conv_wrapper_findings(art, spec, batch, layer, deep=deep)
    if isinstance(art, (PhantomWeight, DirectConvPlan)):
        out = []
        if (
            isinstance(art, PhantomWeight)
            and spec is not None
            and hasattr(spec, "in_dim")
        ):
            bm, bk, bn = (int(b) for b in art.block)
            if tuple(art.shape) != (spec.in_dim, spec.out_dim):
                out.append(Finding(
                    "plan/geometry",
                    f"fc pw.shape {tuple(art.shape)} != "
                    f"(in_dim, out_dim) = {(spec.in_dim, spec.out_dim)}",
                    layer=layer, batch=batch,
                ))
            elif batch is not None:
                want = (
                    math.ceil(int(batch) / bm),
                    math.ceil(spec.in_dim / bk),
                    math.ceil(spec.out_dim / bn),
                )
                if tuple(art.grid_tiles) != want:
                    out.append(Finding(
                        "plan/geometry",
                        f"fc grid_tiles {tuple(art.grid_tiles)} != {want}",
                        layer=layer, batch=batch,
                    ))
        return out + _queue_findings(art, layer=layer, batch=batch, deep=deep)
    if isinstance(art, dict):
        out = []
        for key, sub in art.items():
            if isinstance(sub, (PhantomWeight, DirectConvPlan, PhantomConvWeight, dict)):
                out += check_artifact(
                    sub, batch=batch,
                    layer=f"{layer}/{key}" if layer else str(key),
                    deep=deep,
                )
        return out
    return []


# -- program-level rules ------------------------------------------------------


def _graph_findings(prog) -> list[Finding]:
    from repro.program.plans import build_nodes
    from repro.program.registry import kind_for

    out = []
    try:
        rebuilt = build_nodes(prog.layers, cfg=prog.cfg, overrides=prog.overrides)
    except Exception as e:
        return [Finding(
            "graph/mask-flow",
            f"node graph no longer rebuilds from (layers, cfg, overrides): {e}",
        )]
    if len(rebuilt) != len(prog.nodes):
        out.append(Finding(
            "graph/mask-flow",
            f"program holds {len(prog.nodes)} nodes but the layer list "
            f"rebuilds to {len(rebuilt)}",
        ))
    else:
        for i, (got, want) in enumerate(zip(prog.nodes, rebuilt)):
            if got != want:
                diffs = [
                    f.name for f in dataclasses.fields(got)
                    if getattr(got, f.name) != getattr(want, f.name)
                ]
                out.append(Finding(
                    "graph/mask-flow",
                    f"node {i} diverges from the §3.8 rebuild in {diffs} "
                    f"(glue / τ-at-producer / last-layer contract)",
                    layer=getattr(got, "name", None),
                ))
    for node in prog.nodes:
        try:
            kind = kind_for(node.spec)
        except KeyError as e:
            out.append(Finding("graph/mask-flow", str(e), layer=node.name))
            continue
        missing = [
            m for m in ("prepare", "apply", "mask_out", "stats")
            if not callable(getattr(kind, m, None))
        ]
        if missing or not isinstance(getattr(kind, "name", None), str):
            out.append(Finding(
                "graph/mask-flow",
                f"layer kind {type(kind).__name__} does not implement the "
                f"full LayerKind protocol (missing: "
                f"{missing + ([] if isinstance(getattr(kind, 'name', None), str) else ['name'])})",
                layer=node.name,
            ))
    return out


def _override_findings(prog) -> list[Finding]:
    from repro.core.blocksparse import BALANCE_MODES

    out = []
    names = {spec.name for spec in prog.layers}
    for lname, ov in prog.overrides.items():
        def err(detail, level="error"):
            out.append(Finding("config/overrides", detail, layer=lname, level=level))

        if lname not in names:
            err(f"override names unknown layer {lname!r}")
            continue
        if not isinstance(ov, dict):
            err(f"override is {type(ov).__name__}, not a field dict")
            continue
        try:
            prog.cfg.with_overrides(**ov)
        except (TypeError, ValueError) as e:
            err(f"override does not resolve against PhantomConfig: {e}")
            continue
        for field, val in ov.items():
            if field == "balance" and val not in BALANCE_MODES:
                err(f"balance={val!r} not in {BALANCE_MODES}")
            elif field == "conv_mode" and val not in ("direct", "im2col"):
                err(f"conv_mode={val!r} not in ('direct', 'im2col')")
            elif field == "cores" and (
                not isinstance(val, (int, np.integer)) or val < 1
            ):
                err(f"cores={val!r} is not an int >= 1")
            elif field == "lookahead" and val is not None and (
                not isinstance(val, (int, np.integer)) or val < 0
            ):
                err(f"lookahead={val!r} is not None or an int >= 0")
            elif field == "block" and (
                len(tuple(val)) != 3
                or any(not isinstance(b, (int, np.integer)) or b < 1
                       for b in tuple(val))
            ):
                err(f"block={val!r} is not a 3-tuple of positive tile sizes")
            elif field == "mode" and val not in ("dense", "masked", "kernel", "auto"):
                err(f"mode={val!r} not in ('dense', 'masked', 'kernel', 'auto')")
        # Live-search-space membership is advisory (warn): explicit caller
        # overrides may legitimately sit outside what tune="search" explores,
        # but a *tuned* program drifting out of the space means the cache or
        # the space moved — surface it.
        try:
            from repro.tune.space import override_in_space

            if not override_in_space(ov, prog.cfg):
                err(
                    f"override {ov!r} is outside the live tune search space "
                    f"(repro.tune.space.DEFAULT_SPACE): tune='search' can no "
                    f"longer reproduce this config",
                    level="warn",
                )
        except ImportError:  # pragma: no cover - tuner is an optional layer
            pass
    return out


def check_program(
    prog, *, batches=None, graph: bool = True, deep: bool = True
) -> list[Finding]:
    """Run the full rule set over a program; returns findings, raises nothing.

    ``batches``: iterable of lowered batch sizes to check (default: all
    cached plans; pass ``()`` for graph-only).  ``graph=False`` skips the
    graph/override rules (used by the per-batch hook in ``at_batch``, which
    verified the graph at compile time already).  ``deep=False`` keeps only
    the rules whose cost is independent of queue length — the fast
    verify-on-load tier (see ``_queue_findings``).
    """
    findings: list[Finding] = []
    if graph:
        findings += _graph_findings(prog)
        findings += _override_findings(prog)
    plans = prog._plans
    if batches is None:
        sel = dict(plans)
    else:
        sel = {int(b): plans[int(b)] for b in batches if int(b) in plans}
    node_names = {node.name for node in prog.nodes}
    for b in sorted(sel):
        prepared = sel[b]
        for node in prog.nodes:
            if node.name not in prepared:
                findings.append(Finding(
                    "plan/geometry", "layer has no prepared plan",
                    layer=node.name, batch=b,
                ))
                continue
            findings += check_artifact(
                prepared[node.name], spec=node.spec, batch=b, layer=node.name,
                deep=deep,
            )
        for extra in sorted(set(prepared) - node_names):
            findings.append(Finding(
                "plan/geometry", "prepared plan for a layer not in the graph",
                layer=extra, batch=b,
            ))
    return findings


def verify_program(
    prog, *, path=None, batches=None, graph: bool = True, deep: bool = True
):
    """Verify and enforce: raise :class:`VerifyError` on error findings,
    emit one :class:`UserWarning` for warn findings.  Returns the findings
    (all of them) when no error-level finding exists."""
    findings = check_program(prog, batches=batches, graph=graph, deep=deep)
    warns = [f for f in findings if f.level == "warn"]
    errors = [f for f in findings if f.level != "warn"]
    if warns:
        warnings.warn(
            "phantom verify: " + "; ".join(f.format() for f in warns),
            UserWarning,
            stacklevel=2,
        )
    if errors:
        raise VerifyError(errors, path=path)
    return findings
