"""Static program verifier for compiled Phantom artifacts (DESIGN.md §13).

Public surface:

- :class:`Finding` / :class:`VerifyError` — structured diagnostics naming
  the failed rule, layer and batch.
- :func:`check_artifact` / :func:`check_program` — pure rule runners that
  return findings without raising.
- :func:`verify_program` — the enforcement wrapper used by
  ``phantom.compile(verify=True)`` and ``PhantomProgram.load``.
- :func:`artifact_fingerprint` / :data:`VERIFY_SCHEMA` — the serialized
  content-hash contract stamped by ``save`` and checked at load.
- ``python -m repro.verify <artifact>`` / ``--self-check`` — the CI entry
  points (see :mod:`repro.verify.__main__`).
"""
from repro.verify.rules import (
    VERIFY_SCHEMA,
    Finding,
    VerifyError,
    artifact_fingerprint,
    check_artifact,
    check_program,
    verify_program,
)

__all__ = [
    "VERIFY_SCHEMA",
    "Finding",
    "VerifyError",
    "artifact_fingerprint",
    "check_artifact",
    "check_program",
    "verify_program",
]
