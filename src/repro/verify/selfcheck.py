"""Verifier self-check: clean grid + seeded-mutation matrix (DESIGN.md §13).

Two halves, both required by the tier-1 CI step
``python -m repro.verify --self-check``:

1. **Clean grid** — the verifier must pass with zero findings on
   ``phantom.compile`` of the paper's §5.1 evaluation networks (VGG16 and
   MobileNetV1, reduced resolution) across the full
   ``{conv_mode} × {cores=1,4} × {lookahead=0,L}`` grid.  A false positive
   here means a rule misstates an invariant the real pipeline establishes.

2. **Mutation matrix** — one seeded corruption per verifier rule, applied
   to a known-good compiled program (or its saved artifact), each asserting
   the *specific* rule catches it.  A rule that catches nothing is dead
   code; the matrix is the liveness proof, re-run on every CI build so a
   future scheduling change cannot silently lobotomise a rule.

The mutation program is crafted, not random: the conv layer's column
blocks carry unequal densities (1/3/5/7 of 9 k-tiles) so the 4-core
partition has a guaranteed inert makespan tail, and the FC layer has a
fully-zero column block so zero-write steps exist.  Shared with
``tests/test_verify.py`` so pytest and the CLI exercise the same matrix.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile

import numpy as np

from repro.verify.rules import (
    VerifyError,
    artifact_fingerprint,
    check_program,
)

__all__ = [
    "build_mutation_program",
    "clean_grid",
    "mutation_matrix",
    "restamp_fingerprint",
    "run_selfcheck",
]


# -- clean grid ---------------------------------------------------------------

#: (model, conv_mode, cores, lookahead) — the acceptance grid.
GRID = [
    (model, conv_mode, cores, la)
    for model in ("vgg16", "mobilenet")
    for conv_mode in ("direct", "im2col")
    for cores in (1, 4)
    for la in (0, 4)
]


def clean_grid(input_hw: int = 32, batch: int = 1, block: int = 32):
    """Compile VGG16 + MobileNet across the grid with ``verify=True``.

    Yields ``(label, error-or-None)`` per grid point — compile itself runs
    the verifier at every lowering, so a yielded ``None`` means zero
    error-level findings on every layer's plan.
    """
    import phantom
    from repro.core.phantom_linear import PhantomConfig
    from repro.tune.__main__ import _MODELS, build_params

    for model, conv_mode, cores, la in GRID:
        make, wd, _ = _MODELS[model]
        layers = make(include_fc=True, input_hw=input_hw)
        cfg = PhantomConfig(
            enabled=True,
            block=(block,) * 3,
            conv_mode=conv_mode,
            cores=cores,
            lookahead=la,
        )
        params = build_params(layers, wd, cfg, seed=0)
        label = f"{model}/{conv_mode}/cores={cores}/lookahead={la}"
        try:
            phantom.compile(layers, params, cfg, batch=batch, verify=True)
        except VerifyError as e:
            yield label, e
        else:
            yield label, None


# -- mutation matrix ----------------------------------------------------------


def build_mutation_program():
    """A small known-good program with every structure the rules exercise:
    a 4-core direct conv with lookahead (unequal column densities → inert
    tail, padding columns), plus a single-core FC with a zero column block
    (zero-write steps).  Compiled with ``verify=False`` so mutations are
    applied to an unchecked object."""
    import phantom
    from repro.core.dataflow import ConvSpec, FCSpec
    from repro.core.phantom_linear import PhantomConfig

    layers = [
        ConvSpec("c1", in_ch=16, out_ch=64, in_h=12, in_w=12, kh=3, kw=3),
        FCSpec("fc", in_dim=64, out_dim=48, pool="gap"),
    ]
    cfg = PhantomConfig(enabled=True, block=(16, 16, 16))
    rng = np.random.default_rng(0)
    # conv: K = 3·3·16 = 144 rows → 9 k-tiles; 4 column blocks with
    # 1/3/5/7 live k-tiles → per-core costs 1,3,5,7 under cores=4.
    wc = rng.standard_normal((144, 64)).astype(np.float32) * 0.05
    for j in range(4):
        wc[(2 * j + 1) * 16 :, j * 16 : (j + 1) * 16] = 0.0
    # fc: 4 k-tiles × 3 column blocks; the last column block is all-zero,
    # so its output tiles are covered by §3.8 zero-write steps.
    wf = rng.standard_normal((64, 48)).astype(np.float32) * 0.05
    wf[:, 32:] = 0.0
    params = {
        "c1": {"w": wc.reshape(3, 3, 16, 64), "b": np.zeros(64, np.float32)},
        "fc": {"w": wf, "b": np.zeros(48, np.float32)},
    }
    overrides = {"c1": {"cores": 4, "lookahead": 8, "balance": "full"}}
    return phantom.compile(
        layers, params, cfg, batch=2, overrides=overrides, verify=False
    )


def _conv_plan(prog):
    return prog._plans[2]["c1"].plan


def _fc_pw(prog):
    return prog._plans[2]["fc"]


def _mut_step_classes(prog):
    pw = _fc_pw(prog)
    s, l, v = map(np.asarray, (pw.start, pw.last, pw.valid))
    t = int(np.flatnonzero((s == 1) & (l == 0))[0])
    v[t] = 0  # (1, 0, 0): zeroes the accumulator mid-run without a flush


def _mut_run_structure(prog):
    np.asarray(_fc_pw(prog).start)[0] = 0  # queue no longer opens a run


def _mut_coverage(prog):
    pw = _fc_pw(prog)
    s, l, v, ni = map(np.asarray, (pw.start, pw.last, pw.valid, pw.ni))
    # retarget a zero-write (single-step run: start=last=1, valid=0) onto a
    # column another run already flushes → duplicate + missing tile
    t = int(np.flatnonzero((s == 1) & (l == 1) & (v == 0))[0])
    ni[t] = 0


def _mut_bounds(prog):
    pw = _fc_pw(prog)
    t = int(np.flatnonzero(np.asarray(pw.valid) == 1)[0])
    np.asarray(pw.wq)[t] = np.asarray(pw.packed).shape[0] + 3


def _mut_inert_tail(prog):
    plan = _conv_plan(prog)
    c = int(np.argmin(np.asarray(plan.core_steps)))
    wq = np.asarray(plan.wq)
    # an in-range wq change on a padding step: invisible to every range /
    # MAC re-derivation check (valid=0 there), but a tail revisit would
    # prefetch the wrong payload block
    wq[c, -1] = (wq[c, -1] + 1) % np.asarray(plan.packed).shape[0]


def _mut_partition(prog):
    cp = np.asarray(_conv_plan(prog).col_perm)
    cp[0], cp[1] = cp[1].copy(), cp[0].copy()


def _mut_gauges(prog):
    np.asarray(_conv_plan(prog).core_cost)[0] += 1


def _mut_cmeta(prog):
    np.asarray(_conv_plan(prog).cmeta["seg_end"]).reshape(-1)[0] += 1


def _mut_geometry(prog):
    prog._plans[2]["c1"].batch += 1


def _mut_graph(prog):
    nodes = list(prog.nodes)  # last FC: activation "none" by the §3.8 rule
    nodes[-1] = dataclasses.replace(nodes[-1], activation="relu")
    prog.nodes = type(prog.nodes)(nodes)


def _mut_overrides(prog):
    prog.overrides["fc"] = {"balance": "sideways"}


#: rule → in-memory corruption of a compiled program.
PROGRAM_MUTATIONS = [
    ("queue/step-classes", _mut_step_classes),
    ("queue/run-structure", _mut_run_structure),
    ("queue/coverage", _mut_coverage),
    ("queue/bounds", _mut_bounds),
    ("queue/inert-tail", _mut_inert_tail),
    ("cores/partition", _mut_partition),
    ("cores/gauges", _mut_gauges),
    ("lookahead/cmeta", _mut_cmeta),
    ("plan/geometry", _mut_geometry),
    ("graph/mask-flow", _mut_graph),
    ("config/overrides", _mut_overrides),
]


# -- file-level mutations -----------------------------------------------------


def _step_dir(path: str) -> str:
    (name,) = [
        n for n in os.listdir(path)
        if n.startswith("step_") and not n.endswith(".tmp")
    ]
    return os.path.join(path, name)


def restamp_fingerprint(path: str) -> None:
    """Recompute and rewrite the fingerprint stamp for a (doctored) saved
    program, so targeted corruption tests get past the ``artifact/
    fingerprint`` gate and hit the structural rule they aim at."""
    d = _step_dir(path)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    meta = manifest["extra"]
    meta.setdefault("verify", {})["fingerprint"] = artifact_fingerprint(
        meta, arrays
    )
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def _file_mut_version(path):
    d = _step_dir(path)
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    manifest["extra"]["format"] = 99
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def _file_mut_fingerprint(path):
    d = _step_dir(path)
    npz = os.path.join(d, "arrays.npz")
    with np.load(npz) as z:
        arrays = {k: z[k] for k in z.files}
    key = next(k for k in sorted(arrays) if arrays[k].size)
    flat = arrays[key].reshape(-1)
    flat[0] = flat[0] + 1 if flat[0] == 0 else 0  # bit-rot one element
    np.savez(npz, **arrays)  # fingerprint NOT re-stamped


def _file_mut_read(path):
    d = _step_dir(path)
    npz = os.path.join(d, "arrays.npz")
    with np.load(npz) as z:
        arrays = {k: z[k] for k in z.files}
    victim = next(k for k in sorted(arrays) if k.startswith("plans/"))
    del arrays[victim]  # truncation: metadata now points at a missing array
    np.savez(npz, **arrays)
    restamp_fingerprint(path)


#: rule → on-disk corruption of a saved program (load must raise the rule).
FILE_MUTATIONS = [
    ("artifact/version", _file_mut_version),
    ("artifact/fingerprint", _file_mut_fingerprint),
    ("artifact/read", _file_mut_read),
]


def mutation_matrix():
    """Run every mutation; yield ``(rule, mutation name, caught, detail)``.

    ``caught`` is True when the targeted rule appears among the error-level
    findings (in-memory mutations) / in the raised :class:`VerifyError`
    (file-level mutations).  Other rules co-firing is fine — corruptions
    overlap — but the *named* rule must fire or it is dead code.
    """
    from repro.program import PhantomProgram

    for rule, mut in PROGRAM_MUTATIONS:
        prog = build_mutation_program()
        mut(prog)
        findings = check_program(prog)
        hit = [f for f in findings if f.rule == rule and f.level == "error"]
        yield rule, mut.__name__, bool(hit), (
            hit[0].format() if hit else f"{len(findings)} other finding(s)"
        )
    for rule, mut in FILE_MUTATIONS:
        prog = build_mutation_program()
        with tempfile.TemporaryDirectory(prefix="phantom-verify-") as tmp:
            path = os.path.join(tmp, "prog")
            prog.save(path)
            mut(path)
            try:
                PhantomProgram.load(path, verify="full")
            except VerifyError as e:
                hit = [f for f in e.findings if f.rule == rule]
                yield rule, mut.__name__, bool(hit), (
                    hit[0].format() if hit
                    else f"raised for {[f.rule for f in e.findings]}"
                )
            except Exception as e:  # raw KeyError etc. = the old failure mode
                yield rule, mut.__name__, False, f"unstructured {type(e).__name__}: {e}"
            else:
                yield rule, mut.__name__, False, "load accepted the corrupted artifact"


def run_selfcheck(full_grid: bool = True) -> int:
    """CI entry: clean grid + mutation matrix; 0 iff both halves pass."""
    failures = 0
    if full_grid:
        print("== clean grid (compile + verify, zero findings expected) ==")
        for label, err in clean_grid():
            if err is None:
                print(f"  ok    {label}")
            else:
                failures += 1
                print(f"  FAIL  {label}\n{err}")
    print("== mutation matrix (each rule must catch its corruption) ==")
    for rule, name, caught, detail in mutation_matrix():
        if caught:
            print(f"  CAUGHT  {rule:<22} {name}")
        else:
            failures += 1
            print(f"  DEAD    {rule:<22} {name}: {detail}")
    if failures:
        print(f"self-check: {failures} failure(s)")
        return 1
    print("self-check: OK (grid clean, no dead rules)")
    return 0
