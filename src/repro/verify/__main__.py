"""``python -m repro.verify`` — verify saved Phantom programs, or self-check.

Two modes (DESIGN.md §13):

* ``python -m repro.verify <path> [...]`` — load each saved program with
  verification on and report per-path.  Findings print one per line as
  ``<path>: [rule] layer=... : detail`` (the file:line-style output CI
  surfaces); exit 1 on any finding.
* ``python -m repro.verify --self-check`` — the tier-1 CI gate: the clean
  compile grid (VGG16/MobileNet × conv_mode × cores × lookahead must
  verify with zero findings) plus the seeded-mutation matrix (every rule
  must catch its corruption — no dead rules).  ``--no-grid`` runs the
  mutation matrix only (fast local iteration).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.verify", description=__doc__.split("\n")[0]
    )
    p.add_argument(
        "paths", nargs="*",
        help="saved program directories (PhantomProgram.save output)",
    )
    p.add_argument(
        "--self-check", action="store_true",
        help="run the clean compile grid + the seeded-mutation matrix",
    )
    p.add_argument(
        "--no-grid", action="store_true",
        help="with --self-check: skip the compile grid, mutation matrix only",
    )
    args = p.parse_args(argv)

    if args.self_check:
        from repro.verify.selfcheck import run_selfcheck

        return run_selfcheck(full_grid=not args.no_grid)

    if not args.paths:
        p.error("pass saved program path(s), or --self-check")

    from repro.program import PhantomProgram
    from repro.verify import VerifyError

    rc = 0
    for path in args.paths:
        try:
            prog = PhantomProgram.load(path, verify="full")
        except VerifyError as e:
            rc = 1
            for f in e.findings:
                print(f"{path}: {f.format()}")
        except FileNotFoundError as e:
            rc = 1
            print(f"{path}: [artifact/read] {e}")
        else:
            plans = sum(len(v) for v in prog._plans.values())
            print(
                f"{path}: OK ({len(prog.nodes)} layers, {plans} plans, "
                f"batch sizes {list(prog.batch_sizes)})"
            )
    return rc


if __name__ == "__main__":
    sys.exit(main())
