"""Top-k routed mixture-of-experts with expert parallelism.

Dispatch is the sort-based, capacity-bounded formulation (TPU-friendly —
static shapes, no [T, E, C] one-hot): assignments are sorted by expert id,
ranked within expert by a cumulative count, and scattered into a dense
``[E, C, d]`` buffer that is batch-matmul'd against the stacked expert
weights (the ``expert`` axis shards over the model axis = EP; XLA inserts the
token all-to-all at the sharding boundary).  Tokens beyond capacity are
dropped (standard), tracked by ``dropped_frac`` in the aux outputs.

Phantom mapping (DESIGN.md §6): the paper's *inter-core* balancer dispatches
the densest filters to the earliest-finishing cores using mask popcounts.
For MoE serving with Phantom-pruned experts the identical policy applies at
expert granularity: ``expert_permutation`` orders experts densest-first LPT
across EP shards so per-shard effectual work is even.  At routing time the
standard load-balance auxiliary loss plays the dynamic role.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, ParamSpec, shard_act
from .layers import ACT

__all__ = ["moe_spec", "moe", "expert_permutation", "load_balance_loss"]


def moe_spec(cfg: ModelConfig):
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    spec = {
        "router": ParamSpec((d, e), ("embed", None)),
        "gate": ParamSpec((e, d, ff), ("expert", "embed", "mlp")),
        "up": ParamSpec((e, d, ff), ("expert", "embed", "mlp")),
        "down": ParamSpec((e, ff, d), ("expert", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        sff = ff * cfg.n_shared_experts
        spec["shared"] = {
            "gate": ParamSpec((d, sff), ("embed", "mlp")),
            "up": ParamSpec((d, sff), ("embed", "mlp")),
            "down": ParamSpec((sff, d), ("mlp", "embed")),
        }
    return spec


def load_balance_loss(probs, expert_ids, n_experts: int):
    """Switch-style auxiliary loss: E · Σ_e f_e · p̄_e."""
    one_hot = jax.nn.one_hot(expert_ids[..., 0], n_experts, dtype=probs.dtype)
    f = one_hot.mean(axis=0)
    p = probs.mean(axis=0)
    return n_experts * jnp.sum(f * p)


def _route_tokens(p, xt, cfg: ModelConfig, cap: int):
    """Dispatch/compute/combine for one token group ``xt`` [T, d]."""
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    dt = xt.dtype
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_ids = jax.lax.top_k(probs, k)  # [t, k]
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # Sort the t·k assignments by expert id; rank within expert = position in
    # the sorted run minus the run start (computed from per-expert counts).
    flat_ids = expert_ids.reshape(-1)  # [t*k]
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(flat_ids, length=e)  # tokens per expert
    run_start = jnp.cumsum(counts) - counts  # [e]
    rank_sorted = jnp.arange(t * k) - run_start[sorted_ids]
    keep = rank_sorted < cap
    # Dropped assignments route to a dedicated dead slot (index e·cap) so
    # they can never clobber a live slot.
    slot_sorted = jnp.where(keep, sorted_ids * cap + rank_sorted, e * cap)

    tok_sorted = order // k
    buf = jnp.zeros((e * cap + 1, d), dt)
    buf = buf.at[slot_sorted].set(xt[tok_sorted].astype(dt))
    buf = buf[: e * cap].reshape(e, cap, d)

    # Expert FFN: batched matmul over the (EP-sharded) expert dim.
    h = ACT[cfg.act](jnp.einsum("ecd,edf->ecf", buf, p["gate"].astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(dt))
    out = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(dt)).reshape(e * cap, d)

    # Combine: scatter expert outputs back to tokens, weighted by gates
    # (dead-slot reads are gated to zero).
    out = jnp.concatenate([out, jnp.zeros((1, d), out.dtype)], axis=0)
    flat_gates = gates.reshape(-1)[order] * keep
    y = jnp.zeros((t, d), dt)
    y = y.at[tok_sorted].add(out[slot_sorted] * flat_gates[:, None].astype(dt))
    aux = {
        "lb_loss": load_balance_loss(probs, expert_ids, e),
        "dropped_frac": 1.0 - keep.mean(),
    }
    return y, aux


def moe(p, x, cfg: ModelConfig, *, capacity_factor: float | None = None):
    """x: [B, S, d] → (y, aux) with aux = {'lb_loss', 'dropped_frac'}.

    With ``cfg.moe_groups = G > 1`` tokens are routed within G independent
    groups (aligned to the data shards): the sort/scatter dispatch stays
    shard-local and only the [G, E, C, d] buffer crosses the EP axis — the
    §Perf fix for the global-dispatch collective blow-up.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity_factor
    g = max(1, cfg.moe_groups)
    if t % g:
        g = 1
    tg = t // g
    cap = max(1, int(np.ceil(tg * k / e * cf)))

    # NOTE (§Perf cell B): an explicitly-batched variant with a forced
    # "return leg" resharding constraint on the expert outputs was tried and
    # REFUTED — XLA responded with full-buffer all-gathers (~8× worse than
    # letting the partitioner place the combine for the vmapped form).
    xt = shard_act(x.reshape(g, tg, d), ("batch", None, "embed"))
    route = lambda xg: _route_tokens(p, xg, cfg, cap)
    if g > 1:
        y, aux = jax.vmap(route)(xt)
        aux = jax.tree.map(lambda a: a.mean(), aux)
    else:
        y, aux = route(xt[0])
        y = y[None]
    y = shard_act(y, ("batch", None, "embed")).reshape(t, d)

    if cfg.n_shared_experts:
        sp = p["shared"]
        xf = x.reshape(t, d)
        dt = x.dtype
        hs = ACT[cfg.act](xf @ sp["gate"].astype(dt)) * (xf @ sp["up"].astype(dt))
        y = y + hs @ sp["down"].astype(dt)
    return y.reshape(b, s, d), aux


def expert_permutation(expert_masks: np.ndarray, n_shards: int) -> np.ndarray:
    """Inter-core balancing for Phantom-pruned experts (§4.3.1 analogue):
    order experts densest-first onto the least-loaded EP shard.

    ``expert_masks``: bool [E, ...] weight masks; returns a permutation of
    experts (apply to the stacked expert weights before sharding)."""
    from repro.core.blocksparse import balance_columns

    e = expert_masks.shape[0]
    dens = expert_masks.reshape(e, -1).sum(1)
    # balance_columns works on [K, N] column masks; synthesise one.
    col = np.zeros((int(dens.max()) + 1, e), dtype=bool)
    for i, d_ in enumerate(dens):
        col[: int(d_), i] = True
    return balance_columns(col, n_shards)
