"""Decoder-only LM assembly for all decoder families.

One spec/forward pair covers the four assigned decoder families:

* ``dense``   — pre-norm attention + SwiGLU blocks (llama-style; optional
  QKV bias / M-RoPE per config),
* ``moe``     — attention + routed-expert FFN (:mod:`repro.models.moe`),
* ``ssm``     — attention-free Mamba2 blocks (:mod:`repro.models.ssm`),
* ``hybrid``  — Mamba2 backbone with a *shared* attention+MLP block applied
  every ``hybrid_attn_every`` layers (zamba2-style weight sharing; each
  application keeps its own KV cache).

Layers are stacked and scanned (``jax.lax.scan``) so the lowered HLO is
O(1) in depth; ``cfg.remat`` wraps the block in ``jax.checkpoint`` with the
dots-saveable policy.  Forward returns ``(logits, aux)``; aux carries MoE
load-balance loss / drop fractions.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import ModelConfig, init_params, axes_tree, stack_specs, shard_act
from .layers import embed, embed_spec, mlp, mlp_spec, rmsnorm, rmsnorm_spec, unembed

__all__ = [
    "lm_spec",
    "lm_forward",
    "lm_loss",
    "init_lm_cache",
    "lm_decode_step",
]


def _attn_block_spec(cfg: ModelConfig, ffn_kind: str):
    spec = {
        "ln1": rmsnorm_spec(cfg.d_model),
        "attn": attn_mod.attention_spec(cfg),
        "ln2": rmsnorm_spec(cfg.d_model),
    }
    spec["ffn"] = moe_mod.moe_spec(cfg) if ffn_kind == "moe" else mlp_spec(cfg)
    return spec


def _block_spec(cfg: ModelConfig):
    if cfg.family in ("dense", "vlm"):
        return _attn_block_spec(cfg, "mlp")
    if cfg.family == "moe":
        return _attn_block_spec(cfg, "moe")
    if cfg.family in ("ssm", "hybrid"):
        return {"ln": rmsnorm_spec(cfg.d_model), "ssm": ssm_mod.ssm_spec(cfg)}
    raise ValueError(cfg.family)


def lm_spec(cfg: ModelConfig):
    spec = {
        "embed": embed_spec(cfg),
        "layers": stack_specs(_block_spec(cfg), cfg.n_layers),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = embed_spec(cfg)  # same (vocab, d) layout
    if cfg.family == "hybrid":
        spec["shared"] = _attn_block_spec(cfg, "mlp")
    return spec


def _attn_mlp_block(p, x, cfg, positions):
    x = x + attn_mod.attention(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg, positions)
    x = x + mlp(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return shard_act(x, ("batch", "seq", "embed"))


def _attn_moe_block(p, x, cfg, positions):
    x = x + attn_mod.attention(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg, positions)
    h, aux = moe_mod.moe(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return shard_act(x + h, ("batch", "seq", "embed")), aux


def _ssm_block(p, x, cfg):
    return shard_act(
        x + ssm_mod.ssm(p["ssm"], rmsnorm(p["ln"], x, cfg.norm_eps), cfg),
        ("batch", "seq", "embed"),
    )


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )


def _scan_blocks(stacked, x, cfg: ModelConfig, positions, block_kind: str):
    """Scan a stack of homogeneous blocks; returns (x, summed aux)."""

    def body(carry, layer_params):
        x, lb = carry
        if block_kind == "moe":
            x, aux = _attn_moe_block(layer_params, x, cfg, positions)
            lb = lb + aux["lb_loss"]
        elif block_kind == "ssm":
            x = _ssm_block(layer_params, x, cfg)
        else:
            x = _attn_mlp_block(layer_params, x, cfg, positions)
        return (x, lb), None

    body = _maybe_remat(body, cfg)
    if cfg.scan_layers:
        (x, lb), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
    else:
        lb = jnp.zeros((), jnp.float32)
        n = jax.tree.leaves(stacked)[0].shape[0]
        for i in range(n):
            (x, lb), _ = body((x, lb), jax.tree.map(lambda t: t[i], stacked))
    return x, lb


def lm_forward(
    params,
    tokens: jnp.ndarray,  # [B, S] int32
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    frontend_embeds: Optional[jnp.ndarray] = None,
):
    """Causal forward over full sequences (training / prefill).

    ``frontend_embeds`` [B, S_f, d] (vlm/audio stubs, per assignment):
    precomputed patch/frame embeddings that *replace* the first S_f token
    embeddings.
    """
    b, s = tokens.shape
    x = embed(params["embed"], tokens, cfg)
    if frontend_embeds is not None:
        sf = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x[:, sf:]], axis=1)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    aux = {}
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every or cfg.n_layers
        lb = jnp.zeros((), jnp.float32)
        shared_block = _maybe_remat(
            lambda p_, x_: _attn_mlp_block(p_, x_, cfg, positions), cfg
        )
        for seg_start in range(0, cfg.n_layers, k):
            seg = jax.tree.map(
                lambda t: jax.lax.slice_in_dim(
                    t, seg_start, min(seg_start + k, cfg.n_layers), axis=0
                ),
                params["layers"],
            )
            x, _ = _scan_blocks(seg, x, cfg, positions, "ssm")
            x = shared_block(params["shared"], x)
        aux["lb_loss"] = lb
    else:
        kind = {"moe": "moe", "ssm": "ssm"}.get(cfg.family, "attn")
        x, lb = _scan_blocks(params["layers"], x, cfg, positions, kind)
        aux["lb_loss"] = lb

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(head, x, cfg), aux


def lm_loss(params, batch: dict, cfg: ModelConfig, lb_coef: float = 0.01):
    """Next-token cross-entropy (+ MoE balance aux).  batch: tokens, labels,
    and optional frontend_embeds / positions."""
    logits, aux = lm_forward(
        params,
        batch["tokens"],
        cfg,
        positions=batch.get("positions"),
        frontend_embeds=batch.get("frontend_embeds"),
    )
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = -(ll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    total = loss + lb_coef * aux.get("lb_loss", 0.0)
    return total, {"ce_loss": loss, "lb_loss": aux.get("lb_loss", 0.0)}


# --------------------------------------------------------------------------
# Decode (serving): stacked per-layer caches scanned alongside the params.
# --------------------------------------------------------------------------


def init_lm_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family in ("dense", "moe", "vlm"):
        kv = attn_mod.init_cache(cfg, batch, max_len)
        return {
            "kv": jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (cfg.n_layers, *t.shape)).copy(),
                kv,
            )
        }
    if cfg.family == "ssm":
        st = ssm_mod.init_ssm_state(cfg, batch)
        return {
            "ssm": jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (cfg.n_layers, *t.shape)).copy(),
                st,
            )
        }
    if cfg.family == "hybrid":
        st = ssm_mod.init_ssm_state(cfg, batch)
        n_shared = cfg.n_layers // (cfg.hybrid_attn_every or cfg.n_layers)
        kv = attn_mod.init_cache(cfg, batch, max_len)
        return {
            "ssm": jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (cfg.n_layers, *t.shape)).copy(),
                st,
            ),
            "kv": jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (n_shared, *t.shape)).copy(), kv
            ),
        }
    raise ValueError(cfg.family)


def _decode_attn_block(p, x, kv, index, cfg):
    h, kv = attn_mod.decode_attention(
        p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), kv, index, cfg
    )
    x = x + h
    h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "router" in p["ffn"]:
        hf, _ = moe_mod.moe(p["ffn"], h2, cfg)
    else:
        hf = mlp(p["ffn"], h2, cfg)
    return x + hf, kv


def _scan_or_unroll(body, x, xs, cfg: ModelConfig):
    """lax.scan over stacked (params, cache) or an unrolled python loop —
    unrolled keeps XLA cost_analysis exact (scan bodies are counted once)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, x, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    outs = []
    for i in range(n):
        x, o = body(x, jax.tree.map(lambda t: t[i], xs))
        outs.append(o)
    return x, jax.tree.map(lambda *ts: jnp.stack(ts, 0), *outs)


def lm_decode_step(params, cache, tokens, index, cfg: ModelConfig):
    """One decode step.  tokens: [B, 1]; index: int32 scalar (cache fill)."""
    x = embed(params["embed"], tokens, cfg)

    if cfg.family in ("dense", "moe", "vlm"):

        def body(x, inp):
            p, kv = inp
            x, kv = _decode_attn_block(p, x, kv, index, cfg)
            return x, kv

        x, new_kv = _scan_or_unroll(body, x, (params["layers"], cache["kv"]), cfg)
        cache = {"kv": new_kv}
    elif cfg.family == "ssm":

        def body(x, inp):
            p, st = inp
            h, st = ssm_mod.ssm_decode(p["ssm"], rmsnorm(p["ln"], x, cfg.norm_eps), st, cfg)
            return x + h, st

        x, new_st = _scan_or_unroll(body, x, (params["layers"], cache["ssm"]), cfg)
        cache = {"ssm": new_st}
    else:  # hybrid
        k = cfg.hybrid_attn_every or cfg.n_layers
        new_ssm = []
        new_kv = []
        for si, seg_start in enumerate(range(0, cfg.n_layers, k)):
            seg_p = jax.tree.map(
                lambda t: jax.lax.slice_in_dim(
                    t, seg_start, min(seg_start + k, cfg.n_layers), axis=0
                ),
                params["layers"],
            )
            seg_c = jax.tree.map(
                lambda t: jax.lax.slice_in_dim(
                    t, seg_start, min(seg_start + k, cfg.n_layers), axis=0
                ),
                cache["ssm"],
            )

            def body(x, inp):
                p, st = inp
                h, st = ssm_mod.ssm_decode(
                    p["ssm"], rmsnorm(p["ln"], x, cfg.norm_eps), st, cfg
                )
                return x + h, st

            x, seg_new = _scan_or_unroll(body, x, (seg_p, seg_c), cfg)
            new_ssm.append(seg_new)
            kv_i = jax.tree.map(lambda t: t[si], cache["kv"])
            x, kv_i = _decode_attn_block(params["shared"], x, kv_i, index, cfg)
            new_kv.append(kv_i)
        cache = {
            "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_ssm),
            "kv": jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_kv),
        }

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(head, x, cfg), cache
