"""Unified model interface: build(cfg) → Model(init/loss/forward/decode…).

Every family exposes the same callables so the trainer, server, dry-run and
benchmarks are family-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import encdec as encdec_mod
from . import transformer as tf_mod
from .common import ModelConfig, abstract_params, axes_tree, init_params

__all__ = ["Model", "build"]


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    spec: Any
    loss: Callable  # (params, batch) -> (loss, metrics)
    forward: Callable  # (params, batch) -> logits  (prefill / scoring)
    init_cache: Callable  # (batch, max_len) -> cache
    decode_step: Callable  # (params, cache, tokens, index) -> (logits, cache)

    def init(self, key, dtype=None):
        return init_params(key, self.spec, dtype or self.cfg.pdtype())

    def abstract_params(self, dtype=None):
        return abstract_params(self.spec, dtype or self.cfg.pdtype())

    def axes(self):
        return axes_tree(self.spec)


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        spec = encdec_mod.encdec_spec(cfg)
        return Model(
            cfg=cfg,
            spec=spec,
            loss=lambda p, b: encdec_mod.encdec_loss(p, b, cfg),
            forward=lambda p, b: encdec_mod.encdec_forward(
                p, b["frontend_embeds"], b["tokens"], cfg
            )[0],
            init_cache=lambda batch, max_len, enc_len=None: encdec_mod.init_encdec_cache(
                cfg, batch, max_len, enc_len or max_len
            ),
            decode_step=lambda p, c, t, i: encdec_mod.encdec_decode_step(p, c, t, i, cfg),
        )
    spec = tf_mod.lm_spec(cfg)
    return Model(
        cfg=cfg,
        spec=spec,
        loss=lambda p, b: tf_mod.lm_loss(p, b, cfg),
        forward=lambda p, b: tf_mod.lm_forward(
            p,
            b["tokens"],
            cfg,
            positions=b.get("positions"),
            frontend_embeds=b.get("frontend_embeds"),
        )[0],
        init_cache=lambda batch, max_len: tf_mod.init_lm_cache(cfg, batch, max_len),
        decode_step=lambda p, c, t, i: tf_mod.lm_decode_step(p, c, t, i, cfg),
    )
