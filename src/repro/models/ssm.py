"""Mamba2 (state-space duality / SSD) block — chunked scan + decode step.

Implements the SSD algorithm of arXiv:2405.21060: the sequence is split into
chunks; intra-chunk terms use the quadratic (attention-like) form, inter-
chunk terms propagate the [heads, head_dim, state] recurrent state with
exponential decay.  Sub-quadratic in sequence length (this is why
mamba2-2.7b / zamba2-2.7b run the ``long_500k`` shape).

Decode is the O(1) recurrence: ``h ← h·exp(dtA) + dt·B⊗x``, plus a rolling
causal-conv state.  The SSD scan itself is *not* Phantom-sparsified
(sequential state recurrence has no zero-skippable GEMM tiles — DESIGN.md
§6); the in/out projections are.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec, shard_act
from .layers import linear, linear_spec

__all__ = ["ssm_spec", "ssm", "ssm_decode", "init_ssm_state"]


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    d_xbc = di + 2 * g * n
    return di, g, n, h, p, d_xbc


def ssm_spec(cfg: ModelConfig):
    di, g, n, h, p, d_xbc = _dims(cfg)
    d_in_proj = 2 * di + 2 * g * n + h  # z, x, B, C, dt
    return {
        "in_proj": linear_spec(cfg.d_model, d_in_proj, "embed", "mlp", phantom=cfg.phantom),
        "conv_w": ParamSpec((cfg.ssm_conv, d_xbc), (None, "mlp")),
        "conv_b": ParamSpec((d_xbc,), ("mlp",), init="zeros"),
        "A_log": ParamSpec((h,), (None,), init="zeros"),
        "D": ParamSpec((h,), (None,), init="ones"),
        "dt_bias": ParamSpec((h,), (None,), init="zeros"),
        "norm": ParamSpec((di,), ("mlp",), init="ones"),
        "out_proj": linear_spec(di, cfg.d_model, "mlp", "embed", phantom=cfg.phantom),
    }


def _split(zxbcdt, cfg: ModelConfig):
    di, g, n, h, p, _ = _dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, state=None):
    """Depthwise causal conv, width K.  ``state``: [b, K-1, C] carry for
    decode; training pads with zeros."""
    k = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out + conv_b[None, None, :]), xp[:, -(k - 1) :, :]


def _segsum(x):
    """[..., l] → [..., l, l] lower-triangular segment sums (−inf above)."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, seg, -jnp.inf)


def _ssd_scan(x, dA, b_mat, c_mat, chunk: int):
    """SSD: x [b,s,h,p], dA [b,s,h], B/C [b,s,h,n] (already group-broadcast).

    Returns y [b,s,h,p] and the final state [b,h,p,n].  All decay math in
    fp32 for stability.
    """
    bsz, s0, h, p = x.shape
    n = b_mat.shape[-1]
    pad = (-s0) % chunk
    if pad:  # causal: zero-padded tail never influences earlier outputs
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        x, dA, b_mat, c_mat = zp(x), zp(dA), zp(b_mat), zp(c_mat)
    s = s0 + pad
    nc = s // chunk
    r = lambda t: t.reshape(bsz, nc, chunk, *t.shape[2:])
    xc, bc, cc = r(x), r(b_mat), r(c_mat)
    dac = r(dA).transpose(0, 3, 1, 2).astype(jnp.float32)  # [b,h,c,l]
    da_cum = jnp.cumsum(dac, axis=-1)

    # Intra-chunk (quadratic) term.
    ell = jnp.exp(_segsum(dac))  # [b,h,c,l,l]
    y_diag = jnp.einsum(
        "bclhn,bcshn,bhcls,bcshp->bclhp", cc, bc, ell.astype(x.dtype), xc
    )

    # Chunk-final states.
    decay_states = jnp.exp(da_cum[..., -1:] - da_cum)  # [b,h,c,l]
    states = jnp.einsum(
        "bclhn,bhcl,bclhp->bchpn", bc, decay_states.astype(x.dtype), xc
    )

    # Inter-chunk recurrence (scan over chunks — O(nc) sequential).
    chunk_decay = jnp.exp(da_cum[..., -1])  # [b,h,c]

    def step(carry, inp):
        st, dec = inp  # st: [b,h,p,n], dec: [b,h]
        new = carry * dec[..., None, None].astype(carry.dtype) + st
        return new, carry  # emit the state *entering* this chunk

    init = jnp.zeros((bsz, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [b,c,h,p,n]

    state_decay = jnp.exp(da_cum)  # [b,h,c,l]
    y_off = jnp.einsum(
        "bclhn,bchpn,bhcl->bclhp", cc, prev_states, state_decay.astype(x.dtype)
    )
    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s0]
    return y, final


def _gated_norm(y, z, scale, eps):
    y = y * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps)).astype(y.dtype) * scale.astype(y.dtype)


def ssm(p, u, cfg: ModelConfig, chunk: int = 128):
    """Training / prefill forward.  u: [b, s, d_model]."""
    di, g, n, h, pd, _ = _dims(cfg)
    bsz, s, _ = u.shape
    chunk = min(chunk, s)
    zxbcdt = linear(p["in_proj"], u, cfg, cfg.phantom)
    z, xbc, dt = _split(zxbcdt, cfg)
    xbc, _ = _causal_conv(xbc, p["conv_w"].astype(u.dtype), p["conv_b"].astype(u.dtype))
    x, b_mat, c_mat = jnp.split(xbc, [di, di + g * n], axis=-1)
    x = shard_act(x.reshape(bsz, s, h, pd), ("batch", "seq", "heads", None))
    rep = h // g
    b_mat = jnp.repeat(b_mat.reshape(bsz, s, g, n), rep, axis=2)
    c_mat = jnp.repeat(c_mat.reshape(bsz, s, g, n), rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # [h]
    da = dt * a  # [b,s,h]
    y, _ = _ssd_scan(x * dt.astype(x.dtype)[..., None], da, b_mat, c_mat, chunk)
    y = y + x * p["D"].astype(x.dtype)[None, None, :, None]
    y = _gated_norm(y.reshape(bsz, s, di), z, p["norm"], cfg.norm_eps)
    return linear(p["out_proj"], y, cfg, cfg.phantom)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=None):
    di, g, n, h, pd, d_xbc = _dims(cfg)
    dt = dtype or cfg.dtype()
    return {
        "ssm": jnp.zeros((batch, h, pd, n), dt),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_xbc), dt),
    }


def ssm_decode(p, u, state, cfg: ModelConfig):
    """One-token decode.  u: [b, 1, d_model]; state from init_ssm_state."""
    di, g, n, h, pd, _ = _dims(cfg)
    bsz = u.shape[0]
    zxbcdt = linear(p["in_proj"], u, cfg, cfg.phantom)
    z, xbc, dt = _split(zxbcdt, cfg)
    xbc, conv_state = _causal_conv(
        xbc, p["conv_w"].astype(u.dtype), p["conv_b"].astype(u.dtype), state["conv"]
    )
    x, b_mat, c_mat = jnp.split(xbc[:, 0], [di, di + g * n], axis=-1)
    x = x.reshape(bsz, h, pd)
    rep = h // g
    b_mat = jnp.repeat(b_mat.reshape(bsz, g, n), rep, axis=1)
    c_mat = jnp.repeat(c_mat.reshape(bsz, g, n), rep, axis=1)
    dt1 = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # [b,h]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * a).astype(x.dtype)  # [b,h]
    upd = (x * dt1.astype(x.dtype)[..., None])[..., None] * b_mat[:, :, None, :]
    new_ssm = state["ssm"] * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, c_mat)
    y = y + x * p["D"].astype(x.dtype)[None, :, None]
    y = _gated_norm(y.reshape(bsz, 1, di), z, p["norm"], cfg.norm_eps)
    out = linear(p["out_proj"], y, cfg, cfg.phantom)
    return out, {"ssm": new_ssm, "conv": conv_state}
