"""Model zoo: pure-JAX pytree models for the assigned architectures."""
