"""Encoder–decoder backbone (seamless-m4t-medium assignment).

Per the assignment the modality frontend is a STUB — ``input_specs()``
provides precomputed frame embeddings [B, S_enc, d] for the encoder.  The
decoder is a causal transformer with cross-attention; decode shapes lower the
decoder ``serve_step`` (self-attn KV cache + precomputed cross-attn K/V).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .common import ModelConfig, stack_specs, shard_act
from .layers import embed, embed_spec, mlp, mlp_spec, rmsnorm, rmsnorm_spec, unembed
from .transformer import _maybe_remat


def _scan_or_loop(body, x, xs, cfg):
    if cfg.scan_layers:
        return jax.lax.scan(body, x, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    outs = []
    for i in range(n):
        x, o = body(x, jax.tree.map(lambda t: t[i], xs))
        outs.append(o)
    if outs and outs[0] is not None:
        outs = jax.tree.map(lambda *ts: jnp.stack(ts, 0), *outs)
    else:
        outs = None
    return x, outs

__all__ = [
    "encdec_spec",
    "encdec_forward",
    "encdec_loss",
    "encode",
    "init_encdec_cache",
    "encdec_decode_step",
]


def _enc_block_spec(cfg: ModelConfig):
    return {
        "ln1": rmsnorm_spec(cfg.d_model),
        "attn": attn_mod.attention_spec(cfg),
        "ln2": rmsnorm_spec(cfg.d_model),
        "ffn": mlp_spec(cfg),
    }


def _dec_block_spec(cfg: ModelConfig):
    return {
        "ln1": rmsnorm_spec(cfg.d_model),
        "self_attn": attn_mod.attention_spec(cfg),
        "ln_x": rmsnorm_spec(cfg.d_model),
        "cross_attn": attn_mod.attention_spec(cfg, cross=True),
        "ln2": rmsnorm_spec(cfg.d_model),
        "ffn": mlp_spec(cfg),
    }


def encdec_spec(cfg: ModelConfig):
    return {
        "embed": embed_spec(cfg),
        "enc_layers": stack_specs(_enc_block_spec(cfg), cfg.enc_layers or cfg.n_layers),
        "enc_norm": rmsnorm_spec(cfg.d_model),
        "dec_layers": stack_specs(_dec_block_spec(cfg), cfg.n_layers),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }


def encode(params, frames: jnp.ndarray, cfg: ModelConfig):
    """frames: [B, S_enc, d] stub frontend embeddings → encoder states."""
    b, s, _ = frames.shape
    x = frames.astype(cfg.dtype())
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, p):
        h = attn_mod.attention(
            p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg, positions, causal=False
        )
        x = x + h
        x = x + mlp(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        return shard_act(x, ("batch", "seq", "embed")), None

    body = _maybe_remat(body, cfg)
    x, _ = _scan_or_loop(body, x, params["enc_layers"], cfg)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(p, x, enc_out, positions, cfg):
    x = x + attn_mod.attention(
        p["self_attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg, positions
    )
    x = x + attn_mod.attention(
        p["cross_attn"],
        rmsnorm(p["ln_x"], x, cfg.norm_eps),
        cfg,
        positions,
        kv_input=enc_out,
        causal=False,
    )
    x = x + mlp(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return shard_act(x, ("batch", "seq", "embed"))


def encdec_forward(params, frames, dec_tokens, cfg: ModelConfig):
    enc_out = encode(params, frames, cfg)
    b, s = dec_tokens.shape
    x = embed(params["embed"], dec_tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, p):
        return _dec_block(p, x, enc_out, positions, cfg), None

    body = _maybe_remat(body, cfg)
    x, _ = _scan_or_loop(body, x, params["dec_layers"], cfg)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x, cfg), {}


def encdec_loss(params, batch, cfg: ModelConfig):
    logits, _ = encdec_forward(params, batch["frontend_embeds"], batch["tokens"], cfg)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = -(ll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    return loss, {"ce_loss": loss}


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    """Self-attn KV cache + slots for the precomputed cross-attn K/V."""
    kv = attn_mod.init_cache(cfg, batch, max_len)
    n = cfg.n_layers
    stack = lambda t: jnp.broadcast_to(t[None], (n, *t.shape)).copy()
    cross = attn_mod.init_cache(cfg, batch, enc_len)
    return {
        "kv": jax.tree.map(stack, kv),
        "cross": jax.tree.map(stack, cross),
    }


def encdec_decode_step(params, cache, tokens, index, cfg: ModelConfig):
    """Decoder-only step; ``cache['cross']`` holds precomputed encoder K/V."""
    x = embed(params["embed"], tokens, cfg)
    b = tokens.shape[0]

    def body(x, inp):
        p, kv, cross = inp
        h, kv = attn_mod.decode_attention(
            p["self_attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), kv, index, cfg
        )
        x = x + h
        # Cross-attention against static encoder K/V (no rotary, no update).
        q_in = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        from .layers import linear

        hd, nq = cfg.hd, cfg.n_heads
        q = linear(p["cross_attn"]["wq"], q_in, cfg).reshape(b, 1, nq, hd)
        o = attn_mod._sdpa(q, cross["k"], cross["v"], None, cfg)
        x = x + linear(p["cross_attn"]["wo"], o.reshape(b, 1, nq * hd), cfg, cfg.phantom)
        x = x + mlp(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        return x, kv

    x, new_kv = _scan_or_loop(
        body, x, (params["dec_layers"], cache["kv"], cache["cross"]), cfg
    )
    cache = {"kv": new_kv, "cross": cache["cross"]}
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x, cfg), cache
