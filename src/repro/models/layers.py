"""Shared layers: RMSNorm, embeddings, (Phantom-aware) linears, gated MLP.

Also home of :class:`FFNSpec` — the gated-FFN layer kind for the Phantom
program API.  Its whole integration is the single
:func:`repro.program.register_layer_kind` call at the bottom of this
module: no forward loop anywhere had to learn about FFNs (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.phantom_linear import PhantomConfig, phantom_linear
from repro.program.registry import register_layer_kind
from .common import ModelConfig, ParamSpec, dense_spec, shard_act

__all__ = [
    "rmsnorm_spec",
    "rmsnorm",
    "embed_spec",
    "embed",
    "unembed",
    "linear_spec",
    "linear",
    "mlp_spec",
    "mlp",
    "ACT",
    "FFNSpec",
]

ACT = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "none": lambda x: x,
}


def rmsnorm_spec(d):
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(p, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * p["scale"].astype(dt)


def embed_spec(cfg: ModelConfig):
    # 2-D (vocab×FSDP) sharding is densest at rest but makes the token gather
    # reshard through a full rematerialisation (XLA SPMD limitation observed
    # in the dry-run); ``embed_table_2d=False`` shards vocab only (§Perf).
    axes = ("vocab", "embed") if cfg.embed_table_2d else ("vocab", None)
    return {"table": ParamSpec((cfg.vocab, cfg.d_model), axes, scale=0.02)}


def embed(p, tokens, cfg: ModelConfig):
    x = p["table"].astype(cfg.dtype())[tokens]
    return shard_act(x, ("batch", "seq", "embed"))


def unembed(p, x, cfg: ModelConfig):
    """LM head; with tied embeddings, reuses the embed table."""
    logits = jnp.einsum("...d,vd->...v", x, p["table"].astype(cfg.dtype()))
    return shard_act(logits, ("batch", "seq", "vocab"))


def linear_spec(d_in, d_out, in_ax, out_ax, bias=False, phantom: PhantomConfig | None = None):
    spec = dense_spec(d_in, d_out, in_ax, out_ax, bias=bias)
    if phantom is not None and phantom.enabled:
        # Element-expanded block mask stored with the weight (non-trainable in
        # spirit; the optimizer sees zero gradient through the multiply).
        spec["wmask"] = ParamSpec((d_in, d_out), (in_ax, out_ax), init="ones")
    return spec


def linear(p, x, cfg: ModelConfig, phantom: PhantomConfig | None = None, prepared=None):
    dt = cfg.dtype()
    w = p["w"].astype(dt)
    b = p.get("b")
    if phantom is not None and phantom.enabled:
        return phantom_linear(
            x,
            w,
            p.get("wmask", None) if p.get("wmask") is None else p["wmask"].astype(dt),
            phantom,
            prepared=prepared,
            bias=None if b is None else b.astype(dt),
        )
    y = jnp.einsum("...k,kn->...n", x, w)
    return y if b is None else y + b.astype(dt)


def mlp_spec(cfg: ModelConfig, d_ff: int | None = None):
    """SwiGLU MLP; gate/up/down are Phantom-eligible (DESIGN.md §6)."""
    ff = d_ff or cfg.d_ff
    ph = cfg.phantom
    return {
        "gate": linear_spec(cfg.d_model, ff, "embed", "mlp", phantom=ph),
        "up": linear_spec(cfg.d_model, ff, "embed", "mlp", phantom=ph),
        "down": linear_spec(ff, cfg.d_model, "mlp", "embed", phantom=ph),
    }


def mlp(p, x, cfg: ModelConfig):
    ph = cfg.phantom
    h = ACT[cfg.act](linear(p["gate"], x, cfg, ph)) * linear(p["up"], x, cfg, ph)
    h = shard_act(h, ("batch", "seq", "mlp"))
    return linear(p["down"], h, cfg, ph)


# -- the gated FFN as a Phantom-program layer kind ---------------------------


@dataclasses.dataclass(frozen=True)
class FFNSpec:
    """A gated FFN (``down(act(x @ gate) * (x @ up))``) as a program-layer
    spec: ``params[name] = {"wg", "wu", "wd", "b"}``.  All three matmuls are
    Phantom-eligible (DESIGN.md §6); the gate/up pair shares the incoming
    §3.8 tile bits, the down projection gates on ``h``'s exact zeros."""

    name: str
    in_dim: int
    d_ff: int
    out_dim: int
    act: str = "relu"

    @property
    def macs(self) -> int:
        return self.in_dim * self.d_ff * 2 + self.d_ff * self.out_dim


class FFNKind:
    """Program-layer kind for :class:`FFNSpec` — the one-registration proof
    that new Phantom-eligible layer families need no forward-loop edits."""

    name = "ffn"
    _WEIGHTS = ("wg", "wu", "wd")

    def prepare(self, spec: FFNSpec, params, batch: int, cfg):
        from repro.kernels import ops  # local: kernels are optional at import

        plan = {
            k: ops.prepare_weight(np.asarray(params[k]), m=batch, config=cfg)
            for k in self._WEIGHTS
        }
        plan["act"] = spec.act
        return plan

    def apply(self, x, plan, params, *, mask, act_threshold, interpret):
        from repro.kernels import ops

        bm, bk, _ = plan["wg"].block
        bits = None if mask is None else ops.element_mask_tile_bits(mask, (bm, bk))
        mm = lambda v, pw, b: ops.phantom_matmul(  # noqa: E731
            v, pw, act_bits=b, act_threshold=act_threshold, interpret=interpret
        )
        h = ACT[plan["act"]](mm(x, plan["wg"], bits)) * mm(x, plan["wu"], bits)
        return mm(h, plan["wd"], None) + params["b"]

    def mask_out(self, x, act_threshold):
        return (x > act_threshold).astype(x.dtype)

    def stats(self, plan, spec: FFNSpec, batch: int) -> dict:
        pws = [plan[k] for k in self._WEIGHTS]
        return {
            "kind": self.name,
            "steps": sum(pw.steps for pw in pws),
            "dense_steps": sum(int(np.prod(pw.grid_tiles)) for pw in pws),
            "density": float(np.mean([pw.density() for pw in pws])),
            "valid_macs": batch
            * sum(int(np.count_nonzero(np.asarray(pw.packed))) for pw in pws),
            "dense_macs": batch * spec.macs,
        }


register_layer_kind(FFNSpec, FFNKind())
