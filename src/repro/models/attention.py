"""Grouped-query attention with RoPE / M-RoPE and a KV cache.

Supports the assigned archs' attention variants:
  * GQA with any (n_heads, n_kv_heads) ratio, optional QKV bias (qwen2),
  * rotary embeddings with configurable theta,
  * M-RoPE (qwen2-vl): the rotary half-dim is split into (t, h, w) sections,
    each rotated by its own position stream (text default: t=h=w=pos),
  * causal training attention and single-step decode against a cache,
  * cross-attention (seamless-m4t decoder) via explicit kv inputs.

The KV cache layout is ``[B, S_max, n_kv, hd]``; decode shapes shard S_max
over the model axis (sequence parallelism) — see parallel/sharding.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec, shard_act
from .layers import linear, linear_spec

__all__ = [
    "attention_spec",
    "rope",
    "mrope",
    "attention",
    "decode_attention",
    "init_cache",
]


def attention_spec(cfg: ModelConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    spec = {
        "wq": linear_spec(d, nq * hd, "embed", "kv", bias=cfg.qkv_bias),
        "wk": linear_spec(d, nkv * hd, "embed", "kv", bias=cfg.qkv_bias),
        "wv": linear_spec(d, nkv * hd, "embed", "kv", bias=cfg.qkv_bias),
        # o-proj is Phantom-eligible (DESIGN.md §6)
        "wo": linear_spec(nq * hd, d, "kv", "embed", phantom=cfg.phantom),
    }
    return spec


def _rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def rope(x, positions, theta: float):
    """x: [B, S, H, D]; positions: [B, S] int32."""
    d2 = x.shape[-1] // 2
    freqs = theta ** (-jnp.arange(0, d2, dtype=jnp.float32) / d2)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, d2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.concatenate([cos, cos], axis=-1).astype(x.dtype)
    sin = jnp.concatenate([sin, sin], axis=-1).astype(x.dtype)
    return x * cos + _rotate_half(x) * sin


def mrope(x, positions3, theta: float, sections: tuple[int, ...]):
    """Multimodal RoPE (qwen2-vl): ``positions3`` [3, B, S] — the (t, h, w)
    position streams; the rotary half-dim is partitioned into ``sections``
    (which must sum to D/2), section ``i`` rotated by stream ``i``."""
    d2 = x.shape[-1] // 2
    assert sum(sections) == d2, (sections, d2)
    freqs = theta ** (-jnp.arange(0, d2, dtype=jnp.float32) / d2)
    ang_each = positions3[..., None].astype(jnp.float32) * freqs  # [3, B, S, d2]
    # Select, per frequency index, which position stream rotates it.
    sel = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=d2
    )
    idx = jnp.broadcast_to(sel[None, None, None, :], (1, *ang_each.shape[1:3], d2))
    ang = jnp.take_along_axis(ang_each, idx, axis=0)[0]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.concatenate([cos, cos], axis=-1).astype(x.dtype)
    sin = jnp.concatenate([sin, sin], axis=-1).astype(x.dtype)
    return x * cos + _rotate_half(x) * sin


def _apply_rope(q, k, positions, cfg: ModelConfig):
    if cfg.mrope_sections:
        if positions.ndim == 2:  # text-only: t = h = w = pos
            positions = jnp.broadcast_to(positions[None], (3, *positions.shape))
        q = mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q: [B,S,Hq,D], k/v: [B,T,Hkv,D] → [B,S,Hq,D].  GQA via head groups."""
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(d).astype(jnp.float32)
    if mask is not None:
        logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, hq, d)


def _sdpa_chunked(q, k, v, cfg: ModelConfig, causal: bool, chunk: int = 1024):
    """Flash-style online-softmax attention: scans KV in chunks with running
    (max, denom, acc) so the [S, T] logits tensor is never materialised —
    HBM traffic drops from O(S·T) to O(S + T) per head (beyond-paper §Perf
    optimization; numerically matches `_sdpa` to fp32 softmax accuracy)."""
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (t + pad) // chunk
    qg = (q.reshape(b, s, hkv, g, d).astype(jnp.float32)) / jnp.sqrt(d)
    kc = k.reshape(b, nc, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(nc) * chunk
    qpos = jnp.arange(s)

    def body(carry, inp):
        m, l, acc = carry
        k_c, v_c, start = inp
        lg = jnp.einsum("bskgd,btkd->bkgst", qg, k_c.astype(jnp.float32))
        kpos = start + jnp.arange(chunk)
        valid = kpos < t
        keep = valid[None, :] & (
            (kpos[None, :] <= qpos[:, None]) if causal else valid[None, :]
        )
        lg = jnp.where(keep[None, None, None], lg, -jnp.inf)
        m_new = jnp.maximum(m, lg.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(lg - m_new[..., None])
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, v_c.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, d).astype(q.dtype)


def attention(
    p,
    x,
    cfg: ModelConfig,
    positions,
    *,
    kv_input=None,  # cross-attention source (enc-dec)
    causal: bool = True,
):
    b, s, _ = x.shape
    hd, nq, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = linear(p["wq"], x, cfg).reshape(b, s, nq, hd)
    src = x if kv_input is None else kv_input
    t = src.shape[1]
    k = linear(p["wk"], src, cfg).reshape(b, t, nkv, hd)
    v = linear(p["wv"], src, cfg).reshape(b, t, nkv, hd)
    if kv_input is None:  # self-attention: rotary
        q, k = _apply_rope(q, k, positions, cfg)
    q = shard_act(q, ("batch", "seq", "heads", None))
    k = shard_act(k, ("batch", "seq", "kv_heads", None))
    if cfg.attn_impl == "chunked":
        o = _sdpa_chunked(q, k, v, cfg, causal=causal and kv_input is None,
                          chunk=cfg.attn_chunk)
    else:
        mask = None
        if causal and kv_input is None:
            mask = (jnp.arange(t)[None, :] <= jnp.arange(s)[:, None])[
                None, None, None, :, :
            ]
        o = _sdpa(q, k, v, mask, cfg)
    return linear(p["wo"], o.reshape(b, s, nq * hd), cfg, cfg.phantom)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or cfg.dtype()
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_attention(p, x, cache, index, cfg: ModelConfig):
    """One-token decode: ``x`` [B, 1, D]; ``cache`` k/v [B, S_max, nkv, hd];
    ``index`` int32 scalar or [B] vector — per-slot write position (= number
    of tokens already cached; vector form supports continuous batching)."""
    b, _, _ = x.shape
    hd, nq, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    index = jnp.broadcast_to(jnp.asarray(index, jnp.int32), (b,))
    q = linear(p["wq"], x, cfg).reshape(b, 1, nq, hd)
    k = linear(p["wk"], x, cfg).reshape(b, 1, nkv, hd)
    v = linear(p["wv"], x, cfg).reshape(b, 1, nkv, hd)
    pos = index[:, None]
    q, k = _apply_rope(q, k, pos, cfg)
    rows = jnp.arange(b)
    ck = cache["k"].at[rows, index].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[rows, index].set(v[:, 0].astype(cache["v"].dtype))
    t = ck.shape[1]
    mask = (jnp.arange(t)[None, :] <= index[:, None])[:, None, None, None, :]
    o = _sdpa(q, ck, cv, mask, cfg)
    y = linear(p["wo"], o.reshape(b, 1, nq * hd), cfg, cfg.phantom)
    return y, {"k": ck, "v": cv}
