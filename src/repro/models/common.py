"""Model substrate: parameter specs, logical-axis sharding, config.

Pure-JAX module system (no flax): every layer declares a tree of
:class:`ParamSpec` leaves — shape, initializer, and *logical axes*.  One
source of truth yields (a) the parameter pytree (``init_params``), (b) the
logical-axes pytree (``axes_tree``), and (c) via
:mod:`repro.parallel.sharding`, the mesh ``PartitionSpec`` tree used by pjit.

Logical axis vocabulary (resolved by the rule table in parallel/sharding.py):
  ``embed``     model width             → FSDP axis ('data') on weights
  ``mlp``       FFN hidden              → TP axis ('model')
  ``kv``        flattened heads×head_dim→ TP axis ('model')
  ``vocab``     vocabulary              → TP axis ('model')
  ``expert``    MoE expert count        → EP axis ('model')
  ``layers``    stacked scan dim        → never sharded
  ``conv``/``state``/…                  → replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.phantom_linear import PhantomConfig, PHANTOM_DISABLED

__all__ = [
    "ParamSpec",
    "ModelConfig",
    "init_params",
    "axes_tree",
    "stack_specs",
    "dense_spec",
    "shard_act",
    "set_mesh_rules",
    "get_mesh_rules",
]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # default: 1/sqrt(fan_in)

    def initializer(self, key, dtype):
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        # Fan-in = second-to-last dim (works for 2-D [in, out] and stacked
        # 3-D expert weights [E, in, out]).
        fan_in = self.shape[-2] if len(self.shape) > 1 else self.shape[-1]
        scale = self.scale if self.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape) * scale).astype(dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(key, spec_tree, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [s.initializer(k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(spec_tree, dtype=jnp.float32):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec_tree, is_leaf=is_spec
    )


def axes_tree(spec_tree):
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def stack_specs(spec_tree, n: int):
    """Stack a per-layer spec tree along a leading ``layers`` scan dim."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.axes), s.init, s.scale),
        spec_tree,
        is_leaf=is_spec,
    )


def dense_spec(d_in, d_out, in_ax="embed", out_ax="mlp", bias=False, scale=None):
    spec = {"w": ParamSpec((d_in, d_out), (in_ax, out_ax), scale=scale)}
    if bias:
        spec["b"] = ParamSpec((d_out,), (out_ax,), init="zeros")
    return spec


# --------------------------------------------------------------------------
# Activation sharding constraints.  A launcher installs (mesh, rules); model
# code calls ``shard_act(x, ('batch', 'seq', 'embed'))``.  Outside a mesh
# context (unit tests, CPU) this is the identity.
# --------------------------------------------------------------------------

_MESH_RULES: list = [None]


def set_mesh_rules(mesh, rules: dict | None):
    """Install the active (mesh, logical-rule table); None disables."""
    _MESH_RULES[0] = (mesh, rules) if mesh is not None else None


def get_mesh_rules():
    return _MESH_RULES[0]


def shard_act(x: jnp.ndarray, logical_axes: tuple[Optional[str], ...]):
    ctx = _MESH_RULES[0]
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = []
    claimed: set = set()
    for dim, ax in zip(x.shape, logical_axes):
        mesh_ax = rules.get(ax) if ax else None
        if mesh_ax is None:
            spec.append(None)
            continue
        flat = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
        size = math.prod(mesh.shape[a] for a in flat)
        # A mesh axis may shard at most one dim per tensor (first claim wins).
        if dim % size == 0 and not (claimed & set(flat)):
            spec.append(mesh_ax)
            claimed.update(flat)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(*spec))
    )


# --------------------------------------------------------------------------
# The unified model configuration covering all assigned families.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 → d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) halves
    act: str = "silu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden (d_ff used when 0)
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    # hybrid (zamba2-style): shared attention block every k SSM blocks
    hybrid_attn_every: int = 0
    # enc-dec
    enc_layers: int = 0
    frontend: Optional[str] = None  # 'vision' | 'audio' stubs (per assignment)
    # technique
    phantom: PhantomConfig = PHANTOM_DISABLED
    # numerics / implementation knobs (§Perf hillclimbing)
    param_dtype: str = "float32"
    act_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_impl: str = "naive"  # naive | chunked (flash-style online softmax)
    attn_chunk: int = 1024  # KV tile for the chunked path
    moe_groups: int = 0  # >0: route within token groups (shard-local dispatch)
    embed_table_2d: bool = True  # False: vocab-only sharding (gather-friendly)
    # long-context capability flag (sub-quadratic families)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def dtype(self):
        return jnp.dtype(self.act_dtype)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Approximate N for MODEL_FLOPS = 6·N·D bookkeeping."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
        if self.family == "moe":
            ff = 3 * d * (self.moe_d_ff or self.d_ff) * self.n_experts
        else:
            ff = 3 * d * self.d_ff if self.d_ff else 0
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di = self.d_inner
            ssm = d * 2 * di + di * d + di * (2 * self.ssm_groups * self.ssm_state)
        if self.family == "hybrid":
            # The attention+MLP block is a single shared weight copy (zamba2).
            per_layer = ssm
            shared = attn + ff
        else:
            per_layer = ff + (attn if self.family != "ssm" else 0) + ssm
            shared = 0
        emb = v * d * (1 if self.tie_embeddings else 2)
        layers = L + (self.enc_layers or 0)
        return per_layer * layers + shared + emb

    def active_param_count(self) -> int:
        """N_active for MoE (experts scaled by top_k / n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        ff_all = 3 * d * (self.moe_d_ff or self.d_ff) * self.n_experts
        ff_act = 3 * d * (self.moe_d_ff or self.d_ff) * max(self.top_k, 1)
        return self.param_count() - L * (ff_all - ff_act)
