"""VGG16 / MobileNetV1 in JAX — the faithful CNN reproduction path.

These are the networks the paper evaluates (§5.1).  The JAX forwards share
the layer tables in :mod:`repro.core.netlib`, so the cycle simulator and the
functional network agree on shapes.

Two execution paths share one parameter pytree:

* ``cnn_forward`` — dense XLA (``lax.conv_general_dilated`` + matmul), the
  numerical oracle;
* ``phantom.compile(layers, params, cfg, batch=...)`` — every conv *and*
  FC layer runs on the Phantom block-sparse core through one
  :class:`repro.program.PhantomProgram` (direct implicit-im2col convs by
  default, §3.8 masks flowing between layers, per-batch plan cache,
  save/load).  ``prepare_cnn_phantom`` + ``cnn_forward_phantom`` below are
  the pre-program entry points, kept for one release as deprecated shims
  that delegate to the program machinery (bit-for-bit at ``Cin % bk == 0``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import program as program_mod
from repro.core import netlib
from repro.core.dataflow import ConvSpec
from repro.core.phantom_linear import PhantomConfig
from repro.program.plans import _maxpool2  # one pooling primitive, one place
from .common import ParamSpec

__all__ = [
    "cnn_spec",
    "cnn_forward",
    "cnn_layers",
    "prepare_cnn_phantom",
    "cnn_forward_phantom",
]


def cnn_layers(name: str):
    return {
        "vgg16": netlib.vgg16_layers,
        "mobilenet": netlib.mobilenet_layers,
    }[name](include_fc=True)


def cnn_spec(name: str, input_hw: int = 224):
    layers = {
        "vgg16": netlib.vgg16_layers,
        "mobilenet": netlib.mobilenet_layers,
    }[name](include_fc=True, input_hw=input_hw)
    spec = {}
    for l in layers:
        if isinstance(l, ConvSpec):
            if l.depthwise:
                # HWIO with feature_group_count=in_ch: I dim is Cin/groups=1.
                shape = (l.kh, l.kw, 1, l.out_ch)
            else:
                shape = (l.kh, l.kw, l.in_ch, l.out_ch)
            spec[l.name] = {
                "w": ParamSpec(shape, (None, None, None, "mlp")),
                "b": ParamSpec((l.out_ch,), ("mlp",), init="zeros"),
            }
        else:
            spec[l.name] = {
                "w": ParamSpec((l.in_dim, l.out_dim), ("embed", "mlp")),
                "b": ParamSpec((l.out_dim,), ("mlp",), init="zeros"),
            }
    return spec, layers


def cnn_forward(params, x: jnp.ndarray, layers):
    """x: [B, H, W, 3] → logits.  ReLU after every layer (the paper's source
    of dynamic activation sparsity, §1)."""
    prev_hw = x.shape[1]
    for l in layers:
        if isinstance(l, ConvSpec):
            if l.in_h != prev_hw and prev_hw // 2 == l.in_h:
                x = _maxpool2(x)
            p = params[l.name]
            dn = jax.lax.conv_dimension_numbers(x.shape, p["w"].shape, ("NHWC", "HWIO", "NHWC"))
            x = jax.lax.conv_general_dilated(
                x,
                p["w"],
                window_strides=l.stride,
                padding=l.pad.upper(),
                dimension_numbers=dn,
                feature_group_count=l.in_ch if l.depthwise else 1,
            )
            x = jax.nn.relu(x + p["b"])
            prev_hw = x.shape[1]
        else:
            if x.ndim == 4:
                if l.pool == "gap":
                    x = x.mean(axis=(1, 2))
                else:
                    if l.pool == "pool5" and x.shape[1] > 1:
                        x = _maxpool2(x)
                    x = x.reshape(x.shape[0], -1)
            p = params[l.name]
            x = x @ p["w"] + p["b"]
            # Last layer by *position in the layer list* — matching the
            # phantom path; keying off dict order broke whenever ``params``
            # carried extra keys or was built in a different order.
            if l.name != layers[-1].name:
                x = jax.nn.relu(x)
    return x


def prepare_cnn_phantom(
    params,
    layers,
    batch: int,
    *,
    block: tuple[int, int, int] = (128, 128, 128),
    interleave: bool = True,
    conv_mode: str = "direct",
    dtype=jnp.float32,
):
    """DEPRECATED — use ``phantom.compile(layers, params, cfg, batch=...)``.

    Weight-load-time lowering of every conv/FC layer to the Phantom core.
    Returns ``{layer name: PhantomConvWeight | PhantomWeight}`` for the given
    ``batch``.  Delegates to :func:`repro.program.compile`: the returned
    dict is the program's own batch plan, so outputs are bit-for-bit
    identical to running the program.
    """
    program_mod.warn_deprecated(
        "repro.models.cnn.prepare_cnn_phantom", "phantom.compile"
    )
    cfg = PhantomConfig(
        enabled=True,
        block=tuple(block),
        interleave=interleave,
        conv_mode=conv_mode,
        dtype=jnp.dtype(dtype).name,
    )
    return program_mod.compile(layers, params, cfg, batch=batch).at_batch(batch)


def cnn_forward_phantom(
    params,
    prepared,
    x: jnp.ndarray,
    layers,
    *,
    act_threshold: float = 0.0,
    slot_mask: jnp.ndarray | None = None,
    interpret: bool | None = None,
):
    """DEPRECATED — compile once with ``phantom.compile`` and call the
    program instead.

    ``cnn_forward`` semantics with every conv/FC on the Phantom core —
    §3.8 masks flow between layers, τ is applied at the producer, and
    ``slot_mask`` gates padded serving slots.  Delegates to the program
    graph walk (:func:`repro.program.run_prepared`) over the caller's
    ``prepared`` dict, so it shares every code path with
    :class:`repro.program.PhantomProgram`.
    """
    program_mod.warn_deprecated(
        "repro.models.cnn.cnn_forward_phantom", "phantom.compile"
    )
    return program_mod.run_prepared(
        program_mod.build_nodes(layers),
        params,
        prepared,
        x,
        act_threshold=act_threshold,
        slot_mask=slot_mask,
        interpret=interpret,
    )
