"""VGG16 / MobileNetV1 in JAX — the faithful CNN reproduction path.

These are the networks the paper evaluates (§5.1).  The JAX forwards share
the layer tables in :mod:`repro.core.netlib`, so the cycle simulator and the
functional network agree on shapes.  ``phantom_infer_fc`` runs an FC layer
through the *functional Phantom core* (bit-exact engine) so end-to-end
example flows exercise the paper's datapath on real values.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import netlib
from repro.core.dataflow import ConvSpec, FCSpec
from .common import ParamSpec

__all__ = ["cnn_spec", "cnn_forward", "cnn_layers"]


def cnn_layers(name: str):
    return {
        "vgg16": netlib.vgg16_layers,
        "mobilenet": netlib.mobilenet_layers,
    }[name](include_fc=True)


def cnn_spec(name: str, input_hw: int = 224):
    layers = {
        "vgg16": netlib.vgg16_layers,
        "mobilenet": netlib.mobilenet_layers,
    }[name](include_fc=True, input_hw=input_hw)
    spec = {}
    for l in layers:
        if isinstance(l, ConvSpec):
            if l.depthwise:
                shape = (l.kh, l.kw, l.in_ch, 1)
            else:
                shape = (l.kh, l.kw, l.in_ch, l.out_ch)
            spec[l.name] = {
                "w": ParamSpec(shape, (None, None, None, "mlp")),
                "b": ParamSpec((l.out_ch,), ("mlp",), init="zeros"),
            }
        else:
            spec[l.name] = {
                "w": ParamSpec((l.in_dim, l.out_dim), ("embed", "mlp")),
                "b": ParamSpec((l.out_dim,), ("mlp",), init="zeros"),
            }
    return spec, layers


def cnn_forward(params, x: jnp.ndarray, layers, final_pool: bool = True):
    """x: [B, H, W, 3] → logits.  ReLU after every layer (the paper's source
    of dynamic activation sparsity, §1)."""
    prev_hw = x.shape[1]
    for l in layers:
        if isinstance(l, ConvSpec):
            if l.in_h != prev_hw and prev_hw // 2 == l.in_h:
                x = jax.lax.reduce_window(
                    x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
                )
            p = params[l.name]
            dn = jax.lax.conv_dimension_numbers(x.shape, p["w"].shape, ("NHWC", "HWIO", "NHWC"))
            x = jax.lax.conv_general_dilated(
                x,
                p["w"],
                window_strides=l.stride,
                padding="SAME",
                dimension_numbers=dn,
                feature_group_count=l.in_ch if l.depthwise else 1,
            )
            x = jax.nn.relu(x + p["b"])
            prev_hw = x.shape[1]
        else:
            if x.ndim == 4:
                if x.shape[1] * x.shape[2] * x.shape[3] != l.in_dim:
                    # Global average pool (MobileNet) vs flatten (VGG16).
                    x = x.mean(axis=(1, 2))
                else:
                    if final_pool and x.shape[1] > 7:
                        pass
                    x = x.reshape(x.shape[0], -1)
            p = params[l.name]
            x = x @ p["w"] + p["b"]
            if l.name != list(params)[-1]:
                x = jax.nn.relu(x)
    return x
