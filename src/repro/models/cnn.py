"""VGG16 / MobileNetV1 in JAX — the faithful CNN reproduction path.

These are the networks the paper evaluates (§5.1).  The JAX forwards share
the layer tables in :mod:`repro.core.netlib`, so the cycle simulator and the
functional network agree on shapes.

Two execution paths share one parameter pytree:

* ``cnn_forward`` — dense XLA (``lax.conv_general_dilated`` + matmul), the
  numerical oracle;
* ``prepare_cnn_phantom`` + ``cnn_forward_phantom`` — every conv *and* FC
  layer runs on the Phantom block-sparse core: convs lower through the
  direct implicit-im2col path by default (:mod:`repro.kernels.phantom_conv`,
  any stride / depthwise; ``conv_mode="im2col"`` falls back to the explicit
  patch-matrix path), FCs through :func:`repro.kernels.ops.phantom_matmul`,
  and each layer's §3.8 output-encoding element mask flows to the next
  layer's activation tile bits instead of re-inspecting values.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import netlib
from repro.core.dataflow import ConvSpec
from repro.kernels import ops, phantom_conv
from .common import ParamSpec

__all__ = [
    "cnn_spec",
    "cnn_forward",
    "cnn_layers",
    "prepare_cnn_phantom",
    "cnn_forward_phantom",
]


def cnn_layers(name: str):
    return {
        "vgg16": netlib.vgg16_layers,
        "mobilenet": netlib.mobilenet_layers,
    }[name](include_fc=True)


def cnn_spec(name: str, input_hw: int = 224):
    layers = {
        "vgg16": netlib.vgg16_layers,
        "mobilenet": netlib.mobilenet_layers,
    }[name](include_fc=True, input_hw=input_hw)
    spec = {}
    for l in layers:
        if isinstance(l, ConvSpec):
            if l.depthwise:
                # HWIO with feature_group_count=in_ch: I dim is Cin/groups=1.
                shape = (l.kh, l.kw, 1, l.out_ch)
            else:
                shape = (l.kh, l.kw, l.in_ch, l.out_ch)
            spec[l.name] = {
                "w": ParamSpec(shape, (None, None, None, "mlp")),
                "b": ParamSpec((l.out_ch,), ("mlp",), init="zeros"),
            }
        else:
            spec[l.name] = {
                "w": ParamSpec((l.in_dim, l.out_dim), ("embed", "mlp")),
                "b": ParamSpec((l.out_dim,), ("mlp",), init="zeros"),
            }
    return spec, layers


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_forward(params, x: jnp.ndarray, layers):
    """x: [B, H, W, 3] → logits.  ReLU after every layer (the paper's source
    of dynamic activation sparsity, §1)."""
    prev_hw = x.shape[1]
    for l in layers:
        if isinstance(l, ConvSpec):
            if l.in_h != prev_hw and prev_hw // 2 == l.in_h:
                x = _maxpool2(x)
            p = params[l.name]
            dn = jax.lax.conv_dimension_numbers(x.shape, p["w"].shape, ("NHWC", "HWIO", "NHWC"))
            x = jax.lax.conv_general_dilated(
                x,
                p["w"],
                window_strides=l.stride,
                padding=l.pad.upper(),
                dimension_numbers=dn,
                feature_group_count=l.in_ch if l.depthwise else 1,
            )
            x = jax.nn.relu(x + p["b"])
            prev_hw = x.shape[1]
        else:
            if x.ndim == 4:
                if l.pool == "gap":
                    x = x.mean(axis=(1, 2))
                else:
                    if l.pool == "pool5" and x.shape[1] > 1:
                        x = _maxpool2(x)
                    x = x.reshape(x.shape[0], -1)
            p = params[l.name]
            x = x @ p["w"] + p["b"]
            if l.name != list(params)[-1]:
                x = jax.nn.relu(x)
    return x


def prepare_cnn_phantom(
    params,
    layers,
    batch: int,
    *,
    block: tuple[int, int, int] = (128, 128, 128),
    interleave: bool = True,
    conv_mode: str = "direct",
    dtype=jnp.float32,
):
    """Weight-load-time lowering of every conv/FC layer to the Phantom core.

    Returns ``{layer name: PhantomConvWeight | PhantomWeight}`` for the given
    ``batch`` (the work queue's M-tile count is shape-specialised).  Prune
    the weights in ``params`` first; zero tiles never enter the queues.
    Convs use the direct implicit-im2col kernel by default;
    ``conv_mode="im2col"`` selects the explicit patch-matrix fallback.
    """
    prepared = {}
    for l in layers:
        w = np.asarray(params[l.name]["w"])
        if isinstance(l, ConvSpec):
            prepared[l.name] = phantom_conv.prepare_conv_weight(
                w,
                batch=batch,
                in_hw=(l.in_h, l.in_w),
                stride=l.stride,
                padding=l.pad,
                groups=l.in_ch if l.depthwise else 1,
                block=block,
                interleave=interleave,
                mode=conv_mode,
                dtype=dtype,
            )
        else:
            prepared[l.name] = ops.prepare_weight(
                w, m=batch, block=block, interleave=interleave, dtype=dtype
            )
    return prepared


def cnn_forward_phantom(
    params,
    prepared,
    x: jnp.ndarray,
    layers,
    *,
    act_threshold: float = 0.0,
    slot_mask: jnp.ndarray | None = None,
    interpret: bool | None = None,
):
    """``cnn_forward`` semantics with every conv/FC on the Phantom core.

    The §3.8 element mask of each layer's (post-ReLU) output flows forward:
    conv layers unfold it into patch tile bits
    (:func:`repro.kernels.phantom_conv.conv_patch_tile_bits`), FC layers
    tile-reduce it (:func:`repro.kernels.ops.element_mask_tile_bits`) — the
    consuming kernel never re-inspects activation values.  Max-pool keeps
    the mask exact (post-ReLU values are ≥ 0, so ``maxpool(x) ≠ 0 ⇔
    any(mask)``); global average pooling mixes channels, so the mask is
    re-encoded there.

    ``slot_mask`` (float [B], 1 = live, 0 = padded) re-zeroes dead batch
    slots after every layer's bias+ReLU — without it a zero image turns
    nonzero at ``relu(0 + b)`` and padded slots do full work from layer 2
    on.  With it their activations stay exactly zero, so the flowing mask
    gates every one of their tiles (per output row in the direct conv path;
    FC tiles gate only where a bm-row tile holds no live sample).  Live
    rows are unaffected — samples never mix across the batch dim.
    """
    prev_hw = x.shape[1]
    sm4 = sm2 = None
    if slot_mask is not None:
        sm4 = slot_mask[:, None, None, None]
        sm2 = slot_mask[:, None]
    mask = None  # producing layer's element mask; None ⇒ derive from values
    for l in layers:
        if isinstance(l, ConvSpec):
            if l.in_h != prev_hw and prev_hw // 2 == l.in_h:
                x = _maxpool2(x)
                if mask is not None:
                    mask = _maxpool2(mask.astype(x.dtype))
            p = params[l.name]
            y = phantom_conv.phantom_conv_call(
                x,
                prepared[l.name],
                x_mask=mask,
                # τ was applied when the producer emitted `mask`; only the
                # first layer (no mask yet) thresholds raw values.
                act_threshold=0.0 if mask is not None else act_threshold,
                interpret=interpret,
            )
            x = jax.nn.relu(y + p["b"])
            if sm4 is not None:
                x = x * sm4
            # §3.8 output encoding: the producer applies the (lossy) τ here;
            # consumers then gate on the mask's exact zeros.
            mask = (x > act_threshold).astype(x.dtype)
            prev_hw = x.shape[1]
        else:
            if x.ndim == 4:
                if l.pool == "gap":
                    # Averaging mixes channels — re-encode the mask.
                    x = x.mean(axis=(1, 2))
                    mask = (x != 0).astype(x.dtype)
                else:
                    if l.pool == "pool5" and x.shape[1] > 1:
                        x = _maxpool2(x)
                        if mask is not None:
                            mask = _maxpool2(mask.astype(x.dtype))
                    x = x.reshape(x.shape[0], -1)
                    if mask is not None:
                        mask = mask.reshape(mask.shape[0], -1)
            pw = prepared[l.name]
            bm, bk, _ = pw.block
            bits = (
                None
                if mask is None
                else ops.element_mask_tile_bits(mask, (bm, bk))
            )
            p = params[l.name]
            y = (
                ops.phantom_matmul(
                    x,
                    pw,
                    act_bits=bits,
                    act_threshold=act_threshold,
                    interpret=interpret,
                )
                + p["b"]
            )
            if l.name != layers[-1].name:
                x = jax.nn.relu(y)
                if sm2 is not None:
                    x = x * sm2
                mask = (x > act_threshold).astype(x.dtype)
            else:
                x = y
    return x
