"""Optimizer substrate: AdamW, schedules, clipping, gradient compression."""
from .adamw import AdamWConfig, init_opt_state, adamw_update, learning_rate
from .compression import int8_compress, int8_decompress, compressed_psum_grads

__all__ = [
    "AdamWConfig",
    "init_opt_state",
    "adamw_update",
    "learning_rate",
    "int8_compress",
    "int8_decompress",
    "compressed_psum_grads",
]
