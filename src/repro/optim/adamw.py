"""AdamW with decoupled weight decay, warmup + cosine/linear schedules, and
global-norm clipping.  Optimizer moments inherit the parameters' sharding
(same pytree structure → same PartitionSpecs), giving ZeRO-style sharded
optimizer state for free under the 2-D weight sharding rules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "learning_rate"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | constant
    min_lr_frac: float = 0.1


def learning_rate(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac)
        )
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init_opt_state(params):
    zeros = lambda t: jnp.zeros(t.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = learning_rate(cfg, step)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
