"""Int8 gradient compression with error feedback — cross-pod traffic knob.

Cross-pod ICI/DCN links are the scarcest bandwidth at 2+ pod scale; the
gradient all-reduce over the 'pod' axis is the only traffic that crosses
them under this framework's sharding rules (params are FSDP'd *within* a
pod).  ``compressed_psum_grads`` performs that reduction explicitly on int8
payloads (4× traffic cut vs f32, 2× vs bf16) with per-tensor max-abs
scaling, and carries the quantization residual in an **error-feedback**
buffer so the bias vanishes over steps (Karimireddy et al., 2019).

Implemented with ``shard_map`` over *only* the 'pod' axis ('data'/'model'
stay auto-partitioned), so it composes with FSDP/TP unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["int8_compress", "int8_decompress", "compressed_psum_grads"]


def int8_compress(x: jnp.ndarray):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def _pod_mean_int8(g, err):
    """Inside shard_map over 'pod': quantize(g+err) → psum int8 → dequant."""
    n_pods = jax.lax.axis_size("pod")
    g32 = g.astype(jnp.float32) + err
    q, scale = int8_compress(g32)
    sent = int8_decompress(q, scale)
    new_err = g32 - sent  # error feedback: residual re-sent next step
    tot = jax.lax.psum(q.astype(jnp.int32), "pod").astype(jnp.float32)
    # Scales differ per pod: reduce them too (mean of per-pod scales is exact
    # for the sum of dequantized payloads only if scales are shared; psum the
    # dequantized value instead when pods disagree strongly — here we psum
    # scale-weighted ints, the standard approximation).
    mean = tot * scale / n_pods
    return mean.astype(g.dtype), new_err


def compressed_psum_grads(grads, err_state, mesh):
    """Average *per-pod* gradients across pods with int8 payloads.

    ``grads``: per-pod mean gradients (identical sharding across pods);
    ``err_state``: error-feedback tree (f32, same structure).  Returns
    (global-mean grads, new err_state).  No-op when the mesh has no 'pod'
    axis.
    """
    if "pod" not in mesh.shape or mesh.shape["pod"] == 1:
        return grads, err_state

    # Manual only over 'pod'; 'data'/'model' stay auto-partitioned so this
    # composes with FSDP/TP sharding unchanged.
    fn = jax.shard_map(
        _pod_mean_int8,
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=(P(), P()),
        axis_names={"pod"},
        check_vma=False,
    )
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [fn(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])
