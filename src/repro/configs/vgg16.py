"""VGG16 — the paper's own primary evaluation network (§5.1, Figs. 19-23).

CNN archs use the netlib/cnn machinery rather than ModelConfig; the sparse
densities are the Deep-Compression-pruned values the paper compares at.
"""
from repro.core import netlib

LAYERS = netlib.vgg16_layers
WEIGHT_DENSITY = netlib.VGG16_WEIGHT_DENSITY
ACT_DENSITY = netlib.VGG16_ACT_DENSITY
CONFIG = {"name": "vgg16", "kind": "cnn"}
SMOKE = {"name": "vgg16", "kind": "cnn", "input_hw": 32}
