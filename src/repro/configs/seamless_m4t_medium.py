"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

Backbone only: 12 encoder + 12 decoder layers; the audio frontend is a stub
providing precomputed frame embeddings (per assignment).  Decode shapes
lower the decoder serve_step (self-attn KV cache + cross-attn cache).
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    head_dim=64,
    frontend="audio",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, head_dim=16,
)
