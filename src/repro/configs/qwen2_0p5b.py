"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936
— GQA, QKV bias [arXiv:2407.10671; hf].  Tied embeddings."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151936,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16,
)
