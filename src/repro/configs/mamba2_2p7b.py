"""mamba2-2.7b [ssm]: 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified].

Attention-free and sub-quadratic → runs long_500k.  The Phantom technique
applies only to the dense in/out projections (the SSD recurrence has no
zero-skippable GEMM tiles — DESIGN.md §6).
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    subquadratic=True,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, vocab=512, ssm_state=16, ssm_head_dim=16,
)
