"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2 [hf:xai-org/grok-1; unverified]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    moe_d_ff=32768,
    vocab=131072,
    head_dim=128,
    n_experts=8,
    top_k=2,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    moe_d_ff=128, vocab=512, head_dim=16, n_experts=4, top_k=2,
)
