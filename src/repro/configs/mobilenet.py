"""MobileNetV1 — the paper's second evaluation network (§5.1, Figs. 22, 24).

Includes the non-unit-stride depthwise layers SCNN cannot run (G3).
"""
from repro.core import netlib

LAYERS = netlib.mobilenet_layers
WEIGHT_DENSITY = netlib.MOBILENET_WEIGHT_DENSITY
ACT_DENSITY = netlib.MOBILENET_ACT_DENSITY
CONFIG = {"name": "mobilenet", "kind": "cnn"}
SMOKE = {"name": "mobilenet", "kind": "cnn", "input_hw": 32}
