"""Assigned input shapes × per-shape input_specs (ShapeDtypeStruct, no
allocation — the shannon/kernels dry-run pattern).

  train_4k     seq 4,096  global_batch 256   → train_step
  prefill_32k  seq 32,768 global_batch 32    → serve prefill (forward)
  decode_32k   ctx 32,768 global_batch 128   → serve_step (1 token + cache)
  long_500k    ctx 524,288 global_batch 1    → serve_step, sub-quadratic only

``[audio]``/``[vlm]`` archs get stub frontend embeddings in their specs (the
assignment: ``input_specs()`` provides precomputed frame/patch embeddings).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import Model, build

__all__ = ["SHAPES", "ShapeSpec", "input_specs", "step_kind"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def step_kind(shape_name: str) -> str:
    return SHAPES[shape_name].kind


def _frontend_len(seq: int) -> int:
    return max(min(1024, seq // 4), 1)


def input_specs(cfg: ModelConfig, shape_name: str, model: Model | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape cell."""
    sh = SHAPES[shape_name]
    b, s = sh.global_batch, sh.seq_len
    i32 = jnp.int32
    tok = lambda *shape: jax.ShapeDtypeStruct(shape, i32)
    model = model or build(cfg)

    if sh.kind == "train":
        specs = {"tokens": tok(b, s), "labels": tok(b, s)}
        if cfg.family == "encdec":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), cfg.dtype()
            )
        elif cfg.frontend:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, _frontend_len(s), cfg.d_model), cfg.dtype()
            )
        return specs

    if sh.kind == "prefill":
        specs = {"tokens": tok(b, s)}
        if cfg.family == "encdec":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), cfg.dtype()
            )
        elif cfg.frontend:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, _frontend_len(s), cfg.d_model), cfg.dtype()
            )
        return specs

    # decode: one new token against a seq_len-deep cache.
    if cfg.family == "encdec":
        cache = jax.eval_shape(lambda: model.init_cache(b, s, s))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {
        "tokens": tok(b, 1),
        "cache": cache,
        "index": jax.ShapeDtypeStruct((), i32),
    }
