"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE + dynamic resolution [arXiv:2409.12191; hf].  Backbone only — the
vision frontend is a stub providing precomputed patch embeddings (per
assignment).  M-RoPE sections (t, h, w) = (16, 24, 24) over head_dim/2 = 64.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    frontend="vision",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    head_dim=16,
    mrope_sections=(2, 3, 3),
)
