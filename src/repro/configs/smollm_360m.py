"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
— llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf].  Tied embeddings.

Also the family used by the end-to-end ~100M training example."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
    head_dim=64,
    rope_theta=1e4,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=5, n_kv_heads=5, d_ff=128,
    vocab=512, head_dim=16,
)

# ~100M-param config for the end-to-end training example (same family).
TRAIN_100M = dataclasses.replace(
    CONFIG, name="smollm-100m", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=1536, vocab=16384, head_dim=64,
)
