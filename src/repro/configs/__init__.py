"""Architecture registry: the 10 assigned archs + the paper's own CNNs.

``get_config(name)`` returns the full published configuration;
``get_smoke(name)`` a reduced same-family config for CPU smoke tests.
``ARCHS`` lists every selectable ``--arch`` id.
"""
from __future__ import annotations

import dataclasses
import importlib

__all__ = ["ARCHS", "CNN_ARCHS", "get_config", "get_smoke", "shape_grid"]

ARCHS = [
    "qwen2_vl_7b",
    "zamba2_2p7b",
    "deepseek_coder_33b",
    "qwen2_0p5b",
    "smollm_360m",
    "internlm2_20b",
    "seamless_m4t_medium",
    "moonshot_v1_16b_a3b",
    "grok_1_314b",
    "mamba2_2p7b",
]
CNN_ARCHS = ["vgg16", "mobilenet"]

_ALIAS = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "zamba2-2.7b": "zamba2_2p7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen2-0.5b": "qwen2_0p5b",
    "smollm-360m": "smollm_360m",
    "internlm2-20b": "internlm2_20b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "grok-1-314b": "grok_1_314b",
    "mamba2-2.7b": "mamba2_2p7b",
}


def _module(name: str):
    name = _ALIAS.get(name, name).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


def shape_grid(name: str) -> list[str]:
    """The shape set assigned to an arch (long_500k only for sub-quadratic
    families; pure full-attention archs skip it — DESIGN.md §6)."""
    cfg = get_config(name)
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        shapes.append("long_500k")
    return shapes
