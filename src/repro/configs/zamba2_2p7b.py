"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf].

The shared attention+MLP block (single weight copy) is applied every 6
Mamba2 layers, zamba2-style; each application keeps its own KV cache.
Sub-quadratic backbone → runs long_500k.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    hybrid_attn_every=6,
    subquadratic=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    head_dim=16,
    ssm_state=16,
    ssm_head_dim=16,
    hybrid_attn_every=2,
)
