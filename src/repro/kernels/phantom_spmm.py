"""Two-sided block-sparse matmul — the Phantom core on the MXU.

``y[M, N] = x[M, K] @ w[K, N]`` where

* the weight's zero (bk × bn) tiles are *compacted away*: the grid walks a
  dense work queue of effectual tiles (``repro.core.blocksparse.WorkQueue``),
  so — exactly like the paper's TDS — no compute step is ever issued for a
  zero weight tile, and the packed weight payload (§3.1 sparse-mask storage)
  is the only weight data that ever moves HBM→VMEM;
* the activation's zero tiles are *gated*: the per-step activation tile bit
  arrives via scalar prefetch and a ``pl.when`` skips the MXU op (DESIGN.md
  §2 records this asymmetry vs. the paper) — and with
  ``PhantomConfig(lookahead=L)`` the queue is additionally *compacted* at
  call time so dead steps leave the executed grid entirely: ``num_steps`` /
  ``counts`` below bound the grid after
  :func:`repro.kernels.compaction.compact_queue` (DESIGN.md §10).

Accumulation is k-major in a VMEM fp32 scratch tile that stays resident for
a full (mi, ni) run — the paper's output-buffer L2 accumulation with zero
partial-output HBM traffic.

This kernel is also the execution engine for *convolutions* (the paper's
headline: all CNN layer kinds, §4 goal G3).  ``repro.kernels.phantom_conv``
lowers Conv2D to it via im2col: the [kh, kw, Cin, Cout] weight reshapes to
[kh·kw·Cin, Cout] (grouped/depthwise becomes block-diagonal) and is packed
once at load time; activations unfold to a [B·oh·ow, kh·kw·Cin] patch
matrix.  Stride and padding are absorbed entirely at patch extraction — the
M dimension simply shrinks to B·⌈H/sh⌉·⌈W/sw⌉ — so non-unit-stride layers
(where SCNN degrades) run through the identical queue/kernel machinery at
proportionally *fewer* grid steps, and the per-layer §3.8 element mask
unfolds through the same im2col into the next layer's activation tile bits.

BlockSpec layout (VMEM):
  x: (bm, bk) tile at (mi[i], ki[i])
  w: (1, bk, bn) tile of the packed [nnzb, bk, bn] payload at wq[i]
  y: (bm, bn) tile at (mi[i], ni[i])   — written on ``last`` steps only
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import ACTIVATIONS

__all__ = [
    "phantom_spmm_kernel",
    "phantom_spmm_call",
    "phantom_spmm_multicore_kernel",
    "phantom_spmm_multicore_call",
]


def phantom_spmm_kernel(
    # --- scalar prefetch (SMEM) ---
    mi_ref,
    ni_ref,
    ki_ref,
    wq_ref,
    start_ref,
    last_ref,
    abit_ref,
    # --- VMEM operands ---
    x_ref,
    w_ref,
    o_ref,
    # --- scratch ---
    acc_ref,
):
    i = pl.program_id(0)

    @pl.when(start_ref[i] == 1)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(abit_ref[i] == 1)
    def _mac():  # effectual tile: one MXU op
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(last_ref[i] == 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block", "grid_tiles", "out_dtype", "interpret"),
)
def phantom_spmm_call(
    x: jnp.ndarray,  # [M, K] (padded to tile multiples)
    w_packed: jnp.ndarray,  # [nnzb, bk, bn]
    mi: jnp.ndarray,  # int32 [Q] queue arrays (incl. empty-output steps)
    ni: jnp.ndarray,
    ki: jnp.ndarray,
    wq: jnp.ndarray,
    start: jnp.ndarray,
    last: jnp.ndarray,
    abit: jnp.ndarray,  # int32 [Q] activation tile bit per step (dynamic)
    num_steps=None,  # traced [] grid bound after lookahead compaction (§10)
    *,
    block: tuple[int, int, int],
    grid_tiles: tuple[int, int, int],
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    bm, bk, bn = block
    mt, _kt, nt = grid_tiles
    q = mi.shape[0] if num_steps is None else num_steps
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(q,),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, mi, ni, ki, wq, st, la, ab: (mi[i], ki[i])),
            pl.BlockSpec((1, bk, bn), lambda i, mi, ni, ki, wq, st, la, ab: (wq[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (bm, bn), lambda i, mi, ni, ki, wq, st, la, ab: (mi[i], ni[i])
        ),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        phantom_spmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mt * bm, nt * bn), out_dtype),
        interpret=interpret,
    )(mi, ni, ki, wq, start, last, abit, x, w_packed)


def phantom_spmm_multicore_kernel(
    # --- scalar prefetch (SMEM), all int32 [cores, Qpad] ---
    mi_ref,
    ni_ref,
    ki_ref,
    wq_ref,
    start_ref,
    last_ref,
    abit_ref,
    # --- VMEM operands ---
    x_ref,
    w_ref,
    o_ref,  # (1, bm, bn) slab of the [cores, M, ntc*bn] output
    # --- scratch ---
    acc_ref,
    *,
    activation: str,
):
    c, i = pl.program_id(0), pl.program_id(1)

    @pl.when(start_ref[c, i] == 1)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(abit_ref[c, i] == 1)
    def _mac():
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(last_ref[c, i] == 1)
    def _flush():
        o_ref[0] = ACTIVATIONS[activation](acc_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block", "grid_tiles", "activation", "out_dtype", "interpret"),
)
def phantom_spmm_multicore_call(
    x: jnp.ndarray,  # [M, K] (padded to tile multiples; shared by all cores)
    w_packed: jnp.ndarray,  # [nnzb, bk, bn] per-core payloads concatenated
    mi: jnp.ndarray,  # int32 [cores, Qpad] per-core queues, makespan-padded
    ni: jnp.ndarray,  # (ni is the core-local output column)
    ki: jnp.ndarray,
    wq: jnp.ndarray,
    start: jnp.ndarray,
    last: jnp.ndarray,
    abit: jnp.ndarray,
    counts=None,  # traced [cores] per-core executed-step counts (§10)
    *,
    block: tuple[int, int, int],
    grid_tiles: tuple[int, int, int],  # (Mt, Kt, ntc) — ntc is PER-CORE width
    activation: str = "none",
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """Multi-core Phantom-2D execution (DESIGN.md §9): one ``pallas_call``
    whose leading grid axis walks the virtual cores.

    Each core consumes its own compacted, makespan-padded work queue (the
    2-D scalar-prefetch arrays) and writes its own ``[M, ntc·bn]`` output
    slab — cores never touch each other's columns, so on a multi-device
    backend the leading axis shard_maps onto a device mesh unchanged
    (:func:`repro.parallel.sharding.shard_cores_call`); on one device it is
    a sequential grid dimension with identical numerics.  The host stitches
    slabs back through the inverse column permutation
    (:func:`repro.kernels.ops.stitch_core_outputs`).

    ``counts`` (lookahead compaction, DESIGN.md §10) bounds the step axis
    at ``max(counts)`` — cores run in lock-step (§4.6), so the makespan is
    the slowest core's compacted count; shorter cores idle on their inert
    tail steps.
    """
    bm, bk, bn = block
    mt, _kt, ntc = grid_tiles
    cores, q = mi.shape
    if counts is not None:
        q = jnp.max(counts)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(cores, q),
        in_specs=[
            pl.BlockSpec(
                (bm, bk), lambda c, i, mi, ni, ki, wq, st, la, ab: (mi[c, i], ki[c, i])
            ),
            pl.BlockSpec(
                (1, bk, bn), lambda c, i, mi, ni, ki, wq, st, la, ab: (wq[c, i], 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, bm, bn), lambda c, i, mi, ni, ki, wq, st, la, ab: (c, mi[c, i], ni[c, i])
        ),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = functools.partial(phantom_spmm_multicore_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cores, mt * bm, ntc * bn), out_dtype),
        interpret=interpret,
    )(mi, ni, ki, wq, start, last, abit, x, w_packed)
