"""Runtime lookahead compaction — TDS on the kernel path (DESIGN.md §10).

§3.8 activation bits have so far only *gated* a queue step's MXU op: the
step still occupied a grid iteration, so runtime activation sparsity bought
no wall time (DESIGN.md §2 records the asymmetry).  This module closes the
gap with the paper's §3.4 Top-Down Selector semantics: at call time, given
the already-computed per-step activation bits, the work queue is compacted
so that activation-dead steps are squeezed out of the executed grid
entirely — the same elision Fig. 19b attributes to the lookahead window
``L_f``.

The cycle model is exactly :func:`repro.core.tds.batch_cycles` with
``threads=1, policy="inorder"`` applied per accumulation segment (one
(mi, ni) run = one TDS column queue): each executed step examines a window
of up to ``lookahead`` queue entries, retires every all-zero entry in it
for free, and performs at most one effectual MAC.  A segment of ``d`` dead
entries therefore costs ``ceil(d / lookahead)`` pacing steps instead of
``d`` — and exactly one of those doubles as the §3.8 zero-writer when the
whole segment is dead.

Mechanically (all traced, so the queue compaction itself jits):

1. a ``lax.scan`` over the queue replays the TDS cycle model and marks the
   one *kept* entry per cycle (the effectual entry, or the cycle's closer
   when the cycle is dead);
2. ``start``/``last`` are re-derived from the keep mask's prefix sums so
   each segment's surviving entries still zero the accumulator exactly once
   and flush exactly once;
3. a stable argsort moves kept entries to the queue front; the tail repeats
   the last kept entry with flags zeroed — the same inert-tail invariant as
   the multi-core makespan padding (a revisit targets the just-flushed
   block, so an end-of-window writeback rewrites identical VMEM contents);
4. the kernel grid is bounded by the kept-entry count (a traced grid
   dimension): single-core grids shrink to exactly the executed steps,
   multi-core grids to ``max`` over the per-core counts (§4.6 lock-step).

The static per-entry segment metadata (:func:`compaction_meta`) is computed
once at weight-load time and stored on the artifact; only the activation
bits are dynamic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["compaction_meta", "compact_queue", "lookahead_stats"]


def compaction_meta(start: np.ndarray, real_len=None) -> dict:
    """Static per-entry segment metadata for :func:`compact_queue`.

    ``start``: int [Q] or [cores, Qpad] segment-start flags;
    ``real_len``: per-row count of real (non-makespan-padding) entries —
    ``None`` means every entry is real (single-core queues).

    Returns ``{"seg_base", "seg_end", "pad"}`` (int32/bool, same shape as
    ``start``): ``seg_base[t]`` is the entry index *before* t's segment
    start (−1 for the first segment), ``seg_end[t]`` the index of its last
    real entry, ``pad[t]`` whether t is makespan padding.  Computed once at
    weight-load time (host numpy) and stored on the artifact.
    """
    s2 = np.atleast_2d(np.asarray(start, dtype=np.int32))
    rows, q = s2.shape
    if real_len is None:
        reals = np.full(rows, q, dtype=np.int64)
    else:
        reals = np.asarray(real_len, dtype=np.int64).reshape(rows)
    idx = np.arange(q)
    seg_base = np.empty((rows, q), np.int32)
    seg_end = np.empty((rows, q), np.int32)
    pad = np.empty((rows, q), bool)
    for r in range(rows):
        real = int(reals[r])
        p = idx >= real
        s = (s2[r] == 1) & ~p
        # last segment start at or before t  (first entry always starts)
        seg_start = np.maximum.accumulate(np.where(s, idx, -1))
        # first segment start strictly after t (q when none)
        nxt = np.where(s, idx, q)
        suffix_min = np.minimum.accumulate(nxt[::-1])[::-1]
        nxt_after = np.concatenate([suffix_min[1:], [q]])
        seg_base[r] = (seg_start - 1).astype(np.int32)
        seg_end[r] = (np.minimum(nxt_after, real) - 1).astype(np.int32)
        pad[r] = p
    if np.asarray(start).ndim == 1:
        return {"seg_base": seg_base[0], "seg_end": seg_end[0], "pad": pad[0]}
    return {"seg_base": seg_base, "seg_end": seg_end, "pad": pad}


def _compact_row(fields, start, last, abit, seg_base, seg_end, pad, lookahead):
    q = start.shape[0]
    a = (abit == 1)

    # -- 1. replay the TDS cycle model (threads=1, in-order) ------------------
    def step(carry, inp):
        c, got = carry  # entries retired in the open cycle; cycle has its MAC
        a_t, s_t, p_t = inp
        new = ((s_t == 1) | (c >= lookahead) | (a_t & got)) & ~p_t
        c2 = jnp.where(p_t, c, jnp.where(new, 1, c + 1))
        got2 = jnp.where(p_t, got, jnp.where(new, a_t, got | a_t))
        return (c2, got2), (new, got2)

    (_, _), (new_cycle, got_after) = jax.lax.scan(
        step,
        (jnp.int32(lookahead), jnp.bool_(False)),
        (a, start.astype(jnp.int32), pad),
    )
    # an entry closes its cycle when the next entry opens a new one (or the
    # real queue ends); the closer of a dead cycle is kept as its pacing /
    # §3.8 zero-writer step, every effectual entry is kept as its cycle's MAC
    true1 = jnp.ones((1,), bool)
    close_after = jnp.concatenate([new_cycle[1:], true1]) | jnp.concatenate(
        [pad[1:], true1]
    )
    keep = ~pad & (a | (~got_after & close_after))

    # -- 2. re-derive start/last from surviving per-segment ranks -------------
    kc = jnp.cumsum(keep.astype(jnp.int32))
    base = jnp.where(seg_base >= 0, kc[jnp.maximum(seg_base, 0)], 0)
    rank = kc - base
    tot = kc[seg_end] - base
    new_start = keep & (rank == 1)
    new_last = keep & (rank == tot)

    # -- 3. stable compaction + inert tail ------------------------------------
    order = jnp.argsort((~keep).astype(jnp.int32), stable=True)
    count = kc[q - 1]
    pos = jnp.arange(q)

    def gather_index(arr):
        g = arr[order]
        return jnp.where(pos < count, g, g[count - 1])  # tail: repeat last kept

    out = {k: gather_index(v) for k, v in fields.items()}
    # flags/abit: entries past `count` came from dropped steps, which are
    # never effectual and never flagged — the inert tail is 0 by construction
    start_c = new_start.astype(jnp.int32)[order]
    last_c = new_last.astype(jnp.int32)[order]
    abit_c = (a & keep).astype(jnp.int32)[order]
    return out, start_c, last_c, abit_c, count


@functools.partial(jax.jit, static_argnames=("lookahead",))
def compact_queue(fields, start, last, abit, seg_base, seg_end, pad, *, lookahead):
    """Compact one queue (1-D) or one queue per core (2-D) against the
    dynamic activation bits.

    ``fields``: dict of int32 index arrays (``mi``/``ni``/``ki``/``wq``, or
    the conv offset arrays) — compacted to the front, tail repeating the
    last kept entry; ``start``/``last`` are re-derived, ``abit`` keeps only
    effectual entries.  Returns ``(fields, start, last, abit, count)`` with
    ``count`` int32 [] (1-D) or [cores] (2-D) — the executed grid bound.
    """
    if int(lookahead) < 1:
        raise ValueError(f"lookahead must be >= 1 to compact, got {lookahead}")
    start = jnp.asarray(start)
    args = (fields, start, jnp.asarray(last), jnp.asarray(abit),
            jnp.asarray(seg_base), jnp.asarray(seg_end), jnp.asarray(pad))
    if start.ndim == 2:
        return jax.vmap(
            lambda *a: _compact_row(*a, lookahead=lookahead)
        )(*args)
    return _compact_row(*args, lookahead=lookahead)


def lookahead_stats(art, act_bits, *, lookahead=None) -> dict:
    """Host-side executed-step accounting for an artifact + activation bits,
    via :func:`repro.core.tds.batch_cycles` on the per-segment popcounts —
    the simulator-side number the kernel's compacted grid bound must equal
    (asserted in the tests; the engine↔simulator contract of DESIGN.md §5
    extended to runtime compaction).

    ``art``: a :class:`repro.kernels.ops.PhantomWeight` or
    :class:`repro.kernels.phantom_conv.DirectConvPlan`; ``act_bits``: the
    int [Mt, Kt] tile bits the call would consume; ``lookahead``: override
    of ``art.lookahead`` (0 ⇒ today's gated behaviour, where every padded
    queue slot costs a grid step).

    Returns ``lookahead``, ``queue_steps`` (padded per-core queue length),
    ``executed_steps`` (grid bound actually run: per-core max, §4.6
    lock-step), ``retired_per_step`` (real queue entries retired per
    executed grid slot), ``utilization`` (effectual-MAC steps per executed
    grid slot — ``valid_macs / (cycles · pes · threads)`` of
    :class:`repro.core.tds.TdsSchedule` with one thread per core), and
    ``per_core_executed`` for multi-core artifacts.
    """
    from repro.core import tds

    la = getattr(art, "lookahead", 0) if lookahead is None else int(lookahead or 0)
    bits = np.asarray(act_bits).reshape(-1)
    fa = np.atleast_2d(np.asarray(art.flat_ak))
    va = np.atleast_2d(np.asarray(art.valid))
    st = np.atleast_2d(np.asarray(art.start))
    cores = getattr(art, "cores", 1)
    qpad = fa.shape[1]
    reals = (
        np.asarray(art.core_steps, dtype=np.int64)
        if cores > 1
        else np.full(fa.shape[0], qpad, dtype=np.int64)
    )
    per_exec, retired, live = [], 0, 0
    for r in range(fa.shape[0]):
        real = int(reals[r])
        a = (bits[fa[r, :real]] * va[r, :real]).astype(np.int32)
        starts = np.flatnonzero(st[r, :real] == 1)
        segs = np.split(a, starts[1:]) if len(starts) else [a]
        retired += real
        live += int(a.sum())
        if la:
            lengths = np.asarray([len(s) for s in segs], dtype=np.int64)
            pops = np.zeros((len(segs), int(lengths.max())), np.int32)
            for i, s in enumerate(segs):
                pops[i, : len(s)] = s
            cyc = tds.batch_cycles(
                pops, lengths, lookahead=la, threads=1, policy="inorder"
            )
            per_exec.append(int(cyc.sum()))
        else:
            per_exec.append(qpad)  # gated: every padded slot is a grid step
    executed = max(per_exec)
    slots = cores * executed
    out = {
        "lookahead": la,
        "queue_steps": qpad,
        "executed_steps": executed,
        "retired_per_step": retired / slots if slots else 0.0,
        "utilization": live / slots if slots else 0.0,
    }
    if cores > 1:
        out["per_core_executed"] = per_exec
    return out
