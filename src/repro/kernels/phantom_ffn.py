"""Fused block-sparse linear + activation + output-encoding epilogue.

Same two-sided schedule as :mod:`repro.kernels.phantom_spmm`, plus — on the
``last`` step of each (mi, ni) accumulation run, while the fp32 tile is still
resident in VMEM — the activation function and the §3.8 output encoding: the
consumer layer's activation tile bit ``any(|act(y_tile)| > τ)``.  Fusing the
encoding here means the next layer's sparsity metadata costs zero extra HBM
reads (the paper generates the output sparse mask on the fly for exactly
this reason).

Extra output: ``y_mask`` int32 [Mt, Nt] tile mask, BlockSpec (1, 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import ACTIVATIONS

__all__ = ["phantom_linear_act_kernel", "phantom_linear_act_call"]


def phantom_linear_act_kernel(
    mi_ref,
    ni_ref,
    ki_ref,
    wq_ref,
    start_ref,
    last_ref,
    abit_ref,
    x_ref,
    w_ref,
    o_ref,
    omask_ref,
    acc_ref,
    *,
    activation: str,
    threshold: float,
):
    i = pl.program_id(0)

    @pl.when(start_ref[i] == 1)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(abit_ref[i] == 1)
    def _mac():
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(last_ref[i] == 1)
    def _flush():
        y = ACTIVATIONS[activation](acc_ref[...])
        o_ref[...] = y.astype(o_ref.dtype)
        # §3.8 output encoding, post-activation, on the resident tile.
        omask_ref[0, 0] = jnp.any(jnp.abs(y) > threshold).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block",
        "grid_tiles",
        "activation",
        "threshold",
        "out_dtype",
        "interpret",
    ),
)
def phantom_linear_act_call(
    x,
    w_packed,
    mi,
    ni,
    ki,
    wq,
    start,
    last,
    abit,
    num_steps=None,  # traced [] grid bound after lookahead compaction (§10)
    *,
    block: tuple[int, int, int],
    grid_tiles: tuple[int, int, int],
    activation: str = "none",
    threshold: float = 0.0,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    bm, bk, bn = block
    mt, _kt, nt = grid_tiles
    q = mi.shape[0] if num_steps is None else num_steps
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(q,),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, mi, ni, ki, wq, st, la, ab: (mi[i], ki[i])),
            pl.BlockSpec((1, bk, bn), lambda i, mi, ni, ki, wq, st, la, ab: (wq[i], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, mi, ni, ki, wq, st, la, ab: (mi[i], ni[i])),
            pl.BlockSpec((1, 1), lambda i, mi, ni, ki, wq, st, la, ab: (mi[i], ni[i])),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = functools.partial(
        phantom_linear_act_kernel, activation=activation, threshold=threshold
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((mt * bm, nt * bn), out_dtype),
            jax.ShapeDtypeStruct((mt, nt), jnp.int32),
        ],
        interpret=interpret,
    )(mi, ni, ki, wq, start, last, abit, x, w_packed)
