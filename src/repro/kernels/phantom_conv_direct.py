"""Direct (implicit-im2col) block-sparse convolution — patch gather in-kernel.

The im2col lowering (:mod:`repro.kernels.phantom_conv`) materialises the
``[B·oh·ow, kh·kw·Cin]`` patch matrix in HBM first: a ``kh·kw``× activation
blowup (9× for 3×3 layers) that the paper's core never pays.  This kernel
removes it.  The only array inputs are the *phase-decomposed padded
activation* and the packed nonzero weight payload; the patch gather happens
at tile-fetch time, driven by the work queue's precomputed spatial
coordinates (DESIGN.md §3).

Decomposition (host side, :func:`repro.kernels.phantom_conv` builds it):

* M is tiled per output row: m-tile ``mi = b·oh + oy`` covers the ``ow``
  flattened output positions of one (batch, output-row) pair, so
  ``bm = ow`` and ``M = Mt·ow`` exactly — no M padding, ever;
* K is tiled per filter tap: flat k-tile ``(ky·kw + kx)·ct + ci`` covers one
  (ky, kx) window offset and one ``bk``-wide Cin block, so a k-tile never
  straddles a tap boundary and its source is *contiguous* in the activation;
* stride is absorbed by phase decomposition: the padded input reshapes to
  ``xph[(ky%sh)·sw + kx%sw, b, i, j, c] = xp[b, i·sh + ky%sh, j·sw + kx%sw, c]``
  — a constant-factor copy (identity for stride 1), after which the tile for
  queue step ``(mi, ky, kx, ci)`` is the contiguous window
  ``xph[ph, b, oy + ky//sh, kx//sw : kx//sw + ow, ci·bk : (ci+1)·bk]``.

Those five offsets are precomputed per queue step and shipped via scalar
prefetch; the activation BlockSpec uses **unblocked (element-offset)
indexing**, so each grid step DMAs exactly its ``[ow, bk]`` window out of the
raw activation — the patch matrix is never built.  Weight compaction and
activation gating are identical to :mod:`repro.kernels.phantom_spmm`: zero
weight tiles never enter the queue, zero activation tiles skip their MXU op
via the prefetched tile bit.

BlockSpec layout (VMEM):
  xph: (1, 1, 1, ow, bk) window at element offsets
       (ph[i], nb[i], r0[i], c0[i], ch0[i])          [unblocked indexing]
  w:   (1, bk, bn) tile of the packed [nnzb, bk, bn] payload at wq[i]
  y:   (ow, bn) tile at (mi[i], ni[i])    — written on ``last`` steps only
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import ACTIVATIONS

__all__ = [
    "phantom_conv_direct_kernel",
    "phantom_conv_direct_call",
    "phantom_conv_direct_multicore_kernel",
    "phantom_conv_direct_multicore_call",
]


def phantom_conv_direct_kernel(
    # --- scalar prefetch (SMEM) ---
    ph_ref,
    nb_ref,
    r0_ref,
    c0_ref,
    ch0_ref,
    mi_ref,
    ni_ref,
    wq_ref,
    start_ref,
    last_ref,
    abit_ref,
    # --- VMEM operands ---
    x_ref,  # (1, 1, 1, ow, bk) activation window
    w_ref,  # (1, bk, bn) packed weight tile
    o_ref,  # (ow, bn)
    # --- scratch ---
    acc_ref,
    *,
    activation: str,
):
    i = pl.program_id(0)

    @pl.when(start_ref[i] == 1)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(abit_ref[i] == 1)
    def _mac():  # effectual tile: gather-free dot on the strided window
        acc_ref[...] += jnp.dot(
            x_ref[0, 0, 0], w_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(last_ref[i] == 1)
    def _flush():
        o_ref[...] = ACTIVATIONS[activation](acc_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "ow",
        "block",
        "grid_tiles",
        "activation",
        "out_dtype",
        "interpret",
    ),
)
def phantom_conv_direct_call(
    xph: jnp.ndarray,  # [PH, B, Hq, Wq, Cp] phase-decomposed padded activation
    w_packed: jnp.ndarray,  # [nnzb, bk, bn]
    ph: jnp.ndarray,  # int32 [Q] per-step source offsets (see module docstring)
    nb: jnp.ndarray,
    r0: jnp.ndarray,
    c0: jnp.ndarray,
    ch0: jnp.ndarray,
    mi: jnp.ndarray,  # int32 [Q] queue arrays (incl. empty-output steps)
    ni: jnp.ndarray,
    wq: jnp.ndarray,
    start: jnp.ndarray,
    last: jnp.ndarray,
    abit: jnp.ndarray,  # int32 [Q] activation tile bit per step (dynamic)
    num_steps=None,  # traced [] grid bound after lookahead compaction (§10)
    *,
    ow: int,
    block: tuple[int, int],  # (bk, bn)
    grid_tiles: tuple[int, int, int],  # (Mt = B·oh, Kt = kh·kw·ct, Nt)
    activation: str = "none",
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    bk, bn = block
    mt, _kt, nt = grid_tiles
    q = mi.shape[0] if num_steps is None else num_steps
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=11,
        grid=(q,),
        in_specs=[
            pl.BlockSpec(
                (1, 1, 1, ow, bk),
                lambda i, ph, nb, r0, c0, ch0, mi, ni, wq, st, la, ab: (
                    ph[i],
                    nb[i],
                    r0[i],
                    c0[i],
                    ch0[i],
                ),
                indexing_mode=pl.Unblocked(),
            ),
            pl.BlockSpec(
                (1, bk, bn),
                lambda i, ph, nb, r0, c0, ch0, mi, ni, wq, st, la, ab: (wq[i], 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (ow, bn),
            lambda i, ph, nb, r0, c0, ch0, mi, ni, wq, st, la, ab: (mi[i], ni[i]),
        ),
        scratch_shapes=[pltpu.VMEM((ow, bn), jnp.float32)],
    )
    kernel = functools.partial(phantom_conv_direct_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mt * ow, nt * bn), out_dtype),
        interpret=interpret,
    )(ph, nb, r0, c0, ch0, mi, ni, wq, start, last, abit, xph, w_packed)


def phantom_conv_direct_multicore_kernel(
    # --- scalar prefetch (SMEM), all int32 [cores, Qpad] ---
    ph_ref,
    nb_ref,
    r0_ref,
    c0_ref,
    ch0_ref,
    mi_ref,
    ni_ref,
    wq_ref,
    start_ref,
    last_ref,
    abit_ref,
    # --- VMEM operands ---
    x_ref,  # (1, 1, 1, ow, bk) activation window
    w_ref,  # (1, bk, bn) packed weight tile
    o_ref,  # (1, ow, bn) slab of the [cores, M, ntc*bn] output
    # --- scratch ---
    acc_ref,
    *,
    activation: str,
):
    c, i = pl.program_id(0), pl.program_id(1)

    @pl.when(start_ref[c, i] == 1)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(abit_ref[c, i] == 1)
    def _mac():
        acc_ref[...] += jnp.dot(
            x_ref[0, 0, 0], w_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(last_ref[c, i] == 1)
    def _flush():
        o_ref[0] = ACTIVATIONS[activation](acc_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "ow",
        "block",
        "grid_tiles",
        "activation",
        "out_dtype",
        "interpret",
    ),
)
def phantom_conv_direct_multicore_call(
    xph: jnp.ndarray,  # [PH, B, Hq, Wq, Cp] (shared by all cores)
    w_packed: jnp.ndarray,  # [nnzb, bk, bn] per-core payloads concatenated
    ph: jnp.ndarray,  # int32 [cores, Qpad] per-step source offsets
    nb: jnp.ndarray,
    r0: jnp.ndarray,
    c0: jnp.ndarray,
    ch0: jnp.ndarray,
    mi: jnp.ndarray,  # int32 [cores, Qpad] per-core queues, makespan-padded
    ni: jnp.ndarray,  # (ni is the core-local output column)
    wq: jnp.ndarray,
    start: jnp.ndarray,
    last: jnp.ndarray,
    abit: jnp.ndarray,
    counts=None,  # traced [cores] per-core executed-step counts (§10)
    *,
    ow: int,
    block: tuple[int, int],  # (bk, bn)
    grid_tiles: tuple[int, int, int],  # (Mt = B·oh, Kt, ntc) — ntc PER-CORE
    activation: str = "none",
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """Direct-conv counterpart of
    :func:`repro.kernels.phantom_spmm.phantom_spmm_multicore_call`: the
    leading grid axis walks the virtual cores, each consuming its own
    makespan-padded coordinate-carrying queue and writing its own
    ``[B·oh·ow, ntc·bn]`` output slab (DESIGN.md §9).  ``counts`` bounds
    the step axis at ``max(counts)`` after lookahead compaction (§10)."""
    bk, bn = block
    mt, _kt, ntc = grid_tiles
    cores, q = mi.shape
    if counts is not None:
        q = jnp.max(counts)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=11,
        grid=(cores, q),
        in_specs=[
            pl.BlockSpec(
                (1, 1, 1, ow, bk),
                lambda c, i, ph, nb, r0, c0, ch0, mi, ni, wq, st, la, ab: (
                    ph[c, i],
                    nb[c, i],
                    r0[c, i],
                    c0[c, i],
                    ch0[c, i],
                ),
                indexing_mode=pl.Unblocked(),
            ),
            pl.BlockSpec(
                (1, bk, bn),
                lambda c, i, ph, nb, r0, c0, ch0, mi, ni, wq, st, la, ab: (
                    wq[c, i],
                    0,
                    0,
                ),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, ow, bn),
            lambda c, i, ph, nb, r0, c0, ch0, mi, ni, wq, st, la, ab: (
                c,
                mi[c, i],
                ni[c, i],
            ),
        ),
        scratch_shapes=[pltpu.VMEM((ow, bn), jnp.float32)],
    )
    kernel = functools.partial(
        phantom_conv_direct_multicore_kernel, activation=activation
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cores, mt * ow, ntc * bn), out_dtype),
        interpret=interpret,
    )(ph, nb, r0, c0, ch0, mi, ni, wq, start, last, abit, xph, w_packed)
