"""Pure-jnp oracles for every Pallas kernel (bit-exact reference semantics).

The kernels compute ``y = act_fn(x @ w)`` where
  * weight tiles with a zero block-mask bit are exactly zero (static,
    block-pruned weights), and
  * activation tiles with a zero block-mask bit are treated as exactly zero
    (dynamic tile mask from the producing layer's epilogue, §3.8).

These oracles materialise that semantics densely; tests assert_allclose the
kernels (interpret mode on CPU) against them over shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "expand_block_mask",
    "ref_phantom_spmm",
    "ref_phantom_linear_act",
    "ref_phantom_conv",
    "ref_activation_block_mask",
    "ACTIVATIONS",
]

ACTIVATIONS = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
}


def expand_block_mask(bmask: jnp.ndarray, block: tuple[int, int], shape) -> jnp.ndarray:
    """Tile mask [Mt, Nt] → element mask [M, N] (crop to ``shape``)."""
    bm, bn = block
    m, n = shape
    e = jnp.repeat(jnp.repeat(bmask, bm, axis=0), bn, axis=1)
    return e[:m, :n]


def ref_phantom_spmm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    w_bmask: jnp.ndarray,  # bool [Kt, Nt]
    act_bmask: jnp.ndarray,  # bool [Mt, Kt]
    block: tuple[int, int, int],  # (bm, bk, bn)
    out_dtype=None,
) -> jnp.ndarray:
    """Oracle for the two-sided block-sparse matmul."""
    bm, bk, bn = block
    m, k = x.shape
    _, n = w.shape
    xm = expand_block_mask(act_bmask.astype(x.dtype), (bm, bk), (m, k))
    wm = expand_block_mask(w_bmask.astype(w.dtype), (bk, bn), (k, n))
    acc = jnp.dot(
        (x * xm).astype(jnp.float32),
        (w * wm).astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return acc.astype(out_dtype or x.dtype)


def ref_phantom_linear_act(
    x, w, w_bmask, act_bmask, block, activation: str = "none", threshold: float = 0.0,
    out_dtype=None,
):
    """Oracle for the fused linear + activation + output-encoding kernel.

    Returns ``(y, y_block_mask)`` where the mask is the §3.8 output encoding
    of the *activated* output at (bm, bn) granularity.
    """
    y32 = ref_phantom_spmm(x, w, w_bmask, act_bmask, block, out_dtype=jnp.float32)
    y32 = ACTIVATIONS[activation](y32)
    y = y32.astype(out_dtype or x.dtype)
    ymask = ref_activation_block_mask(y, (block[0], block[2]), threshold)
    return y, ymask


def ref_phantom_conv(
    x: jnp.ndarray,  # [B, H, W, Cin]
    w: jnp.ndarray,  # [kh, kw, Cin/groups, Cout] (HWIO)
    stride=(1, 1),
    padding: str = "SAME",
    groups: int = 1,
) -> jnp.ndarray:
    """Oracle for the im2col conv lowering: the dense XLA convolution on the
    already-pruned weight (kept tiles are exact, τ=0 activation gating is
    semantics-free, so the dense op IS the reference)."""
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NHWC", "HWIO", "NHWC"))
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=tuple(stride),
        padding=padding.upper(),
        dimension_numbers=dn,
        feature_group_count=groups,
    )


def ref_activation_block_mask(x, block: tuple[int, int], threshold: float = 0.0):
    """Tile kept ⇔ any(|x| > τ) over the tile (τ=0 ⇒ exact-zero skipping)."""
    bm, bn = block
    m, n = x.shape
    mt, nt = -(-m // bm), -(-n // bn)
    xp = jnp.zeros((mt * bm, nt * bn), x.dtype).at[:m, :n].set(x)
    return (
        (jnp.abs(xp) > threshold)
        .reshape(mt, bm, nt, bn)
        .any(axis=(1, 3))
    )
