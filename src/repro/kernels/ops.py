"""Public jit'd wrappers for the Phantom TPU kernels.

``prepare_weight`` runs once at weight-load time (host side): block-masks the
pruned weight, packs the kept tiles (§3.1 storage), builds the compacted work
queue (TDS analogue) and appends the §3.8 empty-output steps so every output
tile is written exactly once.  ``phantom_matmul`` /
``phantom_linear_act`` are the runtime entry points; the dynamic activation
tile bits are gathered per queue step and shipped via scalar prefetch.

Interpret mode defaults to on when running on CPU (this container) — the
kernel body executes in Python with identical semantics; on TPU it compiles
to Mosaic.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocksparse as bs
from . import phantom_ffn, phantom_spmm
from .ref import ref_activation_block_mask

__all__ = [
    "PhantomWeight",
    "prepare_weight",
    "append_empty_steps",
    "activation_tile_bits",
    "element_mask_tile_bits",
    "phantom_matmul",
    "phantom_linear_act",
    "default_interpret",
]


def default_interpret() -> bool:
    return jax.default_backend() == "cpu"


@dataclasses.dataclass
class PhantomWeight:
    """Weight-load-time artifact: packed payload + compacted queue."""

    packed: jnp.ndarray  # [nnzb, bk, bn]
    mi: np.ndarray
    ni: np.ndarray
    ki: np.ndarray
    wq: np.ndarray
    start: np.ndarray
    last: np.ndarray
    valid: np.ndarray  # 0 on empty-output steps (abit forced 0)
    flat_ak: np.ndarray  # mi*Kt + ki per step (activation-bit gather index)
    block: tuple[int, int, int]
    grid_tiles: tuple[int, int, int]
    shape: tuple[int, int]  # original (K, N)
    w_bmask: np.ndarray  # [Kt, Nt] (kept for tests / stats)

    @property
    def steps(self) -> int:
        return int(self.mi.shape[0])

    def density(self) -> float:
        return float(self.w_bmask.mean())


def append_empty_steps(queue: bs.WorkQueue):
    """Append the §3.8 empty-output steps to a compacted queue.

    Output tiles with no effectual k-work still must be written (as exact
    zeros), so each gets one step with ``start = last = 1`` and ``valid = 0``
    — the kernel zeroes the accumulator, skips the MXU op (the activation
    bit is forced 0 through ``valid``), and flushes.  Returns
    ``(mi, ni, ki, wq, start, last, valid)`` covering every output tile
    exactly once.  Shared by the matmul and direct-conv preparations.
    """
    e = queue.empty_out
    ones = np.ones(len(e), dtype=np.int32)
    zeros = np.zeros(len(e), dtype=np.int32)
    mi = np.concatenate([queue.mi, e[:, 0].astype(np.int32)])
    ni = np.concatenate([queue.ni, e[:, 1].astype(np.int32)])
    ki = np.concatenate([queue.ki, zeros])
    wq = np.concatenate([queue.wq, zeros])
    start = np.concatenate([queue.start, ones])
    last = np.concatenate([queue.last, ones])
    valid = np.concatenate([np.ones(queue.steps, dtype=np.int32), zeros])
    return mi, ni, ki, wq, start, last, valid


def prepare_weight(
    w: np.ndarray,
    *,
    m: int,
    block: tuple[int, int, int] = (256, 256, 256),
    interleave: bool = True,
    dtype=jnp.float32,
    config=None,
) -> PhantomWeight:
    """Pack a (pruned) dense weight [K, N] for activations with ``m`` rows.

    ``config`` (a :class:`repro.core.phantom_linear.PhantomConfig`) is the
    preferred knob surface and overrides ``block``/``interleave``/``dtype``
    — the program API (DESIGN.md §8) passes it through unchanged.
    """
    if config is not None:
        block, interleave, dtype = config.block, config.interleave, config.jnp_dtype()
    w = np.asarray(w)
    k, n = w.shape
    bm, bk, bn = block
    mt = math.ceil(m / bm)
    bmask = bs.block_mask_from_dense(w, (bk, bn)).mask
    queue = bs.build_work_queue(bmask, mt, interleave=interleave)
    packed = jnp.asarray(bs.pack_blocks(w, bmask, (bk, bn)), dtype=dtype)
    kt = bmask.shape[0]
    mi, ni, ki, wq, start, last, valid = append_empty_steps(queue)
    return PhantomWeight(
        packed=packed,
        mi=mi,
        ni=ni,
        ki=ki,
        wq=wq,
        start=start,
        last=last,
        valid=valid,
        flat_ak=mi * kt + ki,
        block=block,
        grid_tiles=(mt, kt, bmask.shape[1]),
        shape=(k, n),
        w_bmask=bmask,
    )


def activation_tile_bits(x2d: jnp.ndarray, block: tuple[int, int], threshold: float = 0.0):
    """Dynamic activation tile mask (int32 [Mt, Kt]) for a 2-D activation."""
    return ref_activation_block_mask(x2d, block, threshold).astype(jnp.int32)


def _pad2(x, bm, bk):
    m, k = x.shape
    pm, pk = (-m) % bm, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    return x


def element_mask_tile_bits(
    mask2d: jnp.ndarray, block: tuple[int, int], threshold: float = 0.0
):
    """§3.8 inter-layer flow: a producing layer's *element* mask [M, K]
    (bool/0-1, unpadded) → the consuming layer's tile bits int32 [Mt, Kt].

    Pass the result as ``act_bits`` to :func:`phantom_matmul` /
    :func:`phantom_linear_act` instead of letting them re-inspect values.
    """
    m = jnp.asarray(mask2d, jnp.float32)
    return activation_tile_bits(_pad2(m, *block), block, threshold)


def _run(call, x, pw: PhantomWeight, act_bits, interpret, **kw):
    bm, bk, bn = pw.block
    xp = _pad2(x, bm, bk)
    abit = act_bits.reshape(-1)[jnp.asarray(pw.flat_ak)] * jnp.asarray(pw.valid)
    return call(
        xp,
        pw.packed,
        jnp.asarray(pw.mi),
        jnp.asarray(pw.ni),
        jnp.asarray(pw.ki),
        jnp.asarray(pw.wq),
        jnp.asarray(pw.start),
        jnp.asarray(pw.last),
        abit.astype(jnp.int32),
        block=pw.block,
        grid_tiles=pw.grid_tiles,
        interpret=interpret,
        **kw,
    )


def phantom_matmul(
    x: jnp.ndarray,
    pw: PhantomWeight,
    *,
    act_bits: jnp.ndarray | None = None,
    act_threshold: float = 0.0,
    out_dtype=None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """``y = x @ w`` through the two-sided block-sparse kernel.

    ``x``: [..., K]; leading dims are flattened to M (must satisfy
    ``ceil(M/bm) == grid_tiles[0]`` of ``pw``).  ``act_bits`` (int32
    [Mt, Kt]) overrides the tile bits computed from ``x`` — the §3.8 flow
    where the producing layer already emitted the mask (conv patch bits use
    this, :func:`repro.kernels.phantom_conv.conv_patch_tile_bits`).
    """
    interpret = default_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    k, n = pw.shape
    x2 = x.reshape(-1, k)
    bm, bk, _ = pw.block
    bits = (
        activation_tile_bits(_pad2(x2, bm, bk), (bm, bk), act_threshold)
        if act_bits is None
        else act_bits.astype(jnp.int32)
    )
    y = _run(
        phantom_spmm.phantom_spmm_call,
        x2,
        pw,
        bits,
        interpret,
        out_dtype=out_dtype or x.dtype,
    )
    return y[: x2.shape[0], :n].reshape(*lead, n)


def phantom_linear_act(
    x: jnp.ndarray,
    pw: PhantomWeight,
    *,
    activation: str = "none",
    act_bits: jnp.ndarray | None = None,
    act_threshold: float = 0.0,
    mask_threshold: float = 0.0,
    out_dtype=None,
    interpret: bool | None = None,
):
    """Fused ``y = act(x @ w)`` + §3.8 output-encoding tile mask.

    Returns ``(y, y_tile_mask)`` — feed the mask to the next layer's
    ``phantom_matmul`` instead of recomputing it from ``y``.  ``act_bits``
    as in :func:`phantom_matmul`.
    """
    interpret = default_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    k, n = pw.shape
    x2 = x.reshape(-1, k)
    bm, bk, _ = pw.block
    bits = (
        activation_tile_bits(_pad2(x2, bm, bk), (bm, bk), act_threshold)
        if act_bits is None
        else act_bits.astype(jnp.int32)
    )
    y, ymask = _run(
        phantom_ffn.phantom_linear_act_call,
        x2,
        pw,
        bits,
        interpret,
        activation=activation,
        threshold=mask_threshold,
        out_dtype=out_dtype or x.dtype,
    )
    return y[: x2.shape[0], :n].reshape(*lead, n), ymask
