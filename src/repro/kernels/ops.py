"""Public jit'd wrappers for the Phantom TPU kernels.

``prepare_weight`` runs once at weight-load time (host side): block-masks the
pruned weight, packs the kept tiles (§3.1 storage), builds the compacted work
queue (TDS analogue) and appends the §3.8 empty-output steps so every output
tile is written exactly once.  ``phantom_matmul`` /
``phantom_linear_act`` are the runtime entry points; the dynamic activation
tile bits are gathered per queue step and shipped via scalar prefetch.

Interpret mode defaults to on when running on CPU (this container) — the
kernel body executes in Python with identical semantics; on TPU it compiles
to Mosaic.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import types

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocksparse as bs
from . import compaction, phantom_ffn, phantom_spmm
from .compaction import lookahead_stats
from .ref import ref_activation_block_mask

__all__ = [
    "MulticoreSteps",
    "PhantomWeight",
    "prepare_weight",
    "append_empty_steps",
    "build_multicore_queues",
    "pack_multicore_blocks",
    "stitch_core_outputs",
    "cost_artifact",
    "activation_tile_bits",
    "element_mask_tile_bits",
    "phantom_matmul",
    "phantom_linear_act",
    "lookahead_stats",
    "default_interpret",
]


def default_interpret() -> bool:
    return jax.default_backend() == "cpu"


class MulticoreSteps:
    """Shared ``steps`` accounting for single- and multi-core artifacts
    (:class:`PhantomWeight`, :class:`repro.kernels.phantom_conv.DirectConvPlan`).

    For ``cores > 1`` the padding-column zero-writes (slots beyond Nt) are
    excluded so ``steps`` stays comparable across core counts: MAC steps +
    genuine §3.8 empty-output steps, exactly the ``cores == 1`` count.
    """

    @property
    def steps(self) -> int:
        if self.cores > 1:
            pad = self.grid_tiles[0] * (self.cores * self.local_nt - self.grid_tiles[2])
            return int(self.core_steps.sum()) - pad
        return int(self.mi.shape[0])


@dataclasses.dataclass
class PhantomWeight(MulticoreSteps):
    """Weight-load-time artifact: packed payload + compacted queue.

    Single-core (``cores == 1``): the queue arrays are 1-D [Q] and ``ni``
    is the global output tile-column.  Multi-core (DESIGN.md §9): they are
    int32 [cores, Qpad] — one compacted queue per virtual core, padded to
    the makespan — ``ni`` is the *core-local* column, ``col_perm`` (length
    ``cores·local_nt``, −1 on padding slots) maps core-major local columns
    back to global ones, and ``wq`` indexes the per-core payloads
    concatenated along axis 0 of ``packed``.
    """

    packed: jnp.ndarray  # [nnzb, bk, bn]
    mi: np.ndarray
    ni: np.ndarray
    ki: np.ndarray
    wq: np.ndarray
    start: np.ndarray
    last: np.ndarray
    valid: np.ndarray  # 0 on empty-output steps (abit forced 0)
    flat_ak: np.ndarray  # mi*Kt + ki per step (activation-bit gather index)
    block: tuple[int, int, int]
    grid_tiles: tuple[int, int, int]
    shape: tuple[int, int]  # original (K, N)
    w_bmask: np.ndarray  # [Kt, Nt] (kept for tests / stats)
    cores: int = 1
    col_perm: np.ndarray | None = None  # int64 [cores·local_nt], −1 = pad slot
    col_inv: np.ndarray | None = None  # int64 [Nt] inverse (stitch gather)
    local_nt: int = 0  # per-core padded column-tile width (ceil(Nt / cores))
    core_steps: np.ndarray | None = None  # int64 [cores] real steps per core
    core_cost: np.ndarray | None = None  # int64 [cores] Σ column nnz blocks
    # Runtime lookahead compaction (DESIGN.md §10): L_f window (0 = gated
    # path) + the static segment metadata `compact_queue` consumes.
    lookahead: int = 0
    cmeta: dict | None = None  # {"seg_base", "seg_end", "pad"} per-entry

    def density(self) -> float:
        return float(self.w_bmask.mean())


def append_empty_steps(queue: bs.WorkQueue):
    """Append the §3.8 empty-output steps to a compacted queue.

    Output tiles with no effectual k-work still must be written (as exact
    zeros), so each gets one step with ``start = last = 1`` and ``valid = 0``
    — the kernel zeroes the accumulator, skips the MXU op (the activation
    bit is forced 0 through ``valid``), and flushes.  Returns
    ``(mi, ni, ki, wq, start, last, valid)`` covering every output tile
    exactly once.  Shared by the matmul and direct-conv preparations.
    """
    e = queue.empty_out
    ones = np.ones(len(e), dtype=np.int32)
    zeros = np.zeros(len(e), dtype=np.int32)
    mi = np.concatenate([queue.mi, e[:, 0].astype(np.int32)])
    ni = np.concatenate([queue.ni, e[:, 1].astype(np.int32)])
    ki = np.concatenate([queue.ki, zeros])
    wq = np.concatenate([queue.wq, zeros])
    start = np.concatenate([queue.start, ones])
    last = np.concatenate([queue.last, ones])
    valid = np.concatenate([np.ones(queue.steps, dtype=np.int32), zeros])
    return mi, ni, ki, wq, start, last, valid


def build_multicore_queues(
    bmask: np.ndarray,
    m_tiles: int,
    cores: int,
    balance: str,
    *,
    interleave: bool = True,
    conv: dict | None = None,
):
    """Partition tile-columns onto cores and build per-core padded queues.

    The two-level balancing of the paper, at weight-load time (§4.2, §4.3.1;
    DESIGN.md §9): columns go to cores densest-first LPT
    (:func:`repro.core.blocksparse.partition_columns` — naive round-robin
    when ``balance`` disables inter-core balancing), each core's sub-mask is
    compacted into its own queue exactly like the single-core TDS, and all
    queues are padded to the makespan so one grid executes them in lock-step
    slots.  Three step classes per core, distinguished by flags:

    * real steps — the compacted effectual work (``valid = 1``);
    * zero-write steps — §3.8 empty output tiles *plus* the core's padding
      column slots beyond its bucket (``start = last = 1``, ``valid = 0``):
      every local output tile is written exactly once;
    * inert makespan-padding steps (``start = last = valid = 0``) — the tail
      that brings a short queue up to the longest core's length.  Their
      index fields repeat the core's *last* real step (flags zeroed), so the
      revisited output block is the one just flushed: on compiled TPU the
      end-of-window writeback then rewrites that block with the identical
      VMEM contents instead of smearing a stale buffer over tile (0, 0).

    ``conv={"kw": ..., "ct": ...}`` builds coordinate-carrying conv queues
    (adds ``ky``/``kx``/``ci`` rows).  Returns ``(buckets, q2d, meta)``:
    per-core column lists, ``{field: int32 [cores, Qpad]}``, and
    ``{col_perm, local_nt, core_steps, core_cost}``.
    """
    bmask = np.asarray(bmask, dtype=bool)
    kt, nt = bmask.shape
    buckets = bs.partition_columns(bmask, cores, balance)
    ntc = max(1, math.ceil(nt / cores))
    dens = bmask.sum(axis=0)
    per_core: list[dict] = []
    for bucket in buckets:
        sub = bmask[:, bucket] if len(bucket) else np.zeros((kt, 0), dtype=bool)
        if conv is None:
            q = bs.build_work_queue(sub, m_tiles, interleave=interleave)
        else:
            q = bs.build_conv_work_queue(
                sub, m_tiles, kw=conv["kw"], ct=conv["ct"], interleave=interleave
            )
        mi, ni, ki, wq, start, last, valid = append_empty_steps(q)
        fields = dict(mi=mi, ni=ni, ki=ki, wq=wq, start=start, last=last, valid=valid)
        if conv is not None:
            pad0 = np.zeros(len(mi) - q.steps, dtype=np.int32)
            for name in ("ky", "kx", "ci"):
                fields[name] = np.concatenate([getattr(q, name), pad0])
        extra = ntc - len(bucket)
        if extra:  # zero-write the padding column slots (dropped at stitch)
            emi = np.repeat(np.arange(m_tiles, dtype=np.int32), extra)
            eni = np.tile(np.arange(len(bucket), ntc, dtype=np.int32), m_tiles)
            ez = np.zeros(extra * m_tiles, dtype=np.int32)
            eo = np.ones(extra * m_tiles, dtype=np.int32)
            pads = dict(mi=emi, ni=eni, start=eo, last=eo)
            for name, arr in fields.items():
                fields[name] = np.concatenate([arr, pads.get(name, ez)])
        per_core.append(fields)
    core_steps = np.asarray([len(f["mi"]) for f in per_core], dtype=np.int64)
    qmax = int(core_steps.max())
    flags = ("start", "last", "valid")  # tail: no zero / no MAC / no flush
    q2d = {}
    for name in per_core[0]:
        rows = []
        for f in per_core:
            arr = f[name]
            # Tail fill rule (load-bearing, see the tail-revisit test): flag
            # fields pad with 0 so tail steps stay inert; index fields repeat
            # the core's last step so revisits target the just-flushed block.
            fill = 0 if name in flags else arr[-1]
            rows.append(
                np.concatenate([arr, np.full(qmax - len(arr), fill, np.int32)])
            )
        q2d[name] = np.stack(rows)
    col_perm = np.full(cores * ntc, -1, dtype=np.int64)
    for c, bucket in enumerate(buckets):
        col_perm[c * ntc : c * ntc + len(bucket)] = bucket
    live = col_perm >= 0
    col_inv = np.zeros(nt, dtype=np.int64)
    col_inv[col_perm[live]] = np.flatnonzero(live)
    meta = dict(
        col_perm=col_perm,
        col_inv=col_inv,
        local_nt=ntc,
        core_steps=core_steps,
        core_cost=np.asarray([int(dens[b].sum()) for b in buckets], dtype=np.int64),
    )
    return buckets, q2d, meta


def pack_multicore_blocks(
    w_padded: np.ndarray,  # [Kt·bk, Nt·bn] element weight, tile-padded
    bmask: np.ndarray,  # [Kt, Nt]
    buckets: list[np.ndarray],
    block: tuple[int, int],
) -> tuple[np.ndarray, np.ndarray]:
    """Pack each core's kept tiles (its bucket's columns, in local
    (ni-major, ki) order — matching its queue's ``wq`` ids) and concatenate
    the payloads.  Returns ``(packed [nnzb, bk, bn], offsets [cores])`` —
    add ``offsets[c]`` to core ``c``'s local ``wq``.  A core with no kept
    tiles contributes the 1-block zero dummy ``pack_blocks`` emits (its
    queue never MACs, so the dummy is only ever a dead prefetch)."""
    bk, bn = block
    kt = np.asarray(bmask).shape[0]
    packs, offsets, off = [], [], 0
    for bucket in buckets:
        if len(bucket):
            sub_w = np.concatenate(
                [w_padded[:, c * bn : (c + 1) * bn] for c in bucket], axis=1
            )
            sub_mask = np.asarray(bmask)[:, bucket]
        else:
            sub_w = np.zeros((kt * bk, 0), dtype=w_padded.dtype)
            sub_mask = np.zeros((kt, 0), dtype=bool)
        p = bs.pack_blocks(sub_w, sub_mask, (bk, bn))
        packs.append(p)
        offsets.append(off)
        off += p.shape[0]
    return np.concatenate(packs, axis=0), np.asarray(offsets, dtype=np.int32)


@functools.partial(jax.jit, static_argnames=("bn",))
def stitch_core_outputs(
    y3: jnp.ndarray,  # [cores, Mpad, ntc·bn] per-core output slabs
    col_inv: jnp.ndarray,  # int [Nt]: global column → core-major position
    *,
    bn: int,
) -> jnp.ndarray:
    """Invert the balancing permutation: core-major local column slabs →
    the global ``[Mpad, Nt·bn]`` output (padding slots dropped).  ``col_inv``
    is precomputed at weight-load time (:func:`build_multicore_queues`);
    jitted so the transpose/gather compiles once per shape instead of
    dispatching eagerly on the per-layer serving path."""
    cores, mpad, _ = y3.shape
    ntc = y3.shape[2] // bn
    yp = (
        y3.reshape(cores, mpad, ntc, bn)
        .transpose(1, 0, 2, 3)
        .reshape(mpad, cores * ntc, bn)
    )
    nt = col_inv.shape[0]
    return yp[:, col_inv].reshape(mpad, nt * bn)


def _prepare_weight_multicore(
    w: np.ndarray,
    bmask: np.ndarray,
    *,
    m_tiles: int,
    cores: int,
    balance: str,
    block: tuple[int, int, int],
    interleave: bool,
    dtype,
    lookahead: int = 0,
) -> PhantomWeight:
    bm, bk, bn = block
    kt, nt = bmask.shape
    buckets, q2d, meta = build_multicore_queues(
        bmask, m_tiles, cores, balance, interleave=interleave
    )
    wp = np.zeros((kt * bk, nt * bn), dtype=np.asarray(w).dtype)
    wp[: w.shape[0], : w.shape[1]] = w
    packed, offsets = pack_multicore_blocks(wp, bmask, buckets, (bk, bn))
    return PhantomWeight(
        packed=jnp.asarray(packed, dtype=dtype),
        mi=q2d["mi"],
        ni=q2d["ni"],
        ki=q2d["ki"],
        wq=q2d["wq"] + offsets[:, None],
        start=q2d["start"],
        last=q2d["last"],
        valid=q2d["valid"],
        flat_ak=q2d["mi"] * kt + q2d["ki"],
        block=block,
        grid_tiles=(m_tiles, kt, nt),
        shape=w.shape,
        w_bmask=bmask,
        cores=cores,
        lookahead=lookahead,
        cmeta=(
            compaction.compaction_meta(q2d["start"], meta["core_steps"])
            if lookahead
            else None
        ),
        **meta,
    )


def prepare_weight(
    w: np.ndarray,
    *,
    m: int,
    block: tuple[int, int, int] = (256, 256, 256),
    interleave: bool = True,
    dtype=jnp.float32,
    cores: int = 1,
    balance: str = "full",
    lookahead: int = 0,
    config=None,
) -> PhantomWeight:
    """Pack a (pruned) dense weight [K, N] for activations with ``m`` rows.

    ``cores > 1`` partitions the output tile-columns across that many
    virtual Phantom cores (densest-first LPT when ``balance`` enables
    inter-core balancing, naive round-robin otherwise — DESIGN.md §9) and
    the runtime executes all cores in one ``pallas_call`` with a leading
    cores grid axis.  ``balance`` also gates the intra-core-style queue
    rotation: ``interleave`` is honored only for ``{"intra", "full"}``.

    ``lookahead`` ≥ 1 enables runtime queue compaction against the
    activation bits (the §3.4 L_f window, DESIGN.md §10): activation-dead
    steps stop costing grid iterations.  0 keeps the gated path.

    ``config`` (a :class:`repro.core.phantom_linear.PhantomConfig`) is the
    preferred knob surface and overrides ``block``/``interleave``/``dtype``
    /``cores``/``balance``/``lookahead`` — the program API (DESIGN.md §8)
    passes it through unchanged.
    """
    if config is not None:
        block, interleave, dtype = config.block, config.interleave, config.jnp_dtype()
        cores, balance = config.cores, config.balance
        lookahead = config.lookahead
    lookahead = int(lookahead or 0)
    if lookahead < 0:
        raise ValueError(f"lookahead must be >= 0, got {lookahead}")
    interleave = interleave and bs.balance_interleaves(balance)
    w = np.asarray(w)
    k, n = w.shape
    bm, bk, bn = block
    mt = math.ceil(m / bm)
    bmask = bs.block_mask_from_dense(w, (bk, bn)).mask
    if cores > 1:
        return _prepare_weight_multicore(
            w,
            bmask,
            m_tiles=mt,
            cores=cores,
            balance=balance,
            block=block,
            interleave=interleave,
            dtype=dtype,
            lookahead=lookahead,
        )
    queue = bs.build_work_queue(bmask, mt, interleave=interleave)
    packed = jnp.asarray(bs.pack_blocks(w, bmask, (bk, bn)), dtype=dtype)
    kt = bmask.shape[0]
    mi, ni, ki, wq, start, last, valid = append_empty_steps(queue)
    return PhantomWeight(
        packed=packed,
        mi=mi,
        ni=ni,
        ki=ki,
        wq=wq,
        start=start,
        last=last,
        valid=valid,
        flat_ak=mi * kt + ki,
        block=block,
        grid_tiles=(mt, kt, bmask.shape[1]),
        shape=(k, n),
        w_bmask=bmask,
        lookahead=lookahead,
        cmeta=compaction.compaction_meta(start) if lookahead else None,
    )


def cost_artifact(
    bmask: np.ndarray,
    m_tiles: int,
    *,
    cores: int = 1,
    balance: str = "full",
    interleave: bool = True,
    conv: dict | None = None,
):
    """Queue-only artifact for the autotuner's analytic cost model
    (:mod:`repro.tune.cost`, DESIGN.md §12).

    Runs the *same* queue construction as :func:`prepare_weight` /
    ``phantom_conv._prepare_direct`` — partition, compaction, §3.8
    zero-writes, makespan padding — but never packs a weight payload, so a
    candidate configuration can be costed (via :func:`lookahead_stats` on
    the returned artifact) without touching the kernel path.  Because the
    queue code is shared, the predicted ``queue_steps`` / ``executed_steps``
    / ``makespan`` equal the real plan's exactly; the tuner's "never worse
    than the default on the deterministic metrics" guarantee rests on that
    equality.

    ``conv={"kw": ..., "ct": ...}`` costs the coordinate-carrying direct-conv
    queue (same switch as :func:`build_multicore_queues`).
    """
    bmask = np.asarray(bmask, dtype=bool)
    kt, nt = bmask.shape
    interleave = interleave and bs.balance_interleaves(balance)
    if cores > 1:
        _, q2d, meta = build_multicore_queues(
            bmask, m_tiles, cores, balance, interleave=interleave, conv=conv
        )
        return types.SimpleNamespace(
            flat_ak=q2d["mi"] * kt + q2d["ki"],
            valid=q2d["valid"],
            start=q2d["start"],
            cores=cores,
            core_steps=meta["core_steps"],
            core_cost=meta["core_cost"],
            grid_tiles=(m_tiles, kt, nt),
            lookahead=0,
        )
    if conv is None:
        q = bs.build_work_queue(bmask, m_tiles, interleave=interleave)
    else:
        q = bs.build_conv_work_queue(
            bmask, m_tiles, kw=conv["kw"], ct=conv["ct"], interleave=interleave
        )
    mi, ni, ki, wq, start, last, valid = append_empty_steps(q)
    return types.SimpleNamespace(
        flat_ak=mi * kt + ki,
        valid=valid,
        start=start,
        cores=1,
        core_steps=np.asarray([len(mi)], dtype=np.int64),
        core_cost=np.asarray([int(bmask.sum())], dtype=np.int64),
        grid_tiles=(m_tiles, kt, nt),
        lookahead=0,
    )


def activation_tile_bits(x2d: jnp.ndarray, block: tuple[int, int], threshold: float = 0.0):
    """Dynamic activation tile mask (int32 [Mt, Kt]) for a 2-D activation."""
    return ref_activation_block_mask(x2d, block, threshold).astype(jnp.int32)


def _pad2(x, bm, bk):
    m, k = x.shape
    pm, pk = (-m) % bm, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    return x


def element_mask_tile_bits(
    mask2d: jnp.ndarray, block: tuple[int, int], threshold: float = 0.0
):
    """§3.8 inter-layer flow: a producing layer's *element* mask [M, K]
    (bool/0-1, unpadded) → the consuming layer's tile bits int32 [Mt, Kt].

    Pass the result as ``act_bits`` to :func:`phantom_matmul` /
    :func:`phantom_linear_act` instead of letting them re-inspect values.
    """
    m = jnp.asarray(mask2d, jnp.float32)
    return activation_tile_bits(_pad2(m, *block), block, threshold)


def _check_rows(m: int, pw: PhantomWeight):
    """Fail fast (and helpfully) when the activation's row count does not
    match the M-tile count baked into the prepared queue — without this the
    mismatch surfaces as a cryptic BlockSpec shape error deep in the kernel."""
    bm = pw.block[0]
    mt = pw.grid_tiles[0]
    need = math.ceil(m / bm)
    if need != mt:
        raise ValueError(
            f"activation has M={m} rows -> ceil({m}/{bm}) = {need} m-tiles, "
            f"but this PhantomWeight was prepared for grid_tiles[0]={mt} "
            f"(prepare_weight(..., m=...)). Phantom plans bake the M-tile "
            f"count into the work queue: re-prepare for this batch, or use "
            f"the program API's program.at_batch(batch) to fetch the plan "
            f"lowered for it."
        )


def _compact(fields: dict, pw, abit):
    """Call-time lookahead compaction (DESIGN.md §10): squeeze activation-
    dead steps out of the queue; returns the compacted fields plus the
    executed-step count that bounds the grid."""
    start, last = jnp.asarray(pw.start), jnp.asarray(pw.last)
    cm = pw.cmeta
    fields, start, last, abit, count = compaction.compact_queue(
        {k: jnp.asarray(v) for k, v in fields.items()},
        start,
        last,
        abit,
        jnp.asarray(cm["seg_base"]),
        jnp.asarray(cm["seg_end"]),
        jnp.asarray(cm["pad"]),
        lookahead=int(pw.lookahead),
    )
    return fields, start, last, abit, count


def _run(call, x, pw: PhantomWeight, act_bits, interpret, **kw):
    bm, bk, bn = pw.block
    xp = _pad2(x, bm, bk)
    abit = (
        act_bits.reshape(-1)[jnp.asarray(pw.flat_ak)] * jnp.asarray(pw.valid)
    ).astype(jnp.int32)
    fields = dict(mi=pw.mi, ni=pw.ni, ki=pw.ki, wq=pw.wq)
    start, last, num_steps = pw.start, pw.last, None
    if pw.lookahead:
        fields, start, last, abit, num_steps = _compact(fields, pw, abit)
    return call(
        xp,
        pw.packed,
        jnp.asarray(fields["mi"]),
        jnp.asarray(fields["ni"]),
        jnp.asarray(fields["ki"]),
        jnp.asarray(fields["wq"]),
        jnp.asarray(start),
        jnp.asarray(last),
        abit,
        num_steps,
        block=pw.block,
        grid_tiles=pw.grid_tiles,
        interpret=interpret,
        **kw,
    )


def _run_multicore(
    x2: jnp.ndarray,
    pw: PhantomWeight,
    act_bits: jnp.ndarray,
    interpret: bool,
    out_dtype,
    activation: str = "none",
) -> jnp.ndarray:
    """Execute a multi-core artifact: per-core queues through the leading
    cores grid axis (mapped onto a device mesh when one is available —
    :func:`repro.parallel.sharding.cores_mesh`), then stitch the per-core
    output slabs back through the inverse column permutation.  Returns the
    padded ``[Mt·bm, Nt·bn]`` output — numerics are bit-identical to the
    single-core path (per-tile accumulation order is unchanged by the
    partition)."""
    from repro.parallel import sharding  # local: keep kernels importable alone

    bm, bk, bn = pw.block
    xp = _pad2(x2, bm, bk)
    abit = (
        act_bits.reshape(-1)[jnp.asarray(pw.flat_ak)] * jnp.asarray(pw.valid)
    ).astype(jnp.int32)
    mt, kt, _nt = pw.grid_tiles
    call = functools.partial(
        phantom_spmm.phantom_spmm_multicore_call,
        block=pw.block,
        grid_tiles=(mt, kt, pw.local_nt),
        activation=activation,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    fields = dict(mi=pw.mi, ni=pw.ni, ki=pw.ki, wq=pw.wq)
    start, last, counts = pw.start, pw.last, None
    if pw.lookahead:
        # Per-core compaction: each core's queue shrinks to its own executed
        # count; the grid's second dimension is the max (§4.6 lock-step), so
        # `counts` rides along as one more per-core array (split by the
        # shard_map when the cores axis maps onto a device mesh).
        fields, start, last, abit, counts = _compact(fields, pw, abit)
    queues = tuple(
        jnp.asarray(a)
        for a in (
            fields["mi"], fields["ni"], fields["ki"], fields["wq"], start, last
        )
    ) + (abit,)
    if counts is not None:
        queues = queues + (counts,)
    y3 = sharding.run_cores_call(call, (xp, pw.packed), queues, pw.cores)
    return stitch_core_outputs(y3, jnp.asarray(pw.col_inv), bn=bn)


def phantom_matmul(
    x: jnp.ndarray,
    pw: PhantomWeight,
    *,
    act_bits: jnp.ndarray | None = None,
    act_threshold: float = 0.0,
    out_dtype=None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """``y = x @ w`` through the two-sided block-sparse kernel.

    ``x``: [..., K]; leading dims are flattened to M (must satisfy
    ``ceil(M/bm) == grid_tiles[0]`` of ``pw``).  ``act_bits`` (int32
    [Mt, Kt]) overrides the tile bits computed from ``x`` — the §3.8 flow
    where the producing layer already emitted the mask (conv patch bits use
    this, :func:`repro.kernels.phantom_conv.conv_patch_tile_bits`).
    """
    interpret = default_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    k, n = pw.shape
    x2 = x.reshape(-1, k)
    _check_rows(x2.shape[0], pw)
    bm, bk, _ = pw.block
    bits = (
        activation_tile_bits(_pad2(x2, bm, bk), (bm, bk), act_threshold)
        if act_bits is None
        else act_bits.astype(jnp.int32)
    )
    if pw.cores > 1:
        y = _run_multicore(x2, pw, bits, interpret, out_dtype or x.dtype)
    else:
        y = _run(
            phantom_spmm.phantom_spmm_call,
            x2,
            pw,
            bits,
            interpret,
            out_dtype=out_dtype or x.dtype,
        )
    return y[: x2.shape[0], :n].reshape(*lead, n)


def phantom_linear_act(
    x: jnp.ndarray,
    pw: PhantomWeight,
    *,
    activation: str = "none",
    act_bits: jnp.ndarray | None = None,
    act_threshold: float = 0.0,
    mask_threshold: float = 0.0,
    out_dtype=None,
    interpret: bool | None = None,
):
    """Fused ``y = act(x @ w)`` + §3.8 output-encoding tile mask.

    Returns ``(y, y_tile_mask)`` — feed the mask to the next layer's
    ``phantom_matmul`` instead of recomputing it from ``y``.  ``act_bits``
    as in :func:`phantom_matmul`.
    """
    interpret = default_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    k, n = pw.shape
    x2 = x.reshape(-1, k)
    _check_rows(x2.shape[0], pw)
    bm, bk, _ = pw.block
    bits = (
        activation_tile_bits(_pad2(x2, bm, bk), (bm, bk), act_threshold)
        if act_bits is None
        else act_bits.astype(jnp.int32)
    )
    if pw.cores > 1:
        # Multi-core: the activation fuses into the flush step of the
        # multicore kernel (same fp32-accumulator application point as the
        # fused single-core kernel); the §3.8 tile encoding runs as an XLA
        # reduction over the stitched output instead of in-kernel — on the
        # *fp32* activation, pre-cast, matching the in-kernel encoding (a
        # post-cast mask could disagree for narrow out_dtypes near τ).
        y32 = _run_multicore(
            x2, pw, bits, interpret, jnp.float32, activation=activation
        )
        ymask = ref_activation_block_mask(
            y32, (bm, pw.block[2]), mask_threshold
        ).astype(jnp.int32)
        y = y32.astype(out_dtype or x.dtype)
        return y[: x2.shape[0], :n].reshape(*lead, n), ymask
    y, ymask = _run(
        phantom_ffn.phantom_linear_act_call,
        x2,
        pw,
        bits,
        interpret,
        activation=activation,
        threshold=mask_threshold,
        out_dtype=out_dtype or x.dtype,
    )
    return y[: x2.shape[0], :n].reshape(*lead, n), ymask
