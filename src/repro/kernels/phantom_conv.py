"""Block-sparse convolution on the Phantom core — the im2col lowering.

The paper's claim (§4, goal G3) is that Phantom runs *every* CNN layer kind:
unit- and non-unit-stride convolutions, depthwise, pointwise, and FC — where
SCNN handles only unit-stride.  The TPU adaptation keeps that property by
lowering Conv2D to the existing two-sided block-sparse matmul
(:mod:`repro.kernels.phantom_spmm`) via im2col, mirroring the direct sparse
convolution lowering of Park et al. and the mask-level
:func:`repro.core.dataflow.im2col_mask` used by the cycle simulator:

* **weights** ``[kh, kw, Cin, Cout]`` reshape to a ``[kh·kw·Cin, Cout]``
  matrix whose zero (bk × bn) tiles are compacted away by the
  :class:`repro.core.blocksparse.WorkQueue` — stride never appears on the
  weight side, so non-unit strides cost nothing extra;
* **grouped / depthwise** convolutions expand to a block-diagonal
  ``[kh·kw·Cin, Cout]`` matrix (group g's patch rows connect only to group
  g's filters).  The off-diagonal blocks are structurally zero, so the block
  mask compacts a depthwise layer to ~1/C of the dense tile count — the
  "grouped pointwise" view of depthwise;
* **activations** ``[B, H, W, Cin]`` unfold to a ``[B·oh·ow, kh·kw·Cin]``
  patch matrix (stride and SAME/VALID padding are absorbed here, at patch
  extraction); its zero tiles are gated in-kernel via the prefetched
  activation tile bits.  The bits can be derived either from the patch
  matrix itself or from the previous layer's §3.8 output-encoding element
  mask run through the same unfolding (``conv_patch_tile_bits``), so masks
  flow between layers without re-inspecting values.

``prepare_conv_weight`` runs once at weight-load time;
``phantom_conv_call`` is the runtime entry point and drives
``phantom_spmm_call`` (``phantom_conv_act_call`` drives the fused
linear+activation+output-encoding kernel for bias-free epilogues).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from . import ops

__all__ = [
    "PhantomConvWeight",
    "conv_geometry",
    "im2col_patches",
    "grouped_weight_matrix",
    "prepare_conv_weight",
    "conv_patch_tile_bits",
    "phantom_conv_call",
    "phantom_conv_act_call",
]


def conv_geometry(
    h: int, w: int, kh: int, kw: int, stride=(1, 1), padding: str = "SAME"
):
    """Output spatial size and explicit pads, matching ``lax`` conventions.

    Returns ``(oh, ow, ((ph_lo, ph_hi), (pw_lo, pw_hi)))``.
    """
    sh, sw = stride
    padding = padding.upper()
    if padding == "SAME":
        oh, ow = math.ceil(h / sh), math.ceil(w / sw)
        ph = max((oh - 1) * sh + kh - h, 0)
        pw = max((ow - 1) * sw + kw - w, 0)
        pads = ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2))
    elif padding == "VALID":
        oh, ow = (h - kh) // sh + 1, (w - kw) // sw + 1
        pads = ((0, 0), (0, 0))
    else:
        raise ValueError(f"padding must be SAME or VALID, got {padding!r}")
    if oh <= 0 or ow <= 0:
        raise ValueError(f"empty output for input {h}x{w}, kernel {kh}x{kw}")
    return oh, ow, pads


def im2col_patches(
    x: jnp.ndarray, kh: int, kw: int, stride=(1, 1), padding: str = "SAME"
) -> jnp.ndarray:
    """``[B, H, W, C]`` → ``[B·oh·ow, kh·kw·C]`` patch matrix.

    Feature order is ``(dy·kw + dx)·C + c`` — exactly the row order of the
    ``[kh, kw, Cin, Cout]`` weight reshaped to 2-D, and the column order of
    :func:`repro.core.dataflow.im2col_mask`.  Stride is absorbed by strided
    slicing, so the kh·kw loop is static and jit-friendly.
    """
    b, h, w, c = x.shape
    sh, sw = stride
    oh, ow, pads = conv_geometry(h, w, kh, kw, stride, padding)
    xp = jnp.pad(x, ((0, 0),) + pads + ((0, 0),))
    cols = [
        xp[:, dy : dy + (oh - 1) * sh + 1 : sh, dx : dx + (ow - 1) * sw + 1 : sw, :]
        for dy in range(kh)
        for dx in range(kw)
    ]
    patches = jnp.stack(cols, axis=3)  # [B, oh, ow, kh*kw, C]
    return patches.reshape(b * oh * ow, kh * kw * c)


def grouped_weight_matrix(w: np.ndarray, groups: int) -> np.ndarray:
    """``[kh, kw, Cin/groups, Cout]`` HWIO → block-diagonal
    ``[kh·kw·Cin, Cout]``.

    Group ``g``'s input channels feed only its ``Cout/groups`` filters; the
    cross-group blocks are exact zeros the block mask then compacts away.
    ``groups == Cin`` is depthwise (weight ``[kh, kw, 1, Cin·mult]``).
    """
    w = np.asarray(w)
    kh, kw, cpg, cout = w.shape
    if cout % groups:
        raise ValueError(f"Cout={cout} not divisible by groups={groups}")
    cin = cpg * groups
    opg = cout // groups
    w2 = np.zeros((kh * kw * cin, cout), dtype=w.dtype)
    for dy in range(kh):
        for dx in range(kw):
            base = (dy * kw + dx) * cin
            for g in range(groups):
                w2[base + g * cpg : base + (g + 1) * cpg, g * opg : (g + 1) * opg] = w[
                    dy, dx, :, g * opg : (g + 1) * opg
                ]
    return w2


@dataclasses.dataclass
class PhantomConvWeight:
    """Weight-load-time artifact for one conv layer: the packed/compacted
    ``[kh·kw·Cin, Cout]`` matrix plus the geometry needed to unfold inputs."""

    pw: ops.PhantomWeight
    kh: int
    kw: int
    stride: tuple[int, int]
    padding: str
    in_ch: int
    out_ch: int
    groups: int
    batch: int
    in_hw: tuple[int, int]
    out_hw: tuple[int, int]

    @property
    def steps(self) -> int:
        return self.pw.steps

    def density(self) -> float:
        return self.pw.density()


def prepare_conv_weight(
    w: np.ndarray,  # [kh, kw, Cin/groups, Cout] (HWIO)
    *,
    batch: int,
    in_hw: tuple[int, int],
    stride: tuple[int, int] = (1, 1),
    padding: str = "SAME",
    groups: int = 1,
    block: tuple[int, int, int] = (128, 128, 128),
    interleave: bool = True,
    dtype=jnp.float32,
) -> PhantomConvWeight:
    """Lower a (pruned) conv weight to the Phantom spmm artifact.

    The work queue is built on the reshaped ``[kh·kw·Cin, Cout]`` matrix for
    a patch matrix of ``batch · oh · ow`` rows; zero weight tiles (pruned
    blocks *and* the structural zeros of grouped convs) never enter the
    queue.
    """
    w = np.asarray(w)
    kh, kw, cpg, cout = w.shape
    cin = cpg * groups
    h, wd = in_hw
    oh, ow, _ = conv_geometry(h, wd, kh, kw, stride, padding)
    m = batch * oh * ow
    w2d = w.reshape(kh * kw * cin, cout) if groups == 1 else grouped_weight_matrix(w, groups)
    pw = ops.prepare_weight(w2d, m=m, block=block, interleave=interleave, dtype=dtype)
    return PhantomConvWeight(
        pw=pw,
        kh=kh,
        kw=kw,
        stride=tuple(stride),
        padding=padding.upper(),
        in_ch=cin,
        out_ch=cout,
        groups=groups,
        batch=batch,
        in_hw=(h, wd),
        out_hw=(oh, ow),
    )


def conv_patch_tile_bits(
    x_mask: jnp.ndarray, pcw: PhantomConvWeight, threshold: float = 0.0
) -> jnp.ndarray:
    """Previous layer's element mask ``[B, H, W, Cin]`` → activation tile
    bits ``int32 [Mt, Kt]`` of the unfolded patch matrix.

    This is the §3.8 inter-layer mask flow: the producing layer's output
    encoding is unfolded with the *same* im2col as the values, so a patch
    tile is gated iff every element it covers was encoded zero.
    """
    mp = im2col_patches(
        x_mask.astype(jnp.float32), pcw.kh, pcw.kw, pcw.stride, pcw.padding
    )
    bm, bk, _ = pcw.pw.block
    return ops.element_mask_tile_bits(mp, (bm, bk), threshold)


def _check_input(x: jnp.ndarray, pcw: PhantomConvWeight):
    b, h, w, c = x.shape
    if (b, (h, w), c) != (pcw.batch, pcw.in_hw, pcw.in_ch):
        raise ValueError(
            f"input {x.shape} does not match prepared conv weight "
            f"(batch={pcw.batch}, in_hw={pcw.in_hw}, in_ch={pcw.in_ch})"
        )


def phantom_conv_call(
    x: jnp.ndarray,  # [B, H, W, Cin]
    pcw: PhantomConvWeight,
    *,
    x_mask: jnp.ndarray | None = None,  # [B, H, W, Cin] element mask (§3.8)
    act_threshold: float = 0.0,
    out_dtype=None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Conv2D (any stride, SAME/VALID, grouped/depthwise) on the Phantom
    core: unfold → two-sided block-sparse matmul → fold.

    Returns ``[B, oh, ow, Cout]``.  When ``x_mask`` is given, activation
    tile bits come from the producing layer's output encoding instead of
    re-inspecting ``x`` (identical for exact-zero masks, cheaper on TPU).
    """
    _check_input(x, pcw)
    patches = im2col_patches(x, pcw.kh, pcw.kw, pcw.stride, pcw.padding)
    bits = None if x_mask is None else conv_patch_tile_bits(x_mask, pcw, act_threshold)
    y2 = ops.phantom_matmul(
        patches,
        pcw.pw,
        act_bits=bits,
        act_threshold=act_threshold,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    oh, ow = pcw.out_hw
    return y2.reshape(pcw.batch, oh, ow, pcw.out_ch)


def phantom_conv_act_call(
    x: jnp.ndarray,
    pcw: PhantomConvWeight,
    *,
    activation: str = "relu",
    x_mask: jnp.ndarray | None = None,
    act_threshold: float = 0.0,
    mask_threshold: float = 0.0,
    out_dtype=None,
    interpret: bool | None = None,
):
    """Fused bias-free ``act(conv(x))`` + §3.8 output-encoding tile mask.

    Returns ``(y [B, oh, ow, Cout], y_tile_mask [Mt, Nt])`` — the tile mask
    is over the flattened ``[B·oh·ow, Cout]`` output (feed it to a following
    FC/pointwise layer; spatial layers should flow the element mask of the
    activated output instead).
    """
    _check_input(x, pcw)
    patches = im2col_patches(x, pcw.kh, pcw.kw, pcw.stride, pcw.padding)
    bits = None if x_mask is None else conv_patch_tile_bits(x_mask, pcw, act_threshold)
    y2, ymask = ops.phantom_linear_act(
        patches,
        pcw.pw,
        activation=activation,
        act_bits=bits,
        act_threshold=act_threshold,
        mask_threshold=mask_threshold,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    oh, ow = pcw.out_hw
    return y2.reshape(pcw.batch, oh, ow, pcw.out_ch), ymask
