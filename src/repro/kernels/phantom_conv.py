"""Block-sparse convolution on the Phantom core — im2col and direct lowerings.

The paper's claim (§4, goal G3) is that Phantom runs *every* CNN layer kind:
unit- and non-unit-stride convolutions, depthwise, pointwise, and FC — where
SCNN handles only unit-stride.  Two lowerings keep that property on the TPU
adaptation, selected by ``mode`` at weight-load time (DESIGN.md §3):

* ``mode="direct"`` (default) — implicit im2col: the patch matrix is never
  built.  The work queue carries per-step ``(ky, kx, cin-block)`` coordinates
  (:class:`repro.core.blocksparse.ConvWorkQueue`) and the kernel
  (:mod:`repro.kernels.phantom_conv_direct`) gathers each activation tile
  straight out of the phase-decomposed padded NHWC input via unblocked
  scalar-prefetch index maps — the only HBM traffic is the raw activation
  plus the packed nonzero weight payload, mirroring the in-kernel gather of
  Park et al.'s direct sparse convolution and Elsen et al.'s fast convnets;
* ``mode="im2col"`` — the explicit lowering below, kept alive as the oracle
  the direct kernel must match (it materialises the ``kh·kw``× patch matrix
  in HBM, so it is the memory-hungry reference path).

The im2col lowering maps Conv2D onto the existing two-sided block-sparse
matmul (:mod:`repro.kernels.phantom_spmm`), mirroring the mask-level
:func:`repro.core.dataflow.im2col_mask` used by the cycle simulator:

* **weights** ``[kh, kw, Cin, Cout]`` reshape to a ``[kh·kw·Cin, Cout]``
  matrix whose zero (bk × bn) tiles are compacted away by the
  :class:`repro.core.blocksparse.WorkQueue` — stride never appears on the
  weight side, so non-unit strides cost nothing extra;
* **grouped / depthwise** convolutions expand to a block-diagonal
  ``[kh·kw·Cin, Cout]`` matrix (group g's patch rows connect only to group
  g's filters).  The off-diagonal blocks are structurally zero, so the block
  mask compacts a depthwise layer to ~1/C of the dense tile count — the
  "grouped pointwise" view of depthwise;
* **activations** ``[B, H, W, Cin]`` unfold to a ``[B·oh·ow, kh·kw·Cin]``
  patch matrix (stride and SAME/VALID padding are absorbed here, at patch
  extraction); its zero tiles are gated in-kernel via the prefetched
  activation tile bits.  The bits can be derived either from the patch
  matrix itself or from the previous layer's §3.8 output-encoding element
  mask run through the same unfolding (``conv_patch_tile_bits``), so masks
  flow between layers without re-inspecting values.

``prepare_conv_weight`` runs once at weight-load time;
``phantom_conv_call`` is the runtime entry point and drives
``phantom_spmm_call`` (``phantom_conv_act_call`` drives the fused
linear+activation+output-encoding kernel for bias-free epilogues).
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax.numpy as jnp
import numpy as np

from repro.core import blocksparse as bs

from . import ops, phantom_conv_direct
from .ref import ref_activation_block_mask

__all__ = [
    "PhantomConvWeight",
    "DirectConvPlan",
    "conv_geometry",
    "im2col_patches",
    "grouped_weight_matrix",
    "prepare_conv_weight",
    "conv_patch_tile_bits",
    "direct_conv_tile_bits",
    "phantom_conv_call",
    "phantom_conv_act_call",
]


def conv_geometry(
    h: int, w: int, kh: int, kw: int, stride=(1, 1), padding: str = "SAME"
):
    """Output spatial size and explicit pads, matching ``lax`` conventions.

    Returns ``(oh, ow, ((ph_lo, ph_hi), (pw_lo, pw_hi)))``.
    """
    sh, sw = stride
    padding = padding.upper()
    if padding == "SAME":
        oh, ow = math.ceil(h / sh), math.ceil(w / sw)
        ph = max((oh - 1) * sh + kh - h, 0)
        pw = max((ow - 1) * sw + kw - w, 0)
        pads = ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2))
    elif padding == "VALID":
        oh, ow = (h - kh) // sh + 1, (w - kw) // sw + 1
        pads = ((0, 0), (0, 0))
    else:
        raise ValueError(f"padding must be SAME or VALID, got {padding!r}")
    if oh <= 0 or ow <= 0:
        raise ValueError(f"empty output for input {h}x{w}, kernel {kh}x{kw}")
    return oh, ow, pads


def im2col_patches(
    x: jnp.ndarray, kh: int, kw: int, stride=(1, 1), padding: str = "SAME"
) -> jnp.ndarray:
    """``[B, H, W, C]`` → ``[B·oh·ow, kh·kw·C]`` patch matrix.

    Feature order is ``(dy·kw + dx)·C + c`` — exactly the row order of the
    ``[kh, kw, Cin, Cout]`` weight reshaped to 2-D, and the column order of
    :func:`repro.core.dataflow.im2col_mask`.  Stride is absorbed by strided
    slicing, so the kh·kw loop is static and jit-friendly.
    """
    b, h, w, c = x.shape
    sh, sw = stride
    oh, ow, pads = conv_geometry(h, w, kh, kw, stride, padding)
    xp = jnp.pad(x, ((0, 0),) + pads + ((0, 0),))
    cols = [
        xp[:, dy : dy + (oh - 1) * sh + 1 : sh, dx : dx + (ow - 1) * sw + 1 : sw, :]
        for dy in range(kh)
        for dx in range(kw)
    ]
    patches = jnp.stack(cols, axis=3)  # [B, oh, ow, kh*kw, C]
    return patches.reshape(b * oh * ow, kh * kw * c)


def grouped_weight_matrix(w: np.ndarray, groups: int) -> np.ndarray:
    """``[kh, kw, Cin/groups, Cout]`` HWIO → block-diagonal
    ``[kh·kw·Cin, Cout]``.

    Group ``g``'s input channels feed only its ``Cout/groups`` filters; the
    cross-group blocks are exact zeros the block mask then compacts away.
    ``groups == Cin`` is depthwise (weight ``[kh, kw, 1, Cin·mult]``).
    """
    w = np.asarray(w)
    kh, kw, cpg, cout = w.shape
    if cout % groups:
        raise ValueError(f"Cout={cout} not divisible by groups={groups}")
    cin = cpg * groups
    opg = cout // groups
    w2 = np.zeros((kh * kw * cin, cout), dtype=w.dtype)
    for dy in range(kh):
        for dx in range(kw):
            base = (dy * kw + dx) * cin
            for g in range(groups):
                w2[base + g * cpg : base + (g + 1) * cpg, g * opg : (g + 1) * opg] = w[
                    dy, dx, :, g * opg : (g + 1) * opg
                ]
    return w2


@dataclasses.dataclass
class DirectConvPlan(ops.MulticoreSteps):
    """Direct-mode weight-load artifact: tap-aligned packed payload plus the
    coordinate-carrying work queue, fully lowered to the per-step source
    offsets the kernel's unblocked index maps consume (DESIGN.md §3).

    K is tiled per filter tap — flat k-tile ``(ky·kw + kx)·ct + ci`` — so a
    k-tile never straddles a (ky, kx) boundary and its activation source is a
    contiguous ``[ow, bk]`` window of the phase-decomposed padded input.

    Multi-core (``cores > 1``, DESIGN.md §9): queue/offset arrays are int32
    [cores, Qpad] — one makespan-padded queue per virtual core — ``ni`` is
    core-local and ``col_perm`` maps core-major local columns back to global
    output tile-columns, exactly as in
    :class:`repro.kernels.ops.PhantomWeight`.
    """

    packed: jnp.ndarray  # [nnzb, bk, bn] tap-aligned payload
    # Per-step source offsets into the [PH, B, Hq, Wq, Cp] phase array:
    ph: np.ndarray  # (ky % sh)·sw + kx % sw — phase plane
    nb: np.ndarray  # batch index
    r0: np.ndarray  # phase row: oy + ky // sh
    c0: np.ndarray  # phase col window start: kx // sw
    ch0: np.ndarray  # channel element offset: ci · bk
    # Queue arrays (incl. §3.8 empty-output steps):
    mi: np.ndarray
    ni: np.ndarray
    wq: np.ndarray
    start: np.ndarray
    last: np.ndarray
    valid: np.ndarray  # 0 on empty-output steps (abit forced 0)
    flat_ak: np.ndarray  # mi·Kt + ki per step (tile-bit gather index)
    block: tuple[int, int]  # (bk, bn)
    ct: int  # Cin blocks per filter tap
    grid_tiles: tuple[int, int, int]  # (Mt = B·oh, Kt = kh·kw·ct, Nt)
    phase_shape: tuple[int, int, int, int, int]  # (PH, B, Hq, Wq, Cp)
    w_bmask: np.ndarray  # [Kt, Nt] tap-aligned weight tile mask
    cores: int = 1
    col_perm: np.ndarray | None = None  # int64 [cores·local_nt], −1 = pad slot
    col_inv: np.ndarray | None = None  # int64 [Nt] inverse (stitch gather)
    local_nt: int = 0  # per-core padded column-tile width
    core_steps: np.ndarray | None = None  # int64 [cores] real steps per core
    core_cost: np.ndarray | None = None  # int64 [cores] Σ column nnz blocks
    # Runtime lookahead compaction (DESIGN.md §10): L_f window (0 = gated
    # path) + the static segment metadata `compact_queue` consumes.
    lookahead: int = 0
    cmeta: dict | None = None  # {"seg_base", "seg_end", "pad"} per-entry


@dataclasses.dataclass
class PhantomConvWeight:
    """Weight-load-time artifact for one conv layer: the packed/compacted
    ``[kh·kw·Cin, Cout]`` matrix plus the geometry needed to unfold inputs.

    ``mode="im2col"`` fills ``pw`` (the generic spmm artifact over the
    explicit patch matrix); ``mode="direct"`` fills ``plan`` (the implicit
    gather artifact).  ``mask_block`` is the (bm, bn) tiling of the §3.8
    output-encoding tile mask — identical for both modes, so masks emitted
    by either path are directly comparable."""

    pw: ops.PhantomWeight | None
    kh: int
    kw: int
    stride: tuple[int, int]
    padding: str
    in_ch: int
    out_ch: int
    groups: int
    batch: int
    in_hw: tuple[int, int]
    out_hw: tuple[int, int]
    mode: str = "im2col"
    plan: DirectConvPlan | None = None
    mask_block: tuple[int, int] = (128, 128)

    @property
    def steps(self) -> int:
        return self.pw.steps if self.pw is not None else self.plan.steps

    def density(self) -> float:
        bmask = self.pw.w_bmask if self.pw is not None else self.plan.w_bmask
        return float(bmask.mean())


def _prepare_direct(
    w2d: np.ndarray,  # [kh·kw·Cin, Cout]
    *,
    batch: int,
    kh: int,
    kw: int,
    cin: int,
    oh: int,
    ow: int,
    stride: tuple[int, int],
    block: tuple[int, int, int],
    interleave: bool,
    dtype,
    cores: int = 1,
    balance: str = "full",
    lookahead: int = 0,
) -> DirectConvPlan:
    """Build the implicit-gather plan: tap-align the weight, compact it into
    a coordinate-carrying queue, and lower every step to its element offsets
    in the phase-decomposed padded activation.  ``cores > 1`` partitions the
    output tile-columns across virtual cores (DESIGN.md §9) — per-core
    makespan-padded queues, one leading cores grid axis at runtime."""
    _bm, bk, bn = block
    cout = w2d.shape[1]
    sh, sw = stride
    ct = math.ceil(cin / bk)
    cp = ct * bk
    # Tap-align: pad each (ky, kx) channel segment to ct whole bk-blocks so
    # no k-tile straddles a filter tap (the padding rows are exact zeros).
    w3 = np.zeros((kh * kw, cp, cout), dtype=w2d.dtype)
    w3[:, :cin] = w2d.reshape(kh * kw, cin, cout)
    wpad = w3.reshape(kh * kw * cp, cout)
    bmask = bs.block_mask_from_dense(wpad, (bk, bn)).mask  # [kh·kw·ct, Nt]
    mt = batch * oh
    kt, nt = bmask.shape
    geom = dict(
        block=(bk, bn),
        ct=ct,
        grid_tiles=(mt, kt, nt),
        phase_shape=(sh * sw, batch, oh + (kh - 1) // sh, ow + (kw - 1) // sw, cp),
        w_bmask=bmask,
    )
    if cores > 1:
        buckets, q, meta = ops.build_multicore_queues(
            bmask, mt, cores, balance, interleave=interleave,
            conv={"kw": kw, "ct": ct},
        )
        wpe = np.zeros((kt * bk, nt * bn), dtype=wpad.dtype)
        wpe[: wpad.shape[0], :cout] = wpad
        packed, offsets = ops.pack_multicore_blocks(wpe, bmask, buckets, (bk, bn))
        mi, ky, kx, ci = q["mi"], q["ky"], q["kx"], q["ci"]
        return DirectConvPlan(
            packed=jnp.asarray(packed, dtype=dtype),
            ph=((ky % sh) * sw + kx % sw).astype(np.int32),
            nb=(mi // oh).astype(np.int32),
            r0=(mi % oh + ky // sh).astype(np.int32),
            c0=(kx // sw).astype(np.int32),
            ch0=(ci * bk).astype(np.int32),
            mi=mi,
            ni=q["ni"],
            wq=q["wq"] + offsets[:, None],
            start=q["start"],
            last=q["last"],
            valid=q["valid"],
            flat_ak=mi * kt + q["ki"],
            cores=cores,
            lookahead=lookahead,
            cmeta=(
                ops.compaction.compaction_meta(q["start"], meta["core_steps"])
                if lookahead
                else None
            ),
            **geom,
            **meta,
        )
    queue = bs.build_conv_work_queue(bmask, mt, kw=kw, ct=ct, interleave=interleave)
    packed = jnp.asarray(bs.pack_blocks(wpad, bmask, (bk, bn)), dtype=dtype)
    mi, ni, ki, wq, start, last, valid = ops.append_empty_steps(queue)
    pad0 = np.zeros(len(mi) - queue.steps, dtype=np.int32)
    ky = np.concatenate([queue.ky, pad0])  # empty steps read (in-bounds) 0s
    kx = np.concatenate([queue.kx, pad0])
    ci = np.concatenate([queue.ci, pad0])
    return DirectConvPlan(
        packed=packed,
        ph=((ky % sh) * sw + kx % sw).astype(np.int32),
        nb=(mi // oh).astype(np.int32),
        r0=(mi % oh + ky // sh).astype(np.int32),
        c0=(kx // sw).astype(np.int32),
        ch0=(ci * bk).astype(np.int32),
        mi=mi,
        ni=ni,
        wq=wq,
        start=start,
        last=last,
        valid=valid,
        flat_ak=mi * kt + ki,
        lookahead=lookahead,
        cmeta=ops.compaction.compaction_meta(start) if lookahead else None,
        **geom,
    )


def prepare_conv_weight(
    w: np.ndarray,  # [kh, kw, Cin/groups, Cout] (HWIO)
    *,
    batch: int,
    in_hw: tuple[int, int],
    stride: tuple[int, int] = (1, 1),
    padding: str = "SAME",
    groups: int = 1,
    block: tuple[int, int, int] = (128, 128, 128),
    interleave: bool = True,
    mode: str = "direct",
    dtype=jnp.float32,
    cores: int = 1,
    balance: str = "full",
    lookahead: int = 0,
    config=None,
) -> PhantomConvWeight:
    """Lower a (pruned) conv weight to a Phantom core artifact.

    ``mode="direct"`` (default) builds the implicit-im2col plan — the patch
    matrix is never materialised at runtime; ``mode="im2col"`` builds the
    explicit spmm artifact over the ``batch · oh · ow``-row patch matrix.
    Either way, zero weight tiles (pruned blocks *and* the structural zeros
    of grouped convs) never enter the work queue.  ``cores > 1`` partitions
    the output tile-columns (= filter blocks) across virtual Phantom cores,
    balanced per the ``balance`` policy (DESIGN.md §9) — both lowerings run
    all cores in one ``pallas_call`` with a leading cores grid axis.
    ``lookahead`` ≥ 1 additionally compacts the queue at call time against
    the activation bits (DESIGN.md §10).

    ``config`` (a :class:`repro.core.phantom_linear.PhantomConfig`) is the
    preferred knob surface and overrides ``block``/``interleave``/``mode``
    /``dtype``/``cores``/``balance``/``lookahead`` — the program API
    (DESIGN.md §8) passes it through unchanged.
    """
    if config is not None:
        block, interleave = config.block, config.interleave
        mode, dtype = config.conv_mode, config.jnp_dtype()
        cores, balance = config.cores, config.balance
        lookahead = config.lookahead
    lookahead = int(lookahead or 0)
    if lookahead < 0:
        raise ValueError(f"lookahead must be >= 0, got {lookahead}")
    if mode not in ("direct", "im2col"):
        raise ValueError(f"mode must be 'direct' or 'im2col', got {mode!r}")
    interleave = interleave and bs.balance_interleaves(balance)
    w = np.asarray(w)
    kh, kw, cpg, cout = w.shape
    cin = cpg * groups
    h, wd = in_hw
    oh, ow, _ = conv_geometry(h, wd, kh, kw, stride, padding)
    w2d = w.reshape(kh * kw * cin, cout) if groups == 1 else grouped_weight_matrix(w, groups)
    pw = plan = None
    if mode == "im2col":
        pw = ops.prepare_weight(
            w2d, m=batch * oh * ow, block=block, interleave=interleave,
            dtype=dtype, cores=cores, balance=balance, lookahead=lookahead,
        )
    else:
        plan = _prepare_direct(
            w2d,
            batch=batch,
            kh=kh,
            kw=kw,
            cin=cin,
            oh=oh,
            ow=ow,
            stride=tuple(stride),
            block=block,
            interleave=interleave,
            dtype=dtype,
            cores=cores,
            balance=balance,
            lookahead=lookahead,
        )
    return PhantomConvWeight(
        pw=pw,
        kh=kh,
        kw=kw,
        stride=tuple(stride),
        padding=padding.upper(),
        in_ch=cin,
        out_ch=cout,
        groups=groups,
        batch=batch,
        in_hw=(h, wd),
        out_hw=(oh, ow),
        mode=mode,
        plan=plan,
        mask_block=(block[0], block[2]),
    )


def conv_patch_tile_bits(
    x_mask: jnp.ndarray, pcw: PhantomConvWeight, threshold: float = 0.0
) -> jnp.ndarray:
    """Previous layer's element mask ``[B, H, W, Cin]`` → activation tile
    bits ``int32 [Mt, Kt]`` of the unfolded patch matrix (im2col mode).

    This is the §3.8 inter-layer mask flow: the producing layer's output
    encoding is unfolded with the *same* im2col as the values, so a patch
    tile is gated iff every element it covers was encoded zero.
    """
    mp = im2col_patches(
        x_mask.astype(jnp.float32), pcw.kh, pcw.kw, pcw.stride, pcw.padding
    )
    bm, bk, _ = pcw.pw.block
    return ops.element_mask_tile_bits(mp, (bm, bk), threshold)


def direct_conv_tile_bits(
    src: jnp.ndarray, pcw: PhantomConvWeight, threshold: float = 0.0
) -> jnp.ndarray:
    """Activation values or element mask ``[B, H, W, Cin]`` → tile bits
    ``int32 [Mt = B·oh, Kt = kh·kw·ct]`` of the *implicit* patch matrix.

    Direct-mode analogue of :func:`conv_patch_tile_bits`: the any-reduction
    runs on strided slices of the padded input itself — nothing ``kh·kw``×
    the activation is ever materialised (the slices are views of one padded
    copy).  Bit (mi, ki) covers exactly the ``[ow, bk]`` window queue step
    (mi, ki) would read, so gating is as precise as the im2col path's.
    """
    plan = pcw.plan
    kh, kw = pcw.kh, pcw.kw
    sh, sw = pcw.stride
    oh, ow = pcw.out_hw
    bk = plan.block[0]
    b = src.shape[0]
    h, wd = pcw.in_hw
    _, _, pads = conv_geometry(h, wd, kh, kw, pcw.stride, pcw.padding)
    cp = plan.ct * bk
    xp = jnp.pad(
        jnp.asarray(src, jnp.float32),
        ((0, 0),) + pads + ((0, cp - pcw.in_ch),),
    )
    bits = []
    for dy in range(kh):
        for dx in range(kw):
            sl = xp[
                :, dy : dy + (oh - 1) * sh + 1 : sh, dx : dx + (ow - 1) * sw + 1 : sw, :
            ]  # [B, oh, ow, Cp] — the tap's windows, all output positions
            keep = (jnp.abs(sl) > threshold).reshape(b, oh, ow, plan.ct, bk)
            bits.append(keep.any(axis=(2, 4)))  # [B, oh, ct]
    k = jnp.stack(bits, axis=2)  # [B, oh, kh·kw, ct] — matches flat-k order
    return k.reshape(b * oh, kh * kw * plan.ct).astype(jnp.int32)


def _phase_input(x: jnp.ndarray, pcw: PhantomConvWeight) -> jnp.ndarray:
    """Pad and phase-decompose the activation for the direct kernel.

    Returns ``xph [PH, B, Hq, Wq, Cp]`` with
    ``xph[py·sw + px, b, i, j, c] = xp[b, i·sh + py, j·sw + px, c]`` — a
    constant-factor copy of the padded input (and for stride 1 just a
    reshape), after which every (ky, kx) tap reads a *contiguous* window.
    """
    plan = pcw.plan
    sh, sw = pcw.stride
    h, wd = pcw.in_hw
    _, _, pads = conv_geometry(h, wd, pcw.kh, pcw.kw, pcw.stride, pcw.padding)
    _, _, hq, wq, cp = plan.phase_shape
    xp = jnp.pad(x, ((0, 0),) + pads + ((0, cp - pcw.in_ch),))
    if sh == 1 and sw == 1:
        return xp[None]  # Hq = Hp, Wq = Wp: the padded input IS the phase
    xph = jnp.zeros(plan.phase_shape, x.dtype)
    for py in range(sh):
        for px in range(sw):
            sl = xp[:, py::sh, px::sw, :][:, :hq, :wq, :]
            xph = xph.at[
                py * sw + px, :, : sl.shape[1], : sl.shape[2], :
            ].set(sl)
    return xph


def _direct_call(
    x: jnp.ndarray,
    pcw: PhantomConvWeight,
    *,
    activation: str,
    x_mask: jnp.ndarray | None,
    act_threshold: float,
    out_dtype,
    interpret: bool | None,
) -> jnp.ndarray:
    plan = pcw.plan
    interpret = ops.default_interpret() if interpret is None else interpret
    xph = _phase_input(x, pcw)
    bits = direct_conv_tile_bits(
        x if x_mask is None else x_mask, pcw, act_threshold
    )
    abit = (
        bits.reshape(-1)[jnp.asarray(plan.flat_ak)] * jnp.asarray(plan.valid)
    ).astype(jnp.int32)
    fields = dict(
        ph=plan.ph, nb=plan.nb, r0=plan.r0, c0=plan.c0, ch0=plan.ch0,
        mi=plan.mi, ni=plan.ni, wq=plan.wq,
    )
    start, last, count = plan.start, plan.last, None
    if plan.lookahead:
        # Lookahead compaction (DESIGN.md §10): the spatial source offsets
        # ride through the same gather as the queue indices.
        fields, start, last, abit, count = ops._compact(fields, plan, abit)
    oh, ow = pcw.out_hw
    if plan.cores > 1:
        from repro.parallel import sharding  # local: keep kernels standalone

        mt, kt, _nt = plan.grid_tiles
        call = functools.partial(
            phantom_conv_direct.phantom_conv_direct_multicore_call,
            ow=ow,
            block=plan.block,
            grid_tiles=(mt, kt, plan.local_nt),
            activation=activation,
            out_dtype=out_dtype or x.dtype,
            interpret=interpret,
        )
        queues = tuple(
            jnp.asarray(a)
            for a in (
                fields["ph"], fields["nb"], fields["r0"], fields["c0"],
                fields["ch0"], fields["mi"], fields["ni"], fields["wq"],
                start, last,
            )
        ) + (abit,)
        if count is not None:
            queues = queues + (count,)  # per-core counts split by shard_map
        y3 = sharding.run_cores_call(call, (xph, plan.packed), queues, plan.cores)
        y2 = ops.stitch_core_outputs(
            y3, jnp.asarray(plan.col_inv), bn=plan.block[1]
        )
        return y2[:, : pcw.out_ch].reshape(pcw.batch, oh, ow, pcw.out_ch)
    y2 = phantom_conv_direct.phantom_conv_direct_call(
        xph,
        plan.packed,
        jnp.asarray(fields["ph"]),
        jnp.asarray(fields["nb"]),
        jnp.asarray(fields["r0"]),
        jnp.asarray(fields["c0"]),
        jnp.asarray(fields["ch0"]),
        jnp.asarray(fields["mi"]),
        jnp.asarray(fields["ni"]),
        jnp.asarray(fields["wq"]),
        jnp.asarray(start),
        jnp.asarray(last),
        abit,
        count,
        ow=ow,
        block=plan.block,
        grid_tiles=plan.grid_tiles,
        activation=activation,
        out_dtype=out_dtype or x.dtype,
        interpret=interpret,
    )
    return y2[:, : pcw.out_ch].reshape(pcw.batch, oh, ow, pcw.out_ch)


def _check_input(x: jnp.ndarray, pcw: PhantomConvWeight):
    b, h, w, c = x.shape
    if (b, (h, w), c) != (pcw.batch, pcw.in_hw, pcw.in_ch):
        raise ValueError(
            f"input {x.shape} does not match prepared conv weight "
            f"(batch={pcw.batch}, in_hw={pcw.in_hw}, in_ch={pcw.in_ch})"
        )


def phantom_conv_call(
    x: jnp.ndarray,  # [B, H, W, Cin]
    pcw: PhantomConvWeight,
    *,
    x_mask: jnp.ndarray | None = None,  # [B, H, W, Cin] element mask (§3.8)
    act_threshold: float = 0.0,
    out_dtype=None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Conv2D (any stride, SAME/VALID, grouped/depthwise) on the Phantom
    core: unfold → two-sided block-sparse matmul → fold.

    Returns ``[B, oh, ow, Cout]``.  When ``x_mask`` is given, activation
    tile bits come from the producing layer's output encoding instead of
    re-inspecting ``x`` (identical for exact-zero masks, cheaper on TPU).
    Dispatches on ``pcw.mode``: the direct path gathers patches in-kernel;
    the im2col path materialises them here first.
    """
    _check_input(x, pcw)
    if pcw.mode == "direct":
        return _direct_call(
            x,
            pcw,
            activation="none",
            x_mask=x_mask,
            act_threshold=act_threshold,
            out_dtype=out_dtype,
            interpret=interpret,
        )
    patches = im2col_patches(x, pcw.kh, pcw.kw, pcw.stride, pcw.padding)
    bits = None if x_mask is None else conv_patch_tile_bits(x_mask, pcw, act_threshold)
    y2 = ops.phantom_matmul(
        patches,
        pcw.pw,
        act_bits=bits,
        act_threshold=act_threshold,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    oh, ow = pcw.out_hw
    return y2.reshape(pcw.batch, oh, ow, pcw.out_ch)


def phantom_conv_act_call(
    x: jnp.ndarray,
    pcw: PhantomConvWeight,
    *,
    activation: str = "relu",
    x_mask: jnp.ndarray | None = None,
    act_threshold: float = 0.0,
    mask_threshold: float = 0.0,
    out_dtype=None,
    interpret: bool | None = None,
):
    """Fused bias-free ``act(conv(x))`` + §3.8 output-encoding tile mask.

    Returns ``(y [B, oh, ow, Cout], y_tile_mask [Mt, Nt])`` — the tile mask
    is over the flattened ``[B·oh·ow, Cout]`` output at ``pcw.mask_block``
    tiling, identical for both modes (feed it to a following FC/pointwise
    layer; spatial layers should flow the element mask of the activated
    output instead).  In direct mode the activation is fused into the
    kernel's flush step and the tile encoding runs as an XLA reduction over
    the kernel output (on TPU it would fuse into the epilogue; the im2col
    kernel computes it on the resident VMEM tile — DESIGN.md §3).
    """
    _check_input(x, pcw)
    if pcw.mode == "direct":
        y = _direct_call(
            x,
            pcw,
            activation=activation,
            x_mask=x_mask,
            act_threshold=act_threshold,
            out_dtype=out_dtype,
            interpret=interpret,
        )
        ymask = ref_activation_block_mask(
            y.reshape(-1, pcw.out_ch), pcw.mask_block, mask_threshold
        ).astype(jnp.int32)
        return y, ymask
    patches = im2col_patches(x, pcw.kh, pcw.kw, pcw.stride, pcw.padding)
    bits = None if x_mask is None else conv_patch_tile_bits(x_mask, pcw, act_threshold)
    y2, ymask = ops.phantom_linear_act(
        patches,
        pcw.pw,
        activation=activation,
        act_bits=bits,
        act_threshold=act_threshold,
        mask_threshold=mask_threshold,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    oh, ow = pcw.out_hw
    return y2.reshape(pcw.batch, oh, ow, pcw.out_ch), ymask
