"""``phantom.compile`` → :class:`PhantomProgram`: the compile-once artifact.

The paper's value is a *weight-load-time* transformation (mask+payload
compaction, queue scheduling, the §3.8 output-encoding flow) reused for
every inference.  ``PhantomProgram`` is that transformation reified as one
object (DESIGN.md §8):

* **per-batch-size plan cache** — Phantom artifacts bake the M-tile count
  into the work queue (DESIGN.md §4), so plans are shape-specialised;
  :meth:`at_batch` lowers a batch size at most once and the
  :attr:`lowerings` counter proves it;
* **save / load** — packed payloads + queues + config go through the atomic
  :mod:`repro.checkpoint` writer, so lowering happens once per fleet, not
  once per process: a loaded program serves immediately (``lowerings == 0``);
* **stats** — per-layer steps / density / valid_macs for the
  engine↔simulator consistency contract (DESIGN.md §5).

Layer execution is delegated to the :mod:`repro.program.registry` kinds;
the forward is the generic walk in :mod:`repro.program.plans`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import types
import typing
import warnings

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, _flatten as _flatten_params
from repro.core.phantom_linear import PhantomConfig

from . import serialize
from .plans import build_nodes, run_prepared
from .registry import kind_for, spec_class

__all__ = ["PhantomProgram", "compile", "warn_deprecated", "reset_deprecation_warnings"]

#: Default knobs for ``compile`` when no config is given: the serving
#: defaults the old ``prepare_cnn_phantom`` hardcoded (128-tiles, direct
#: conv, fp32, exact-zero skipping).
SERVE_DEFAULT = PhantomConfig(enabled=True, block=(128, 128, 128))

_FORMAT_VERSION = 1


class PhantomProgram:
    """A network compiled onto the Phantom core, for any batch size.

    Built by :func:`compile`; callable: ``program(x)`` runs the batch
    ``x.shape[0]`` plan (lowering it on first use), with §3.8 masks flowing
    between layers and the τ-at-producer rule applied uniformly.
    """

    def __init__(
        self,
        layers,
        params,
        cfg: PhantomConfig | None = None,
        *,
        overrides: dict | None = None,
        recorder=None,
    ):
        self.layers = list(layers)
        self.cfg = cfg or SERVE_DEFAULT
        self.params = params
        #: per-layer partial PhantomConfig diffs (``{layer name: {field:
        #: value}}``, DESIGN.md §12) — the autotuner's output, or explicit
        #: caller tunings.  Normalised (block lists → tuples, empty diffs
        #: dropped), validated against the layer list and the config's field
        #: set, and serialised by :meth:`save` so a loaded program lowers
        #: new batch sizes with the same per-layer configs.
        self.overrides = _normalize_overrides(overrides, self.layers, self.cfg)
        self.nodes = build_nodes(self.layers, cfg=self.cfg, overrides=self.overrides)
        self._plans: dict[int, dict] = {}  # batch -> {layer name: plan}
        #: number of weight-load-time lowerings actually performed by this
        #: object (cache hits and checkpoint loads do not count).
        self.lowerings = 0
        #: optional :class:`repro.obs.Recorder` (DESIGN.md §11).  Purely a
        #: runtime sink: it is never serialised, so attaching one leaves
        #: :meth:`save` output byte-identical.
        self.recorder = recorder
        #: when True, every fresh lowering in :meth:`at_batch` runs the
        #: static verifier over the new plans (DESIGN.md §13).  Set by
        #: ``compile(verify=...)`` / ``load(verify=...)``; never serialised.
        self.verify = False

    # -- plan cache ----------------------------------------------------------
    def at_batch(self, batch: int) -> dict:
        """The prepared ``{layer name: plan}`` dict for ``batch`` rows.

        Lowers on first use, then serves from the cache — the "queue bakes
        in the M-tile count" shape specialisation never leaks to callers.
        """
        batch = int(batch)
        if batch <= 0:
            raise ValueError(f"batch must be positive, got {batch}")
        if batch not in self._plans:
            rec = self.recorder
            cm = (
                rec.span("program/lower", batch=batch)
                if rec is not None
                else contextlib.nullcontext()
            )
            with cm:
                self._plans[batch] = {
                    node.name: kind_for(node.spec).prepare(
                        node.spec, self.params[node.name], batch,
                        node.cfg or self.cfg,
                    )
                    for node in self.nodes
                }
            self.lowerings += 1
            if self.verify:
                # Deferred import (same cycle-freedom rule as the tuner):
                # the verifier checks programs, programs must import clean
                # without it.  Graph rules ran at compile/load time; only
                # the freshly lowered plans need checking here.
                from repro import verify as _verify

                _verify.verify_program(self, batches=(batch,), graph=False)
            if rec is not None:
                rec.inc("program/lowerings")
                self._record_static(batch, rec)
        return self._plans[batch]

    def _record_static(self, batch: int, rec) -> None:
        """Weight-load-time facts as gauges, once per lowered batch size:
        per-layer queue steps, and for multi-core plans the per-core
        work / makespan / imbalance of DESIGN.md §9."""
        prepared = self._plans[batch]
        for node in self.nodes:
            s = kind_for(node.spec).stats(prepared[node.name], node.spec, batch)
            lab = dict(layer=node.name, batch=batch)
            rec.gauge("layer/steps", s["steps"], **lab)
            rec.gauge("layer/dense_steps", s["dense_steps"], **lab)
            if "makespan" in s:
                rec.gauge("layer/makespan", s["makespan"], **lab)
                rec.gauge("layer/imbalance", s["imbalance"], **lab)
                for c, w in enumerate(s["per_core_work"]):
                    rec.gauge("layer/core_work", w, core=c, **lab)

    @property
    def batch_sizes(self) -> tuple[int, ...]:
        return tuple(sorted(self._plans))

    def effective_cfg(self, name: str) -> PhantomConfig:
        """The config layer ``name`` actually lowers with: the base config
        plus that layer's override diff, if any."""
        for node in self.nodes:
            if node.name == name:
                return node.cfg or self.cfg
        raise KeyError(f"no layer named {name!r}; layers: "
                       f"{[n.name for n in self.nodes]}")

    # -- execution -----------------------------------------------------------
    def __call__(
        self,
        x: jnp.ndarray,
        *,
        slot_mask: jnp.ndarray | None = None,
        act_threshold: float | None = None,
        interpret: bool | None = None,
    ) -> jnp.ndarray:
        """Run the network on ``x`` (batch inferred from ``x.shape[0]``).

        ``act_threshold`` defaults to ``cfg.act_threshold``; ``slot_mask``
        (float [B], 1 = live) gates padded serving slots (DESIGN.md §4).

        With a :attr:`recorder` attached (DESIGN.md §11) each call records
        one ``program/call`` span plus one ``layer/<name>`` span per layer
        (wall time, ``block_until_ready``-correct); a recorder constructed
        with ``runtime=True`` additionally accounts the §10 per-call
        runtime stats (executed steps / utilization per layer) from the
        same activation tile bits the kernels gate on.
        """
        prepared = self.at_batch(x.shape[0])
        tau = self.cfg.act_threshold if act_threshold is None else act_threshold
        rec = self.recorder
        if rec is None:
            return run_prepared(
                self.nodes,
                self.params,
                prepared,
                x,
                act_threshold=tau,
                slot_mask=slot_mask,
                interpret=interpret,
            )
        collected: dict | None = {} if rec.runtime else None
        with rec.span("program/call", batch=int(x.shape[0])):
            out = run_prepared(
                self.nodes,
                self.params,
                prepared,
                x,
                act_threshold=tau,
                slot_mask=slot_mask,
                interpret=interpret,
                collect=collected,
                recorder=rec,
            )
        rec.inc("program/calls")
        if collected:
            for node in self.nodes:
                rs = getattr(kind_for(node.spec), "runtime_stats", None)
                if rs is not None and node.name in collected:
                    st = rs(prepared[node.name], collected[node.name])
                    rec.gauge(
                        "layer/executed_steps",
                        st["executed_steps"],
                        layer=node.name,
                    )
                    rec.observe(
                        "layer/utilization", st["utilization"], layer=node.name
                    )
        return out

    # -- introspection -------------------------------------------------------
    def stats(
        self,
        batch: int | None = None,
        *,
        sample: jnp.ndarray | None = None,
        slot_mask: jnp.ndarray | None = None,
        interpret: bool | None = None,
    ) -> dict:
        """Per-layer ``{name: {steps, density, valid_macs, ...}}``.

        ``batch=None`` reads the single cached batch size (error if zero or
        several are cached — pass one explicitly then).  Never lowers.

        With ``sample`` (an input batch of the requested size) the static
        stats are augmented with the *runtime* lookahead accounting of
        DESIGN.md §10 — ``executed_steps`` / ``retired_per_step`` /
        ``utilization`` per layer, computed from the exact activation tile
        bits that batch's forward gates (and, with ``cfg.lookahead``,
        compacts) on.  This runs the forward once to flow the §3.8 masks.
        """
        if batch is None:
            if len(self._plans) != 1:
                raise ValueError(
                    f"program has {len(self._plans)} cached batch sizes "
                    f"{self.batch_sizes}; pass stats(batch=...)"
                )
            batch = next(iter(self._plans))
        if batch not in self._plans:
            raise KeyError(f"batch {batch} not lowered; cached: {self.batch_sizes}")
        prepared = self._plans[batch]
        out = {
            node.name: kind_for(node.spec).stats(prepared[node.name], node.spec, batch)
            for node in self.nodes
        }
        for name, ov in self.overrides.items():
            out[name]["override"] = dict(ov)
        if sample is not None:
            if sample.shape[0] != batch:
                raise ValueError(
                    f"sample batch {sample.shape[0]} != stats batch {batch}"
                )
            collected: dict = {}
            run_prepared(
                self.nodes,
                self.params,
                prepared,
                sample,
                act_threshold=self.cfg.act_threshold,
                slot_mask=slot_mask,
                interpret=interpret,
                collect=collected,
            )
            for node in self.nodes:
                rs = getattr(kind_for(node.spec), "runtime_stats", None)
                if rs is not None and node.name in collected:
                    out[node.name].update(
                        rs(prepared[node.name], collected[node.name])
                    )
        return out

    # -- persistence ---------------------------------------------------------
    def save(self, path: str) -> str:
        """Persist config + params + every cached plan (packed payloads,
        queues, masks) atomically under ``path``.  Returns ``path``."""
        arrays: dict[str, np.ndarray] = {}
        plan_meta: dict[str, dict] = {}
        memo: dict = {}  # dedupe batch-invariant payloads across batch plans
        for b, prepared in self._plans.items():
            plan_meta[str(b)] = {
                name: serialize.pack(plan, f"plans/{b}/{name}", arrays, memo)
                for name, plan in prepared.items()
            }
        params_meta = {
            key: serialize.pack(np.asarray(leaf), f"params/{key}", arrays, memo)
            for key, leaf in _flatten_params(self.params).items()
        }
        meta = {
            "format": _FORMAT_VERSION,
            "cfg": serialize.pack_config(self.cfg),
            "overrides": {k: dict(v) for k, v in self.overrides.items()},
            "layers": [
                {"type": type(l).__name__, "fields": dataclasses.asdict(l)}
                for l in self.layers
            ],
            "plans": plan_meta,
            "params": params_meta,
        }
        from repro import verify as _verify

        # Content stamp (DESIGN.md §13): hashes the metadata plus every
        # payload array, so load can reject bit-rot / truncation with a
        # named rule before any plan is trusted.  Deterministic, so the
        # recorder-attached-vs-plain byte-identity contract is preserved.
        meta["verify"] = {
            "schema": _verify.VERIFY_SCHEMA,
            "fingerprint": _verify.artifact_fingerprint(meta, arrays),
        }
        CheckpointManager(path, keep=1).save(0, arrays, extra=meta)
        return path

    @classmethod
    def load(cls, path: str, *, verify=True) -> "PhantomProgram":
        """Rebuild a saved program in a fresh process — no re-lowering: the
        plan cache is restored verbatim and :attr:`lowerings` stays 0.

        ``verify`` picks the tier (DESIGN.md §13):

        * ``True`` (default) — the fast tier: stamp-schema check plus every
          rule whose cost is independent of queue length (version, read
          consistency, graph/mask-flow, overrides, geometry, partition,
          gauges).  Payload bit-rot is already caught during the read
          itself — the npz container checksums every member — so this tier
          stays within the <5% load-overhead budget ``kernel_bench``
          enforces.
        * ``"full"`` — everything: the sha256 content fingerprint
          round-trip plus the per-step queue scans (step classes, run
          structure, coverage, bounds, inert tail, compaction-meta
          re-derivation).  Used by ``python -m repro.verify`` and the
          corruption test suite; cost is O(artifact bytes + steps).
        * ``False`` — format-version check only; an artifact from a
          different schema is still rejected with ``artifact/version``
          (it cannot be deserialised meaningfully at all).

        Violations raise :class:`~repro.verify.VerifyError` naming the
        failing rule, layer and batch.
        """
        from repro import verify as _verify

        deep = verify == "full"

        try:
            arrays, meta = CheckpointManager(path).restore_flat()
        except FileNotFoundError:
            raise  # "no checkpoint here" is not a corruption finding
        except Exception as e:
            raise _verify.VerifyError(
                [_verify.Finding("artifact/read", f"checkpoint unreadable: {e}")],
                path=path,
            ) from e
        if meta.get("format") != _FORMAT_VERSION:
            raise _verify.VerifyError(
                [_verify.Finding(
                    "artifact/version",
                    f"unsupported program format: {meta.get('format')!r} "
                    f"(this build reads schema version {_FORMAT_VERSION})",
                )],
                path=path,
            )
        if verify:
            stamp = meta.get("verify")
            if not isinstance(stamp, dict) or stamp.get("schema") != _verify.VERIFY_SCHEMA:
                raise _verify.VerifyError(
                    [_verify.Finding(
                        "artifact/version",
                        f"verification stamp missing or from another schema "
                        f"({stamp!r}; this build checks verify schema "
                        f"{_verify.VERIFY_SCHEMA}) — re-save the program",
                    )],
                    path=path,
                )
            if deep:
                want = stamp.get("fingerprint")
                got = _verify.artifact_fingerprint(meta, arrays)
                if got != want:
                    raise _verify.VerifyError(
                        [_verify.Finding(
                            "artifact/fingerprint",
                            f"content fingerprint mismatch: stamped {want!r}, "
                            f"recomputed {got!r} — metadata or payload arrays "
                            f"changed since save",
                        )],
                        path=path,
                    )
        try:
            cfg = serialize.unpack_config(meta["cfg"])
            layers = [
                _build_spec(spec_class(entry["type"]), entry["fields"])
                for entry in meta["layers"]
            ]
            params: dict = {}
            for key, node in meta["params"].items():
                tree = params
                parts = key.split("/")
                for p in parts[:-1]:
                    tree = tree.setdefault(p, {})
                tree[parts[-1]] = jnp.asarray(serialize.unpack(node, arrays))
            prog = cls(layers, params, cfg, overrides=meta.get("overrides"))
            for b_str, per_layer in meta["plans"].items():
                prog._plans[int(b_str)] = {
                    name: serialize.unpack(node, arrays)
                    for name, node in per_layer.items()
                }
        except KeyError as e:
            # A metadata node pointing at a payload array that is not in
            # the npz (or a missing metadata section) used to surface as a
            # raw KeyError deep in serialize.unpack.
            raise _verify.VerifyError(
                [_verify.Finding(
                    "artifact/read",
                    f"serialized metadata references missing node/array "
                    f"{e.args[0] if e.args else e!r} — artifact truncated "
                    f"or metadata out of sync with arrays.npz",
                )],
                path=path,
            ) from e
        prog.lowerings = 0
        prog.verify = bool(verify)
        if verify:
            _verify.verify_program(prog, path=path, deep=deep)
        return prog


def _normalize_overrides(overrides, layers, cfg: PhantomConfig) -> dict:
    """Validated, normalised per-layer override diffs.

    Every diff must name a real layer and only real :class:`PhantomConfig`
    fields (checked by resolving it through ``with_overrides``); ``block``
    lists from JSON become tuples so a save→load round trip is
    value-identical; empty diffs are dropped.  Stored sorted by layer name
    so two programs with the same tunings serialise identically regardless
    of how the dict was assembled.
    """
    if not overrides:
        return {}
    names = {spec.name for spec in layers}
    unknown = sorted(set(overrides) - names)
    if unknown:
        raise KeyError(
            f"config override(s) for unknown layer(s) {unknown}; "
            f"layers: {sorted(names)}"
        )
    out: dict[str, dict] = {}
    for name in sorted(overrides):
        ov = dict(overrides[name])
        cfg.with_overrides(**ov)  # raises on unknown/invalid fields
        if ov.get("block") is not None and "block" in ov:
            ov["block"] = tuple(ov["block"])
        if ov:
            out[name] = ov
    return out


def _wants_tuple(hint) -> bool:
    """True when a spec field annotated ``hint`` stores a tuple (including
    ``Optional[tuple]``/union members) — only those JSON lists are converted
    back, so genuinely list-typed fields round-trip with equal types."""
    if hint is tuple or typing.get_origin(hint) is tuple:
        return True
    if typing.get_origin(hint) in (typing.Union, types.UnionType):
        return any(_wants_tuple(a) for a in typing.get_args(hint))
    return False


def _build_spec(cls, fields: dict):
    """Rebuild a layer spec from its JSON fields, restoring container types
    from the dataclass annotations (JSON turns every tuple into a list; a
    blanket list→tuple conversion would corrupt list-typed fields).  Specs
    whose annotations cannot be resolved at runtime (TYPE_CHECKING-only or
    function-local names under PEP 563) fall back to the blanket coercion —
    load must not crash on a spec that saved fine."""
    try:
        hints = typing.get_type_hints(cls)
    except Exception:
        hints = {}
    kw = {
        k: tuple(v) if isinstance(v, list) and _wants_tuple(hints.get(k, tuple)) else v
        for k, v in fields.items()
    }
    return cls(**kw)


def compile(
    layers,
    params,
    cfg: PhantomConfig | None = None,
    *,
    batch: int | tuple[int, ...] = 1,
    recorder=None,
    overrides: dict | None = None,
    tune: str = "off",
    tune_cache=None,
    verify: bool = True,
) -> PhantomProgram:
    """Compile a network onto the Phantom core: one weight-load-time pass
    per batch size, reused for every inference.

    ``layers``: spec list (:class:`~repro.core.dataflow.ConvSpec` /
    :class:`~repro.core.dataflow.FCSpec` / any registered spec type);
    ``params``: ``{layer name: {"w": ..., "b": ...}}`` pytree (prune first —
    zero tiles never enter the queues); ``cfg``: the one knob surface
    (:class:`~repro.core.phantom_linear.PhantomConfig`), defaulting to
    :data:`SERVE_DEFAULT`; ``batch``: size(s) to pre-lower (more are lowered
    lazily by :meth:`PhantomProgram.at_batch`); ``recorder``: an optional
    :class:`repro.obs.Recorder` metrics sink — lowering, per-call and
    per-layer timing land there (DESIGN.md §11; never serialised by
    :meth:`PhantomProgram.save`).

    Autotuning (DESIGN.md §12): ``overrides`` is an explicit per-layer
    partial-config dict (``{layer name: {field: value}}``); ``tune`` selects
    the :mod:`repro.tune` integration —

    * ``"off"``   (default) — no tuner involvement;
    * ``"cached"`` — consult the persistent tune cache only; cache misses
      fall back to the base config and **zero searches run** (asserted by
      CI on ``TuneCache.searches``), so compile latency stays flat;
    * ``"search"`` — cache misses trigger the cost-model search and the
      winners are persisted for the next compile.

    ``tune_cache`` is a :class:`repro.tune.TuneCache` instance (lets callers
    inspect hit/search counters) or a path for one (default
    ``checkpoint/tune_cache.json``).  Tuning keys off the *first* batch
    size; explicit ``overrides`` win over tuned ones per layer.

    ``verify`` (default True, DESIGN.md §13): statically verify the node
    graph / overrides once up front and every lowered plan as it is built;
    violations raise :class:`~repro.verify.VerifyError` naming the rule and
    layer.  The returned program keeps verifying future ``at_batch``
    lowerings until ``prog.verify`` is cleared.
    """
    if tune not in ("off", "cached", "search"):
        raise ValueError(
            f"tune must be 'off', 'cached' or 'search', got {tune!r}"
        )
    cfg = cfg or SERVE_DEFAULT
    merged = dict(overrides or {})
    if tune != "off":
        # Deferred import: the program layer must stay importable (and
        # cycle-free) without the tuner, and vice versa.
        from repro.tune import TuneCache, tune_overrides
        if isinstance(tune_cache, TuneCache):
            cache = tune_cache
        elif tune_cache is None:
            cache = TuneCache()
        else:
            cache = TuneCache(tune_cache)
        first_batch = batch if isinstance(batch, int) else tuple(batch)[0]
        tuned = tune_overrides(
            layers,
            params,
            first_batch,
            cfg,
            cache=cache,
            mode="cached" if tune == "cached" else "search",
            recorder=recorder,
        )
        for name, ov in tuned.items():
            merged.setdefault(name, ov)
    prog = PhantomProgram(
        layers, params, cfg, overrides=merged, recorder=recorder
    )
    prog.verify = bool(verify)
    if verify:
        from repro import verify as _verify

        # Graph-level rules once, before any lowering; per-batch plan rules
        # run inside at_batch as each plan is built.
        _verify.verify_program(prog, batches=(), graph=True)
    for b in (batch,) if isinstance(batch, int) else tuple(batch):
        prog.at_batch(b)
    return prog


# -- deprecation plumbing for the pre-program entry points -------------------

_WARNED: set[str] = set()


def warn_deprecated(name: str, instead: str):
    """Emit a :class:`DeprecationWarning` for ``name`` exactly once per
    process (deterministic, independent of the warnings-filter registry)."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use {instead} (see DESIGN.md §8)",
        DeprecationWarning,
        stacklevel=3,
    )


def reset_deprecation_warnings():
    """Testing hook: re-arm the once-per-process deprecation warnings."""
    _WARNED.clear()
