"""Built-in layer kinds (conv, FC) and the generic graph walk.

This is the code that replaced the hand-written conv-vs-FC branching in
``models/cnn.cnn_forward_phantom`` and its second, divergent copy in
``serve/cnn.py``: dispatch is a registry lookup, §3.8 mask threading and
τ-at-producer semantics live in exactly one place (:func:`run_prepared`),
and the inter-layer pooling/flatten/GAP plumbing is *declarative* — each
:class:`LayerNode` carries the glue ops the compile-time shape walk
(:func:`build_nodes`) decided it needs, so the runtime walk never inspects
shapes or spec fields.

Glue ops (all mask-preserving, DESIGN.md §4):

* ``maxpool2`` — 2×2 max-pool; max-pool keeps element masks exact because
  post-ReLU values are ≥ 0 (``maxpool(x) > τ ⇔ any(window > τ)``);
* ``flatten`` — ``[B, h, w, C] → [B, h·w·C]`` on values and mask alike;
* ``gap``     — global average pool; averaging mixes channels, so the mask
  is *re-encoded* from the pooled values — with the producer's τ, the same
  rule every other producer uses (the old forward used ``x != 0`` here,
  which silently dropped τ at exactly one point in the network).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import ConvSpec, FCSpec
from repro.kernels import ops, phantom_conv

from .registry import kind_for, register_layer_kind

__all__ = [
    "LayerNode",
    "build_nodes",
    "run_prepared",
    "ConvKind",
    "FCKind",
    "GLUE",
    "multicore_stats",
]


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


# -- declarative inter-layer glue -------------------------------------------
# Each op: (x, mask, tau) -> (x, mask).  mask is the producing layer's
# element mask (float 0/1, same layout as x) or None before the first layer.


def _glue_maxpool2(x, mask, tau):
    x = _maxpool2(x)
    if mask is not None:
        mask = _maxpool2(mask.astype(x.dtype))
    return x, mask


def _glue_flatten(x, mask, tau):
    x = x.reshape(x.shape[0], -1)
    if mask is not None:
        mask = mask.reshape(mask.shape[0], -1)
    return x, mask


def _glue_gap(x, mask, tau):
    x = x.mean(axis=(1, 2))
    # Re-encode with the producer's τ — averaging mixes channels, so the
    # incoming mask no longer describes x (satellite fix: was ``x != 0``).
    return x, (x > tau).astype(x.dtype)


GLUE = {"maxpool2": _glue_maxpool2, "flatten": _glue_flatten, "gap": _glue_gap}


def multicore_stats(art) -> dict:
    """Per-core scheduling stats for a multi-core artifact (DESIGN.md §9).

    ``per_core_work`` is each core's MAC-step count (m-tiles × the Σ of its
    columns' weight-mask popcounts) — the engine-side counterpart of
    :func:`repro.core.balance.inter_core_schedule` finish times on the same
    per-column costs (asserted equal in the multi-core test grid);
    ``makespan`` is the padded per-core queue length the grid actually
    executes (MAC steps + §3.8 zero-writes + column-slot padding);
    ``imbalance`` is max/mean of per-core work, the §4.2 metric.
    """
    if getattr(art, "cores", 1) <= 1:
        return {}
    mt = art.grid_tiles[0]
    work = np.asarray(art.core_cost, dtype=np.int64) * mt
    mean = float(work.mean())
    return {
        "cores": art.cores,
        "per_core_steps": [int(s) for s in art.core_steps],
        "per_core_work": [int(w) for w in work],
        "makespan": int(art.core_steps.max()),
        "imbalance": float(work.max() / mean) if mean > 0 else 1.0,
    }


# -- built-in kinds ----------------------------------------------------------


class ConvKind:
    """Conv2D through either Phantom lowering (direct default, DESIGN.md §3)."""

    name = "conv"

    def prepare(self, spec: ConvSpec, params, batch: int, cfg):
        return phantom_conv.prepare_conv_weight(
            np.asarray(params["w"]),
            batch=batch,
            in_hw=(spec.in_h, spec.in_w),
            stride=spec.stride,
            padding=spec.pad,
            groups=spec.in_ch if spec.depthwise else 1,
            config=cfg,
        )

    def apply(self, x, plan, params, *, mask, act_threshold, interpret):
        y = phantom_conv.phantom_conv_call(
            x,
            plan,
            x_mask=mask,
            act_threshold=act_threshold,
            interpret=interpret,
        )
        return y + params["b"]

    def mask_out(self, x, act_threshold):
        return (x > act_threshold).astype(x.dtype)

    def tune_signature(self, spec: ConvSpec, batch: int) -> str:
        """Tune-cache signature (DESIGN.md §12): the geometry that shapes
        the candidate cost landscape — identically-shaped convs share
        tunings regardless of their display names."""
        oh, ow = spec.out_hw
        return (
            f"conv[{spec.in_ch}x{spec.in_h}x{spec.in_w}->{spec.out_ch}x{oh}x{ow}"
            f",k{spec.kh}x{spec.kw},s{spec.stride[0]}x{spec.stride[1]}"
            f"{',dw' if spec.depthwise else ''},pad={spec.pad}]@b{batch}"
        )

    def tile_bits(self, x, plan, *, mask, act_threshold):
        """The [Mt, Kt] activation tile bits :meth:`apply` would gate on —
        recomputed host-visibly so :meth:`runtime_stats` can account the
        executed grid without re-running the kernel (DESIGN.md §10)."""
        if plan.mode == "direct":
            return phantom_conv.direct_conv_tile_bits(
                x if mask is None else mask, plan, act_threshold
            )
        if mask is not None:
            return phantom_conv.conv_patch_tile_bits(mask, plan, act_threshold)
        patches = phantom_conv.im2col_patches(
            x, plan.kh, plan.kw, plan.stride, plan.padding
        )
        bm, bk, _ = plan.pw.block
        return ops.activation_tile_bits(
            ops._pad2(patches, bm, bk), (bm, bk), act_threshold
        )

    def runtime_stats(self, plan, tile_bits) -> dict:
        art = plan.pw if plan.pw is not None else plan.plan
        return ops.lookahead_stats(art, tile_bits)

    def stats(self, plan, spec: ConvSpec, batch: int) -> dict:
        art = plan.pw if plan.pw is not None else plan.plan
        mt, kt, nt = art.grid_tiles
        oh, ow = plan.out_hw
        w_nnz = int(np.count_nonzero(np.asarray(art.packed)))
        return {
            "kind": self.name,
            "mode": plan.mode,
            "steps": plan.steps,
            "dense_steps": mt * kt * nt,
            "density": plan.density(),
            "lookahead": getattr(art, "lookahead", 0),
            # Weight-effectual MACs at dense activations: M output positions
            # × nonzero weights.  The simulator's layer_work counts the same
            # quantity per-mask (DESIGN.md §5); dynamic activation gating is
            # a runtime subtraction on top.
            "valid_macs": batch * oh * ow * w_nnz,
            "dense_macs": batch * spec.macs,
            **multicore_stats(art),
        }


class FCKind:
    """Fully-connected layer through the two-sided block-sparse matmul."""

    name = "fc"

    def prepare(self, spec: FCSpec, params, batch: int, cfg):
        return ops.prepare_weight(np.asarray(params["w"]), m=batch, config=cfg)

    def apply(self, x, plan, params, *, mask, act_threshold, interpret):
        bm, bk, _ = plan.block
        bits = None if mask is None else ops.element_mask_tile_bits(mask, (bm, bk))
        y = ops.phantom_matmul(
            x,
            plan,
            act_bits=bits,
            act_threshold=act_threshold,
            interpret=interpret,
        )
        return y + params["b"]

    def mask_out(self, x, act_threshold):
        return (x > act_threshold).astype(x.dtype)

    def tune_signature(self, spec: FCSpec, batch: int) -> str:
        """Tune-cache signature: the matmul shape alone — the inter-layer
        pooling glue (``pool``) never changes this layer's own schedule."""
        return f"fc[{spec.in_dim}->{spec.out_dim}]@b{batch}"

    def tile_bits(self, x, plan, *, mask, act_threshold):
        """See :meth:`ConvKind.tile_bits` — same contract for FC layers."""
        bm, bk, _ = plan.block
        if mask is not None:
            return ops.element_mask_tile_bits(mask, (bm, bk))
        x2 = x.reshape(-1, plan.shape[0])
        return ops.activation_tile_bits(
            ops._pad2(x2, bm, bk), (bm, bk), act_threshold
        )

    def runtime_stats(self, plan, tile_bits) -> dict:
        return ops.lookahead_stats(plan, tile_bits)

    def stats(self, plan, spec: FCSpec, batch: int) -> dict:
        mt, kt, nt = plan.grid_tiles
        w_nnz = int(np.count_nonzero(np.asarray(plan.packed)))
        return {
            "kind": self.name,
            "steps": plan.steps,
            "dense_steps": mt * kt * nt,
            "density": plan.density(),
            "lookahead": getattr(plan, "lookahead", 0),
            "valid_macs": batch * w_nnz,
            "dense_macs": batch * spec.macs,
            **multicore_stats(plan),
        }


register_layer_kind(ConvSpec, ConvKind())
register_layer_kind(FCSpec, FCKind())


# -- compile-time graph construction ----------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerNode:
    """One compiled layer: spec + the declarative glue before it (the kind
    is resolved from ``spec``'s type via the registry at use sites).

    ``activation`` is the epilogue the walk applies after ``kind.apply``
    (the last layer's logits stay linear — decided here, at compile time,
    by position in ``layers``, never by dict order).

    ``cfg`` is this node's *effective* :class:`PhantomConfig` when it
    differs from the program's base config (``None`` = use the base) — the
    resolved form of the autotuner's / caller's per-layer override diff
    (DESIGN.md §12).  ``prepare`` must lower with it, which is why it lives
    on the node: the runtime walk and plan cache stay override-agnostic.
    """

    name: str
    spec: Any
    pre: tuple[str, ...]
    activation: str  # "relu" | "none"
    cfg: Any = None  # PhantomConfig | None


def build_nodes(layers, cfg=None, overrides=None) -> tuple[LayerNode, ...]:
    """Shape-walk the layer list once and emit the node sequence.

    All glue decisions (inter-conv max-pool, pool5, GAP, flatten) are made
    here from static spec geometry, so :func:`run_prepared` is a pure
    dispatch loop.  Raises at compile time on geometry the old forwards
    would only have crashed on at trace time.

    ``overrides`` (``{layer name: partial PhantomConfig field dict}``)
    resolves each named layer's effective config against the base ``cfg``
    via :meth:`PhantomConfig.with_overrides`; an override naming a layer
    not in ``layers`` is a compile-time error (a silently-ignored tuning
    would defeat the never-worse guarantee).
    """
    if not layers:
        raise ValueError("cannot compile an empty layer list")
    overrides = dict(overrides or {})
    if overrides and cfg is None:
        raise ValueError("build_nodes(overrides=...) requires the base cfg")
    unknown = sorted(set(overrides) - {spec.name for spec in layers})
    if unknown:
        raise KeyError(
            f"config override(s) for unknown layer(s) {unknown}; "
            f"layers: {[spec.name for spec in layers]}"
        )
    nodes = []
    spatial = isinstance(layers[0], ConvSpec)
    hw = layers[0].in_h if spatial else None
    last = len(layers) - 1
    for i, spec in enumerate(layers):
        kind_for(spec)  # raises early for unregistered spec types
        pre: list[str] = []
        if isinstance(spec, ConvSpec):
            if not spatial:
                raise ValueError(f"conv layer {spec.name!r} after a flattening layer")
            if spec.in_h != hw:
                if hw // 2 == spec.in_h:
                    pre.append("maxpool2")
                    hw //= 2
                else:
                    raise ValueError(
                        f"layer {spec.name!r} expects H={spec.in_h}, got H={hw} "
                        f"(only 2x max-pool bridging is supported)"
                    )
            hw = spec.out_hw[0]
            activation = "relu"
        else:
            if spatial:
                pool = getattr(spec, "pool", "flatten")
                if pool == "gap":
                    pre.append("gap")
                else:
                    if pool == "pool5" and hw > 1:
                        pre.append("maxpool2")
                    pre.append("flatten")
                spatial = False
            activation = "relu" if i < last else "none"
        ov = overrides.get(spec.name)
        nodes.append(
            LayerNode(
                name=spec.name,
                spec=spec,
                pre=tuple(pre),
                activation=activation,
                cfg=cfg.with_overrides(**ov) if ov else None,
            )
        )
    return tuple(nodes)


# -- the generic runtime walk ------------------------------------------------


def run_prepared(
    nodes: tuple[LayerNode, ...],
    params,
    prepared: dict,
    x: jnp.ndarray,
    *,
    act_threshold: float = 0.0,
    slot_mask: jnp.ndarray | None = None,
    interpret: bool | None = None,
    collect: dict | None = None,
    recorder=None,
) -> jnp.ndarray:
    """Run a compiled node sequence over prepared artifacts.

    §3.8 semantics in one place: the *producer* applies the (lossy) τ when
    it emits its element mask; consumers gate on that mask's exact zeros,
    so only the first layer (no mask yet) thresholds raw values.
    ``slot_mask`` (float [B], 1 = live) re-zeroes padded batch slots after
    every activation so their flowing masks keep gating their tiles
    (DESIGN.md §4) — without it, ``relu(0 + b)`` lights dead slots up from
    layer 2 on.

    ``collect`` (a dict, mutated in place) gathers each layer's activation
    tile bits — the same bits the kernel call gates/compacts on — keyed by
    node name, for :meth:`PhantomProgram.stats`'s runtime accounting
    (DESIGN.md §10).  Kinds without a ``tile_bits`` method are skipped.

    ``recorder`` (a :class:`repro.obs.Recorder`) wraps each node — its glue
    ops, kernel call and activation epilogue — in one ``layer/<name>`` span,
    blocking on the layer's output inside the span so async dispatch cannot
    attribute one layer's work to the next (DESIGN.md §11).  Exactly one
    span per node per call: the trace's per-layer span count equals the
    program's layer count.
    """
    mask = None
    for node in nodes:
        kind = kind_for(node.spec)
        cm = (
            recorder.span(f"layer/{node.name}", kind=kind.name)
            if recorder is not None
            else contextlib.nullcontext()
        )
        with cm:
            for g in node.pre:
                x, mask = GLUE[g](x, mask, act_threshold)
            eff_tau = 0.0 if mask is not None else act_threshold
            if collect is not None:
                tb = getattr(kind, "tile_bits", None)
                if tb is not None:
                    collect[node.name] = np.asarray(
                        tb(x, prepared[node.name], mask=mask, act_threshold=eff_tau)
                    )
            y = kind.apply(
                x,
                prepared[node.name],
                params[node.name],
                mask=mask,
                act_threshold=eff_tau,
                interpret=interpret,
            )
            if node.activation == "relu":
                x = jax.nn.relu(y)
                if slot_mask is not None:
                    x = x * slot_mask.reshape((-1,) + (1,) * (x.ndim - 1))
                mask = kind.mask_out(x, act_threshold)
            else:
                x = y
            if recorder is not None:
                x = jax.block_until_ready(x)
    return x
