"""Plan-artifact (de)serialization for :meth:`PhantomProgram.save` / ``load``.

Plans are plain dataclasses of arrays + static metadata (``PhantomWeight``,
``PhantomConvWeight``, ``DirectConvPlan``) — or dicts of them (the FFN kind).
``pack`` walks that structure generically: arrays land in a flat
``{path: np.ndarray}`` dict (stored through the atomic
:mod:`repro.checkpoint` writer), everything else lands in a JSON-able
metadata tree that mirrors the structure, so ``unpack`` can rebuild the
exact dataclasses in a fresh process without re-running weight-load-time
lowering.

Dataclass types referenced from metadata must be registered here
(``register_plan_class``); the built-ins are pre-registered.
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["pack", "unpack", "pack_config", "unpack_config", "register_plan_class"]

_PLAN_CLASSES: dict[str, type] = {}


def register_plan_class(cls: type) -> type:
    _PLAN_CLASSES[cls.__name__] = cls
    return cls


def _register_builtins():
    from repro.kernels.ops import PhantomWeight
    from repro.kernels.phantom_conv import DirectConvPlan, PhantomConvWeight

    for cls in (PhantomWeight, PhantomConvWeight, DirectConvPlan):
        register_plan_class(cls)


def pack_config(cfg) -> dict:
    """:class:`~repro.core.phantom_linear.PhantomConfig` → JSON-able dict.

    JSON turns the ``block`` tuple into a list; :func:`unpack_config` is the
    inverse that restores it, so configs — and the per-node override diffs
    ``PhantomProgram`` saves next to them — round-trip with equal types.
    """
    return dataclasses.asdict(cfg)


def unpack_config(d: dict):
    """Inverse of :func:`pack_config` (also accepts partial override dicts
    via ``PhantomConfig.with_overrides`` at the call site — this function is
    only for full configs)."""
    from repro.core.phantom_linear import PhantomConfig

    d = dict(d)
    if d.get("block") is not None:
        d["block"] = tuple(d["block"])
    return PhantomConfig(**d)


def pack(obj, path: str, arrays: dict, memo: dict | None = None) -> dict:
    """Serialize ``obj``: arrays appended to ``arrays`` under ``path``-rooted
    keys, returns the JSON-able metadata node describing ``obj``.

    ``memo`` (content digest → stored path) deduplicates identical arrays
    across calls sharing it — batch-invariant payloads (packed weights,
    weight masks) are stored once even when several batch-size plans
    reference them.
    """
    if obj is None:
        return {"t": "none"}
    if isinstance(obj, jax.Array) or isinstance(obj, np.ndarray):
        d = np.asarray(obj)
        node = {"t": "arr", "path": path, "jnp": isinstance(obj, jax.Array)}
        if d.dtype.kind not in "?biufc":
            # Extension dtypes (bfloat16 & friends) silently degrade to raw
            # void in npz — store a byte view + the dtype/shape to rebuild.
            node["dtype"] = str(d.dtype)
            node["shape"] = list(d.shape)
            d = np.ascontiguousarray(d).view(np.uint8)
        if memo is not None:
            key = (hashlib.sha256(np.ascontiguousarray(d).tobytes()).hexdigest(),
                   str(d.dtype), d.shape)
            if key in memo:
                node["path"] = memo[key]
                return node
            memo[key] = path
        arrays[path] = d
        return node
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        cls_name = type(obj).__name__
        if cls_name not in _PLAN_CLASSES:
            _register_builtins()
        if cls_name not in _PLAN_CLASSES:
            raise TypeError(
                f"cannot serialize plan dataclass {cls_name}: register it "
                f"with repro.program.serialize.register_plan_class"
            )
        fields = {
            f.name: pack(getattr(obj, f.name), f"{path}/{f.name}", arrays, memo)
            for f in dataclasses.fields(obj)
        }
        return {"t": "dc", "cls": cls_name, "fields": fields}
    if isinstance(obj, dict):
        return {
            "t": "dict",
            "items": {k: pack(v, f"{path}/{k}", arrays, memo) for k, v in obj.items()},
        }
    if isinstance(obj, (tuple, list)):
        if not all(isinstance(v, (int, float, str, bool)) for v in obj):
            raise TypeError(f"cannot serialize nested sequence at {path}")
        return {"t": "tuple", "v": list(obj)}
    if isinstance(obj, (bool, str)):
        return {"t": "s", "v": obj}
    if isinstance(obj, (int, float, np.integer, np.floating)):
        return {"t": "s", "v": obj.item() if isinstance(obj, np.generic) else obj}
    raise TypeError(f"cannot serialize {type(obj).__name__} at {path}")


def unpack(node: dict, arrays: dict):
    """Inverse of :func:`pack` over the same ``arrays`` dict."""
    t = node["t"]
    if t == "none":
        return None
    if t == "arr":
        a = arrays[node["path"]]
        if "dtype" in node:  # byte view of an extension dtype (see pack)
            a = a.view(jnp.dtype(node["dtype"])).reshape(node["shape"])
        return jnp.asarray(a) if node["jnp"] else a
    if t == "dc":
        _register_builtins()
        cls = _PLAN_CLASSES[node["cls"]]
        kwargs = {k: unpack(v, arrays) for k, v in node["fields"].items()}
        return cls(**kwargs)
    if t == "dict":
        return {k: unpack(v, arrays) for k, v in node["items"].items()}
    if t == "tuple":
        return tuple(node["v"])
    if t == "s":
        return node["v"]
    raise ValueError(f"unknown metadata node type {t!r}")
