"""Layer-kind registry: the extension point of the program API (DESIGN.md §8).

A *layer kind* teaches :class:`repro.program.PhantomProgram` how to run one
spec type on the Phantom core.  The protocol is deliberately small — four
methods, all shape-static — so adding a new Phantom-eligible layer family
(e.g. the FFN path in :mod:`repro.models.layers`) is one
:func:`register_layer_kind` call, not an edit to the forward loops:

* ``prepare(spec, params, batch, cfg) -> plan`` — weight-load-time lowering
  (pack payloads, build queues) for a fixed batch size;
* ``apply(x, plan, params, *, mask, act_threshold, interpret) -> y`` — the
  runtime call (bias included, activation NOT included: the program's graph
  walk owns the epilogue so the last-layer rule lives in one place);
* ``mask_out(x, act_threshold) -> mask`` — the §3.8 output encoding the
  *producer* emits once for downstream consumers (τ applied here, at the
  producer — the rule every kind shares, including the GAP re-encode glue);
* ``stats(plan, spec, batch) -> dict`` — steps / density / valid_macs for
  the engine↔simulator consistency contract (DESIGN.md §5).

Kinds may additionally define ``tune_signature(spec, batch) -> str``
(optional, DESIGN.md §12): the geometry part of the autotuner's cache key.
Defining it lets identically-shaped layers share cached tunings regardless
of display-name / cosmetic spec fields; kinds without it fall back to the
spec's full dataclass-field dump (always correct, occasionally
over-specific).

Registration is keyed by the spec *type* (e.g.
:class:`repro.core.dataflow.ConvSpec`); the class-name index lets
:meth:`PhantomProgram.load` reconstruct specs in a fresh process.  Spec
types must be **dataclasses of JSON-able fields** — that is what
``PhantomProgram.save``/``load`` (de)serialize them through.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

__all__ = ["LayerKind", "register_layer_kind", "kind_for", "spec_class"]


@runtime_checkable
class LayerKind(Protocol):
    """Protocol every registered layer kind implements."""

    name: str

    def prepare(self, spec, params, batch: int, cfg) -> Any: ...

    def apply(self, x, plan, params, *, mask, act_threshold: float, interpret): ...

    def mask_out(self, x, act_threshold: float): ...

    def stats(self, plan, spec, batch: int) -> dict: ...


_KINDS: dict[type, LayerKind] = {}  # spec type -> kind
_SPEC_BY_NAME: dict[str, type] = {}  # spec class name -> spec type (for load)


def register_layer_kind(spec_cls: type, kind: LayerKind) -> LayerKind:
    """Register ``kind`` as the executor for layers of type ``spec_cls``.

    Returns ``kind`` so it can be used as a decorator helper.  Re-registering
    a spec type replaces the previous kind (last one wins — lets tests swap
    instrumented kinds in).
    """
    if not dataclasses.is_dataclass(spec_cls):
        raise TypeError(
            f"{spec_cls.__name__} must be a dataclass: PhantomProgram.save "
            f"serializes specs via dataclasses.asdict"
        )
    _KINDS[spec_cls] = kind
    _SPEC_BY_NAME[spec_cls.__name__] = spec_cls
    return kind


def kind_for(spec) -> LayerKind:
    """The registered kind for ``spec``'s type (exact type match first, then
    MRO walk so spec subclasses inherit their base's kind)."""
    for cls in type(spec).__mro__:
        if cls in _KINDS:
            return _KINDS[cls]
    raise KeyError(
        f"no layer kind registered for {type(spec).__name__}; "
        f"register one with repro.program.register_layer_kind"
    )


def spec_class(name: str) -> type:
    """Spec type by class name (used by :meth:`PhantomProgram.load`); the
    defining module must have been imported so its registration ran."""
    try:
        return _SPEC_BY_NAME[name]
    except KeyError:
        raise KeyError(
            f"unknown layer spec {name!r}: import the module that registers "
            f"it before PhantomProgram.load"
        ) from None
