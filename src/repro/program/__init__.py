"""The program API: compile a network once, run/save/serve it anywhere.

``repro.program`` (aliased as the top-level ``phantom`` package) is the
single entry point to the Phantom core:

    import phantom
    prog = phantom.compile(layers, params, cfg, batch=8)
    logits = prog(x)                    # any pre-lowered batch size
    prog.save("ckpt/prog"); prog2 = phantom.PhantomProgram.load("ckpt/prog")

See DESIGN.md §8 for the compile/apply/save contract and the
:class:`~repro.program.registry.LayerKind` protocol that makes new layer
kinds a single registration.

Exports resolve lazily (PEP 562) so importing :mod:`repro.program.registry`
alone — e.g. from :mod:`repro.models.layers` to register a layer kind —
does not pull the Pallas kernel modules in; they load on first use of the
compile/run machinery (the built-in conv/FC kinds register when
:mod:`repro.program.plans` loads, which every such path imports).
"""
from repro.core.phantom_linear import PhantomConfig

__all__ = [
    "PhantomConfig",
    "PhantomProgram",
    "compile",
    "SERVE_DEFAULT",
    "LayerKind",
    "LayerNode",
    "register_layer_kind",
    "kind_for",
    "build_nodes",
    "run_prepared",
    "warn_deprecated",
    "reset_deprecation_warnings",
]

_LAZY = {
    "PhantomProgram": "program",
    "compile": "program",
    "SERVE_DEFAULT": "program",
    "warn_deprecated": "program",
    "reset_deprecation_warnings": "program",
    "LayerKind": "registry",
    "register_layer_kind": "registry",
    "kind_for": "registry",
    "LayerNode": "plans",
    "build_nodes": "plans",
    "run_prepared": "plans",
}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(f".{_LAZY[name]}", __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
