"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (device count is locked at first backend init, and
the dry-run needs to set XLA_FLAGS before that happens).

Mesh shapes: single pod = (16, 16) over ('data', 'model') — 256 chips of a
v5e pod; multi-pod = (2, 16, 16) over ('pod', 'data', 'model') — 512 chips.
The 'pod' axis only ever carries gradient all-reduce traffic (params are
FSDP'd within a pod), matching the slow cross-pod links.  An optional
'stage' axis prepends pipeline parallelism.
"""
from __future__ import annotations

__all__ = ["make_production_mesh", "make_mesh_shape"]


def make_mesh_shape(*, multi_pod: bool = False, pipeline_stages: int = 1):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if pipeline_stages > 1:
        # Stages take over the data axis: total chips stay fixed.
        shape = (pipeline_stages,) + shape[:-2] + (shape[-2] // pipeline_stages, shape[-1])
        axes = ("stage",) + axes
    return shape, axes


def make_production_mesh(*, multi_pod: bool = False, pipeline_stages: int = 1):
    from repro.parallel.sharding import compat_make_mesh

    shape, axes = make_mesh_shape(multi_pod=multi_pod, pipeline_stages=pipeline_stages)
    return compat_make_mesh(shape, axes)
