"""Serving driver: batched generation with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0p5b --smoke \
      --requests 6 --max-new 12 [--phantom]

``--phantom`` enables the paper's technique: FFN/o-proj weights block-pruned
to the configured density and executed through the masked block-sparse path.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.core.phantom_linear import PhantomConfig
from repro.models.registry import build
from repro.serve import ServeEngine


def phantomize(model, params, density: float, block=(8, 8)):
    """Apply block pruning to every Phantom-eligible weight (the stored
    ``wmask`` leaves) — serving-side model preparation."""
    from repro.core.sparsity import block_prune

    def visit(p):
        if isinstance(p, dict):
            if "wmask" in p and "w" in p:
                w = np.asarray(p["w"])
                flat = w.reshape(-1, w.shape[-1]) if w.ndim > 2 else w
                mask = block_prune(flat, density, block).reshape(w.shape)
                p["wmask"] = jax.numpy.asarray(mask.astype(np.asarray(p["w"]).dtype))
            for v in p.values():
                visit(v)

    visit(params)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0p5b", choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--phantom", action="store_true")
    ap.add_argument("--density", type=float, default=0.5)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    if args.phantom:
        cfg = dataclasses.replace(
            cfg,
            phantom=PhantomConfig(
                enabled=True, mode="masked", weight_density=args.density,
                block=(8, 8, 8),
            ),
        )
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.phantom:
        params = phantomize(model, params, args.density)

    eng = ServeEngine(model, params, batch_size=args.batch_size, max_len=args.max_len)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 12)).tolist()
        eng.submit(prompt, max_new_tokens=args.max_new)
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s){' [phantom]' if args.phantom else ''}")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4]} -> out[:8]={r.output[:8]}")


if __name__ == "__main__":
    main()
