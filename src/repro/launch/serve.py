"""Serving driver: batched generation with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0p5b --smoke \
      --requests 6 --max-new 12 [--phantom]

``--phantom`` enables the paper's technique: FFN/o-proj weights block-pruned
to the configured density and executed through the masked block-sparse path.

Fault-tolerant serving (DESIGN.md §14): ``--faults smoke`` (or an explicit
``transient_rate=0.2,latency_rate=0.1,...`` spec) runs the same workload
under a seeded :class:`repro.serve.FaultPlan` with a
:class:`repro.serve.ServePolicy` (deadlines/retries/degradation knobs via
``--deadline`` / ``--retries`` / ``--max-queue``).  A fault run is a *chaos
smoke*: the driver exits nonzero unless every request completed and at
least one retry actually fired (otherwise the run proved nothing), and
``--metrics-out`` writes the full recorder snapshot as JSON for the CI
artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.core.phantom_linear import PhantomConfig
from repro.models.registry import build
from repro.obs import Recorder
from repro.serve import FaultPlan, ServeEngine, ServePolicy


def phantomize(model, params, density: float, block=(8, 8)):
    """Apply block pruning to every Phantom-eligible weight (the stored
    ``wmask`` leaves) — serving-side model preparation."""
    from repro.core.sparsity import block_prune

    def visit(p):
        if isinstance(p, dict):
            if "wmask" in p and "w" in p:
                w = np.asarray(p["w"])
                flat = w.reshape(-1, w.shape[-1]) if w.ndim > 2 else w
                mask = block_prune(flat, density, block).reshape(w.shape)
                p["wmask"] = jax.numpy.asarray(mask.astype(np.asarray(p["w"]).dtype))
            for v in p.values():
                visit(v)

    visit(params)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0p5b", choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--phantom", action="store_true")
    ap.add_argument("--density", type=float, default=0.5)
    ap.add_argument("--faults", default="none",
                    help="fault plan: none | smoke | key=value,... "
                         "(FaultPlan fields, e.g. transient_rate=0.2)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the fault schedule and the prompt stream")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds (requires a policy "
                         "run, i.e. --faults or --max-queue)")
    ap.add_argument("--retries", type=int, default=8,
                    help="ServePolicy.max_retries for fault runs")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission queue (RejectedError beyond it)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the recorder metrics snapshot JSON here")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    if args.phantom:
        cfg = dataclasses.replace(
            cfg,
            phantom=PhantomConfig(
                enabled=True, mode="masked", weight_density=args.density,
                block=(8, 8, 8),
            ),
        )
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.phantom:
        params = phantomize(model, params, args.density)

    plan = FaultPlan.parse(args.faults, seed=args.seed)
    policy = None
    if plan is not None or args.max_queue is not None or args.deadline is not None:
        policy = ServePolicy(
            faults=plan,
            max_retries=args.retries,
            max_queue=args.max_queue,
            deadline_s=args.deadline,
        )
    rec = Recorder()
    eng = ServeEngine(
        model, params, batch_size=args.batch_size, max_len=args.max_len,
        recorder=rec, policy=policy,
    )
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 12)).tolist()
        reqs.append(eng.submit(prompt, max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s){' [phantom]' if args.phantom else ''}"
          f"{' [faults=' + args.faults + ']' if plan is not None else ''}")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4]} -> out[:8]={r.output[:8]}")

    if args.metrics_out:
        rec.to_json(args.metrics_out)
        print(f"metrics written to {args.metrics_out}")

    if plan is not None:
        # Chaos-smoke contract: the run only proves fault tolerance if
        # every request completed AND the schedule actually exercised the
        # retry path.  Either miss is a hard failure for CI.
        incomplete = [r.rid for r in reqs if not r.done]
        retries = int(rec.counters.get("serve/retries", 0))
        injected = int(sum(
            v for k, v in rec.counters.items()
            if k.startswith("serve/faults_injected")
        ))
        print(f"chaos: injected={injected} retries={retries} "
              f"degradations={int(rec.counters.get('serve/degradations', 0))} "
              f"deadline_missed={int(rec.counters.get('serve/deadline_missed', 0))} "
              f"incomplete={len(incomplete)}")
        if incomplete:
            print(f"FAIL: incomplete request rids {incomplete}", file=sys.stderr)
            sys.exit(1)
        if retries == 0:
            print("FAIL: fault run injected no retryable fault — raise the "
                  "rates or the request count; this run proved nothing",
                  file=sys.stderr)
            sys.exit(1)
        print("chaos smoke OK: zero incomplete requests, retry path exercised")


if __name__ == "__main__":
    main()
