"""Elastic scaling / failure-recovery demonstration (fault tolerance).

Simulates the production failure path on fake devices:

  1. train N steps on mesh A, checkpointing (atomic + async),
  2. "lose" devices — rebuild a *smaller* mesh B,
  3. restore the latest checkpoint **resharded** onto mesh B
     (``CheckpointManager.restore`` device_puts against the new shardings),
  4. resume training; the counter-based data pipeline skips ahead
     deterministically, so the loss curve continues exactly where it left
     off (verified against an uninterrupted run in tests/test_elastic.py).

  PYTHONPATH=src python -m repro.launch.elastic
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:  # fake an 8-device slice for the demo
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import shutil
import tempfile

import jax
import numpy as np

from repro import configs, optim
from repro.data import DataConfig, SyntheticTokens
from repro.models.registry import build
from repro.parallel import sharding as shd
from repro.train import TrainConfig, Trainer


def make_mesh(n_data: int, n_model: int):
    return shd.compat_make_mesh((n_data, n_model), ("data", "model"))


def run(arch: str = "smollm_360m", steps_a: int = 6, steps_b: int = 6, batch=8, seq=64):
    cfg = configs.get_smoke(arch)
    model = build(cfg)
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch))
    opt_cfg = optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps_a + steps_b)
    ckpt_dir = tempfile.mkdtemp(prefix="elastic_ckpt_")
    try:
        # Phase A: 8 devices (4×2).
        mesh_a = make_mesh(4, 2)
        tr_a = Trainer(model, data, opt_cfg, TrainConfig(ckpt_every=steps_a),
                       mesh=mesh_a, ckpt_dir=ckpt_dir)
        params, opt = tr_a.init_state()
        params, opt = tr_a.run(params, opt, steps_a)
        loss_a = tr_a.history[-1]["loss"]
        print(f"[phase A] {steps_a} steps on mesh {dict(mesh_a.shape)} "
              f"loss={loss_a:.4f}; checkpointed")

        # Phase B: node failure -> only 4 devices remain (2×2). Restore the
        # checkpoint RESHARDED onto the smaller mesh and resume.
        mesh_b = make_mesh(2, 2)
        tr_b = Trainer(model, data, opt_cfg, TrainConfig(), mesh=mesh_b,
                       ckpt_dir=ckpt_dir)
        aparams = model.abstract_params()
        pshard = shd.param_shardings(aparams, model.axes(), mesh_b)
        oshard = {"m": pshard, "v": pshard,
                  "step": jax.NamedSharding(mesh_b, jax.sharding.PartitionSpec())}
        state = tr_b.ckpt.restore(
            {"params": aparams, "opt": jax.eval_shape(optim.init_opt_state, aparams)},
            shardings={"params": pshard, "opt": oshard},
        )
        tr_b.start_step = int(np.asarray(state["opt"]["step"]))
        params_b, opt_b = tr_b.run(state["params"], state["opt"], steps_b)
        print(f"[phase B] resumed at step {tr_b.start_step} on mesh "
              f"{dict(mesh_b.shape)} loss={tr_b.history[-1]['loss']:.4f}")
        return tr_a.history, tr_b.history
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    run()
