import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × assigned input shape) cell, on the single-pod
16×16 mesh and the 2×16×16 multi-pod mesh:

  with mesh:
      lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
      compiled = lowered.compile()
      print(compiled.memory_analysis())   # proves it fits
      print(compiled.cost_analysis())     # FLOPs / bytes for §Roofline

``train_*`` shapes lower the full train step (fwd + bwd + AdamW update with
sharded optimizer state); ``prefill_*`` the forward; ``decode_*`` /
``long_*`` the one-token serve step against the full-depth cache.  Results
(memory, cost, roofline terms, collective schedule) are dumped as JSON for
EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2_0p5b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs, optim, roofline
from repro.configs import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.models.common import set_mesh_rules
from repro.models.registry import build
from repro.parallel import sharding as shd
from repro.train.trainer import TrainConfig, make_train_step


OPTIMIZED = {  # §Perf-winning knobs (see EXPERIMENTS.md); defaults stay
    "attn_impl": "chunked",  # paper-faithful-baseline without --optimized
    "moe_groups": 16,
    "embed_table_2d": False,
}


def build_step(arch: str, shape: str, mesh, compress_cross_pod: bool = False,
               cfg_override=None, optimized: bool = False):
    """Returns (jitted fn, positional ShapeDtypeStruct args) for one cell."""
    cfg = cfg_override if cfg_override is not None else configs.get_config(arch)
    if optimized:
        import dataclasses

        cfg = dataclasses.replace(cfg, **OPTIMIZED)
    model = build(cfg)
    set_mesh_rules(mesh, shd.act_rules(mesh))
    spec = shp.SHAPES[shape]
    aparams = model.abstract_params()
    pshard = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        shd.param_pspecs(aparams, model.axes(), mesh),
    )
    specs = shp.input_specs(cfg, shape, model)

    if spec.kind == "train":
        tcfg = TrainConfig(compress_cross_pod=compress_cross_pod)
        step = make_train_step(model, optim.AdamWConfig(), tcfg, mesh)
        aopt = jax.eval_shape(optim.init_opt_state, aparams)
        if compress_cross_pod and "pod" in mesh.shape:
            aopt["err_fb"] = jax.tree.map(
                lambda t: jax.ShapeDtypeStruct(t.shape, "float32"), aparams
            )
        return step, (aparams, aopt, specs)

    if spec.kind == "prefill":
        bshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), shd.batch_pspecs(specs, mesh)
        )
        fn = jax.jit(
            model.forward, in_shardings=(pshard, bshard), out_shardings=None
        )
        return fn, (aparams, specs)

    # decode
    cache_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, s), shd.cache_pspecs(specs["cache"], mesh)
    )
    tok_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        shd.batch_pspecs({"tokens": specs["tokens"]}, mesh),
    )["tokens"]
    fn = jax.jit(
        model.decode_step,
        in_shardings=(pshard, cache_shard, tok_shard, NamedSharding(mesh, P())),
        out_shardings=None,
        donate_argnums=(1,),
    )
    return fn, (aparams, specs["cache"], specs["tokens"], specs["index"])


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
             optimized: bool = False) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = configs.get_config(arch)
    with mesh:
        fn, abstract_args = build_step(arch, shape, mesh, optimized=optimized)
        lowered = fn.lower(*abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        rep = roofline.analyze(
            compiled,
            mesh,
            arch=arch,
            shape=shape,
            cfg=cfg,
            shape_spec=shp.SHAPES[shape],
        )
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "roofline": rep.to_dict(),
    }
    if verbose:
        print(compiled.memory_analysis())
        print({k: v for k, v in (compiled.cost_analysis() or {}).items()
               if k in ("flops", "bytes accessed")})
        print(rep.summary(), flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS)
    ap.add_argument("--shape", choices=list(shp.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf-winning config knobs")
    ap.add_argument("--out", default=None, help="JSONL, appended per cell")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present (ok) in --out")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in configs.ARCHS:
            for shape in configs.shape_grid(arch):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    done = set()
    if args.skip_done and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    def emit(rec):
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")

    results = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            tag = f"{arch} × {shape} × {mesh_name}"
            if (arch, shape, mesh_name) in done:
                print(f"[SKIP] {tag}", flush=True)
                continue
            try:
                rec = run_cell(arch, shape, mp, optimized=args.optimized)
                results.append(rec)
                emit(rec)
                print(f"[OK]   {tag}", flush=True)
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "ok": False, "error": f"{type(e).__name__}: {e}"}
                results.append(rec)
                emit(rec)
                print(f"[FAIL] {tag}: {e}", flush=True)
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} cells compiled")
    raise SystemExit(0 if n_ok == len(results) else 1)


if __name__ == "__main__":
    main()
