import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Corrected roofline sweep (§Roofline).

XLA's ``cost_analysis`` counts a ``while``-loop (scan-over-layers) body ONCE,
not × trip-count — the raw dry-run numbers therefore undercount FLOPs/bytes/
collectives by ~n_layers.  This sweep derives exact per-layer costs by
compiling each cell UNROLLED at two depths (L1, L2 = 2·L1; depths are
family-aware so hybrids keep whole shared-attention segments) and
extrapolating linearly:

  per_layer = (cost(L2) − cost(L1)) / (L2 − L1)
  corrected = cost(L1) + (L_full − L1) · per_layer

Memory residency still comes from the full-depth scanned compile (scan
reuses layer buffers — that *is* the real residency).  Output:
roofline_corrected.jsonl, one record per (arch × shape) on the single-pod
mesh (per assignment the roofline table is single-pod only).

  PYTHONPATH=src python -m repro.launch.roofline_sweep [--arch A --shape S]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro import configs, roofline
from repro.configs import shapes as shp
from repro.launch.dryrun import build_step
from repro.launch.mesh import make_production_mesh


def _depths(cfg):
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every or 1
        return k, 2 * k
    return 1, 2


def _reduced(cfg, L):
    kw = {"n_layers": L, "scan_layers": False}
    if cfg.family == "encdec":
        kw["enc_layers"] = L
    return dataclasses.replace(cfg, **kw)


def _cell_costs(arch, shape, mesh, cfg):
    fn, args = build_step(arch, shape, mesh, cfg_override=cfg)
    compiled = fn.lower(*args).compile()
    ca = compiled.cost_analysis()
    coll = roofline.collective_bytes(compiled.as_text())
    return (
        float(ca.get("flops", 0.0)),
        float(ca.get("bytes accessed", 0.0)),
        coll,
    )


def run_cell(arch: str, shape: str) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=False)
    cfg = configs.get_config(arch)
    l1, l2 = _depths(cfg)
    l_full = cfg.n_layers
    with mesh:
        f1, b1, c1 = _cell_costs(arch, shape, mesh, _reduced(cfg, l1))
        f2, b2, c2 = _cell_costs(arch, shape, mesh, _reduced(cfg, l2))
    scale = (l_full - l1) / (l2 - l1)
    flops = f1 + (f2 - f1) * scale
    byts = b1 + (b2 - b1) * scale
    coll = {k: c1[k] + (c2[k] - c1[k]) * scale for k in c1}
    coll_total = sum(v * (2 if k == "all-reduce" else 1) for k, v in coll.items())

    hw = roofline.HW
    compute_s = flops / hw["peak_flops_bf16"]
    memory_s = byts / hw["hbm_bw"]
    collective_s = coll_total / hw["link_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = roofline.model_flops(cfg, shp.SHAPES[shape])
    chips = mesh.size
    return {
        "arch": arch,
        "shape": shape,
        "mesh": "16x16",
        "ok": True,
        "chips": chips,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": byts,
        "collective_bytes_per_chip": coll_total,
        "collective_breakdown": coll,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "model_flops_ratio": mf / (flops * chips) if flops else 0.0,
        "roofline_fraction": compute_s / max(terms.values()) if max(terms.values()) else 0.0,
        "elapsed_s": round(time.time() - t0, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS)
    ap.add_argument("--shape", choices=list(shp.SHAPES))
    ap.add_argument("--out", default="roofline_corrected.jsonl")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    cells = (
        [(args.arch, args.shape)]
        if args.arch
        else [(a, s) for a in configs.ARCHS for s in configs.shape_grid(a)]
    )
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            done = {
                (r["arch"], r["shape"]) for r in map(json.loads, f) if r.get("ok")
            }
    for arch, shape in cells:
        if (arch, shape) in done:
            print(f"[SKIP] {arch} × {shape}", flush=True)
            continue
        try:
            rec = run_cell(arch, shape)
            print(
                f"[OK]   {arch:22s} {shape:12s} "
                f"comp={rec['compute_s']*1e3:9.2f}ms mem={rec['memory_s']*1e3:9.2f}ms "
                f"coll={rec['collective_s']*1e3:9.2f}ms dom={rec['dominant']:10s} "
                f"useful={rec['model_flops_ratio']:.2%} "
                f"frac={rec['roofline_fraction']:.3f}",
                flush=True,
            )
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "ok": False,
                   "error": f"{type(e).__name__}: {e}"}
            print(f"[FAIL] {arch} × {shape}: {e}", flush=True)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
