"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch smollm_360m \
      --steps 50 --batch 8 --seq 256 [--smoke] [--ckpt-dir /tmp/ckpt]

On this CPU container use ``--smoke`` (reduced config).  On a real cluster
the same driver runs under the production mesh (--mesh single|multi).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro import configs, optim
from repro.data import DataConfig, SyntheticTokens
from repro.models.registry import build
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m", choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--mesh", choices=["none", "single", "multi"], default="none")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    model = build(cfg)
    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    data = SyntheticTokens(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    )
    opt_cfg = optim.AdamWConfig(
        lr=args.lr, warmup_steps=max(2, args.steps // 10), total_steps=args.steps
    )
    trainer = Trainer(
        model,
        data,
        opt_cfg,
        TrainConfig(micro_batches=args.micro_batches, ckpt_every=args.ckpt_every),
        mesh=mesh,
        ckpt_dir=args.ckpt_dir,
    )
    params, opt_state = trainer.init_state()
    params, opt_state = trainer.maybe_restore(params, opt_state)
    params, opt_state = trainer.run(params, opt_state, args.steps)
    first, last = trainer.history[0], trainer.history[-1]
    print(
        f"steps {first['step']}..{last['step']}  "
        f"loss {first['loss']:.4f} -> {last['loss']:.4f}  "
        f"stragglers={trainer.straggler_events}"
    )


if __name__ == "__main__":
    main()
