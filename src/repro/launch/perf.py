import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing harness: hypothesis → change → re-lower → re-analyse.

Runs the corrected roofline (see roofline_sweep.py) for one cell under a
sequence of named config overrides and prints the before/after terms.  The
three hillclimbed cells (per assignment: worst roofline fraction, most
collective-bound, most representative of the paper's technique):

  A  smollm_360m × train_4k      (worst compute/dominant fraction)
  B  moonshot_v1_16b_a3b × train_4k  (most collective-bound)
  C  qwen2_0p5b × prefill_32k    (Phantom serving cell)

  PYTHONPATH=src python -m repro.launch.perf --cell A
"""
import argparse
import dataclasses
import json

from repro import configs, roofline
from repro.configs import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline_sweep import _cell_costs, _depths, _reduced


CELLS = {
    "A": ("smollm_360m", "train_4k", [
        ("baseline", {}),
        ("chunked_attn", {"attn_impl": "chunked"}),
        ("chunked+embed1d", {"attn_impl": "chunked", "embed_table_2d": False}),
        ("chunked4k", {"attn_impl": "chunked", "attn_chunk": 4096}),
        ("chunked4k+noremat",
         {"attn_impl": "chunked", "attn_chunk": 4096, "remat": False}),
        ("chunked1k+noremat", {"attn_impl": "chunked", "remat": False}),
        ("chunked512", {"attn_impl": "chunked", "attn_chunk": 512}),
    ]),
    "B": ("moonshot_v1_16b_a3b", "train_4k", [
        ("baseline", {}),
        ("grouped_moe", {"moe_groups": 16}),
        ("grouped+chunked", {"moe_groups": 16, "attn_impl": "chunked"}),
        ("grouped+chunked+embed1d",
         {"moe_groups": 16, "attn_impl": "chunked", "embed_table_2d": False}),
    ]),
    "C": ("qwen2_0p5b", "prefill_32k", [
        ("baseline", {}),
        ("chunked_attn", {"attn_impl": "chunked"}),
        ("chunked+embed1d", {"attn_impl": "chunked", "embed_table_2d": False}),
    ]),
}


def corrected_terms(arch, shape, overrides: dict) -> dict:
    mesh = make_production_mesh(multi_pod=False)
    cfg = dataclasses.replace(configs.get_config(arch), **overrides)
    l1, l2 = _depths(cfg)
    with mesh:
        f1, b1, c1 = _cell_costs(arch, shape, mesh, _reduced(cfg, l1))
        f2, b2, c2 = _cell_costs(arch, shape, mesh, _reduced(cfg, l2))
    scale = (cfg.n_layers - l1) / (l2 - l1)
    flops = f1 + (f2 - f1) * scale
    byts = b1 + (b2 - b1) * scale
    coll = {k: c1[k] + (c2[k] - c1[k]) * scale for k in c1}
    coll_total = sum(v * (2 if k == "all-reduce" else 1) for k, v in coll.items())
    hw = roofline.HW
    terms = {
        "compute_s": flops / hw["peak_flops_bf16"],
        "memory_s": byts / hw["hbm_bw"],
        "collective_s": coll_total / hw["link_bw"],
    }
    mf = roofline.model_flops(cfg, shp.SHAPES[shape])
    dom = max(terms.values())
    return {
        **terms,
        "dominant": max(terms, key=terms.get),
        "useful": mf / (flops * mesh.size) if flops else 0.0,
        "roofline_fraction": terms["compute_s"] / dom if dom else 0.0,
        "collective_breakdown": coll,
    }


def phantom_kernel_analytic(arch, shape, base: dict, weight_density=0.25,
                            block=(256, 256, 256)) -> dict:
    """Beyond-dry-run term: the Pallas kernel path cannot lower for a fake
    TPU, so its effect is derived from the *real* work queue built on the
    arch's actual FFN shapes: MXU grid steps shrink to the measured
    compaction ratio; packed-weight HBM bytes shrink to ~weight_density."""
    import numpy as np

    from repro.core.sparsity import block_prune
    from repro.kernels import ops

    cfg = configs.get_config(arch)
    sp = shp.SHAPES[shape]
    rng = np.random.default_rng(0)
    d, ff = cfg.d_model, cfg.d_ff
    tokens = sp.global_batch * sp.seq_len
    ratios = []
    for (k_, n_) in ((d, ff), (ff, d)):
        w = rng.standard_normal((k_, n_)).astype(np.float32)
        w *= block_prune(w, weight_density, block[1:])
        pw = ops.prepare_weight(w, m=4096, block=block)
        mt, kt, nt = pw.grid_tiles
        ratios.append(pw.steps / (mt * kt * nt))
    r = float(np.mean(ratios))
    # FFN share of model GEMM flops (gate+up+down) per token.
    ffn_flops = 2.0 * 3 * d * ff * tokens * (1 if sp.kind != "train" else 3)
    chips = 256
    ffn_compute_s = ffn_flops / chips / roofline.HW["peak_flops_bf16"]
    w_bytes = cfg.n_layers * 3 * d * ff * 2 / chips
    out = dict(base)
    out["compute_s"] = base["compute_s"] - ffn_compute_s * (1 - r)
    out["memory_s"] = base["memory_s"] - w_bytes * (1 - weight_density) / roofline.HW["hbm_bw"]
    dom = max(out["compute_s"], out["memory_s"], out["collective_s"])
    out["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: out[k]
    )
    out["roofline_fraction"] = out["compute_s"] / dom
    out["note"] = f"kernel compaction r={r:.3f} @ density {weight_density}"
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS), required=True)
    ap.add_argument("--out", default="perf_results.jsonl")
    args = ap.parse_args()
    arch, shape, steps = CELLS[args.cell]
    print(f"=== cell {args.cell}: {arch} × {shape} (single-pod 16x16) ===")
    base = None
    for name, ov in steps:
        rec = corrected_terms(arch, shape, ov)
        if base is None:
            base = rec
        line = (
            f"{name:26s} comp={rec['compute_s']*1e3:9.2f}ms "
            f"mem={rec['memory_s']*1e3:9.2f}ms coll={rec['collective_s']*1e3:9.2f}ms "
            f"dom={rec['dominant'][:-2]:10s} frac={rec['roofline_fraction']:.3f} "
            f"useful={rec['useful']:.2%}"
        )
        print(line, flush=True)
        with open(args.out, "a") as f:
            f.write(json.dumps({"cell": args.cell, "arch": arch, "shape": shape,
                                "step": name, **{k: v for k, v in rec.items()}}) + "\n")
    if args.cell == "C":
        rec = phantom_kernel_analytic(arch, shape, rec)
        print(
            f"{'phantom_kernel(analytic)':26s} comp={rec['compute_s']*1e3:9.2f}ms "
            f"mem={rec['memory_s']*1e3:9.2f}ms coll={rec['collective_s']*1e3:9.2f}ms "
            f"dom={rec['dominant'][:-2]:10s} frac={rec['roofline_fraction']:.3f} "
            f"[{rec['note']}]",
            flush=True,
        )
        with open(args.out, "a") as f:
            f.write(json.dumps({"cell": "C", "arch": arch, "shape": shape,
                                "step": "phantom_kernel_analytic",
                                **{k: v for k, v in rec.items()}}) + "\n")


if __name__ == "__main__":
    main()
