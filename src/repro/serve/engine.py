"""Batched serving engine: slot-based KV cache with continuous batching.

``ServeEngine`` owns a fixed pool of ``batch_size`` cache slots.  Requests
queue up; free slots are filled immediately (continuous batching — a
finishing request never stalls the rest of the batch).  Prompts are fed
token-by-token through the same jitted decode step that generates (teacher
forcing into the cache), so there is exactly one compiled program — the
per-slot ``index`` vector tracks each slot's fill independently.

This is where Phantom serves: with ``cfg.phantom.enabled`` the FFN/o-proj
matmuls route through the masked (or Pallas-kernel) block-sparse path, and
activation tile masks flow between layers (DESIGN.md §4).

The engine takes a :class:`repro.program.PhantomProgram` directly
(``ServeEngine(model, params, program=prog, ...)``): models whose
``decode_step`` accepts a ``program`` keyword receive it and can pull
prepared kernel-path artifacts from the program's plan cache instead of
re-lowering per process (DESIGN.md §8); for other models the program is
held for introspection (``engine.program.stats(...)``).

With ``recorder=`` (a :class:`repro.obs.Recorder`, DESIGN.md §11) the
engine publishes serving metrics: per-request latency
(``serve/request_latency_s`` — read p50/p95/p99 via
``recorder.percentiles``), queue depth and slot occupancy per decode step,
steps-per-request, and counters for submissions, completions, empty-prompt
rejections and ``run()`` exhaustions.

With ``policy=`` (a :class:`repro.serve.policy.ServePolicy`, DESIGN.md §14)
the engine gains failure semantics: per-request deadlines (overdue requests
are *failed* with ``req.error`` set, never silently dropped), a bounded
admission queue (:class:`~repro.serve.policy.RejectedError` beyond it),
retry-with-exponential-backoff for transient decode faults, and graceful
degradation to the ``lookahead=0``/``cores=1`` fallback program.
``policy=None`` (the default) preserves the pre-policy behaviour
bit-for-bit, recorder snapshots included.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import itertools
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import faults as faults_mod
from . import policy as policy_mod

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0  # engine-clock timestamp (observability)
    #: absolute engine-clock deadline (None = no deadline); set from
    #: ``submit(deadline_s=...)`` or the policy default (DESIGN.md §14)
    deadline: Optional[float] = None
    #: failure reason when the request left the engine without completing
    #: (e.g. ``"deadline exceeded"``); ``done`` stays False then
    error: Optional[str] = None


def _accepts_program(fn) -> bool:
    """Whether a model's ``decode_step`` opts into the program contract.

    Opt-in requires a *named* ``program`` parameter — a bare ``**kwargs``
    catch-all does not count (it usually forwards elsewhere, and baking
    ``program=`` into it would crash or silently embed the program's arrays
    as trace constants in a model that never asked for them).
    """
    try:
        return "program" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


class ServeEngine:
    def __init__(
        self,
        model,
        params,
        *,
        batch_size: int,
        max_len: int,
        program=None,
        recorder=None,
        policy: "policy_mod.ServePolicy | None" = None,
    ):
        self.model, self.params = model, params
        self.b, self.max_len = batch_size, max_len
        self.program = program
        self.recorder = recorder
        self._clock = recorder.clock if recorder is not None else time.perf_counter
        self.policy = policy
        self._rt = (
            policy_mod.PolicyRuntime(
                policy,
                clock=self._clock,
                recorder=recorder,
                prefix="serve",
                degrade=self._degrade_step,
            )
            if policy is not None
            else None
        )
        self._fallback_program = None
        if recorder is not None and program is not None and program.recorder is None:
            # One timeline: the program's per-layer spans land in the same
            # trace as the engine's serving metrics (DESIGN.md §11).
            program.recorder = recorder
        self.cache = model.init_cache(batch_size, max_len)
        self.index = np.zeros(batch_size, dtype=np.int32)  # per-slot fill
        self.slot_req: list[Optional[Request]] = [None] * batch_size
        self.slot_pending: list[deque] = [deque() for _ in range(batch_size)]
        self.queue: deque[Request] = deque()
        self._rid = itertools.count()
        step_fn = model.decode_step
        if program is not None and _accepts_program(step_fn):
            step_fn = functools.partial(step_fn, program=program)
        self._step = jax.jit(step_fn)

    # -- client API ----------------------------------------------------------
    def _now(self) -> float:
        """Engine time: the injected clock, plus fault/backoff skew when a
        policy is active (exactly one clock read either way)."""
        return self._rt.now() if self._rt is not None else self._clock()

    def submit(
        self,
        prompt: list[int],
        max_new_tokens: int = 16,
        eos_id=None,
        *,
        deadline_s: Optional[float] = None,
    ) -> Request:
        prompt = list(prompt)
        if not prompt:
            if self.recorder is not None:
                self.recorder.inc("serve/rejected_empty_prompt")
            raise ValueError(
                "cannot submit an empty prompt: decoding needs at least one "
                "conditioning token (the engine would otherwise crash at "
                "generation time reading prompt[-1])"
            )
        if max_new_tokens < 1:
            if self.recorder is not None:
                self.recorder.inc("serve/rejected_invalid_request")
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}: a "
                f"request allowed to generate nothing would complete after "
                f"one spurious token — clamp upstream or drop the request"
            )
        if deadline_s is not None and not deadline_s > 0:
            if self.recorder is not None:
                self.recorder.inc("serve/rejected_invalid_request")
            raise ValueError(
                f"deadline_s must be positive, got {deadline_s} (a "
                f"non-positive deadline is already missed at submit)"
            )
        if deadline_s is not None and self._rt is None:
            raise ValueError(
                "deadline_s requires failure semantics: construct the "
                "engine with policy=ServePolicy(...) to enable deadline "
                "enforcement (DESIGN.md §14)"
            )
        if self._rt is not None:
            self._rt.admit(len(self.queue))
        req = Request(
            next(self._rid), prompt, max_new_tokens, eos_id, t_submit=self._now()
        )
        if self._rt is not None:
            req.deadline = self._rt.resolve_deadline(deadline_s, req.t_submit)
        self.queue.append(req)
        if self.recorder is not None:
            self.recorder.inc("serve/submitted")
            self.recorder.gauge("serve/queue_depth", len(self.queue))
        return req

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive until every submitted request completes; returns them.

        Raises :class:`RuntimeError` if ``max_steps`` decode steps pass
        without draining the work — silently dropping undone requests would
        hand the caller a short list indistinguishable from success.

        With a policy, requests whose deadline expires are *failed*
        (``done=False``, ``error`` set) and still returned — a caller can
        always account for every accepted request.
        """
        finished = []
        for _ in range(max_steps):
            if self._rt is not None:
                self._expire_overdue(finished)
            self._fill_slots()
            if all(r is None for r in self.slot_req):
                break
            self._decode_once(finished)
        else:
            undone = [r.rid for r in self.slot_req if r is not None]
            undone += [r.rid for r in self.queue]
            if undone:
                if self.recorder is not None:
                    self.recorder.inc("serve/exhausted_runs")
                raise RuntimeError(
                    f"run(max_steps={max_steps}) exhausted with "
                    f"{len(undone)} request(s) incomplete (rids {undone}); "
                    f"raise max_steps or submit less work per run() call"
                )
        return finished

    @property
    def degraded(self) -> bool:
        """True once graceful degradation swapped in the fallback path."""
        return self._rt is not None and self._rt.degraded

    # -- internals -------------------------------------------------------------
    def _expire_overdue(self, finished: list):
        """Fail every queued/in-slot request whose deadline has passed.

        Candidate scan first, clock read second: when no live request has a
        deadline this reads no clock at all, so a no-op policy stays
        bit-identical to ``policy=None`` under the recorder's fake clock.
        """
        live = [r for r in self.slot_req if r is not None] + list(self.queue)
        if not any(r.deadline is not None for r in live):
            return
        now = self._rt.now()
        for s, req in enumerate(self.slot_req):
            if req is not None and req.deadline is not None and now > req.deadline:
                self.slot_req[s] = None
                self._fail_deadline(req, now, finished)
        if any(r.deadline is not None and now > r.deadline for r in self.queue):
            keep: deque[Request] = deque()
            for req in self.queue:
                if req.deadline is not None and now > req.deadline:
                    self._fail_deadline(req, now, finished)
                else:
                    keep.append(req)
            self.queue = keep

    def _fail_deadline(self, req: Request, now: float, finished: list):
        req.error = policy_mod.DEADLINE_REASON
        finished.append(req)
        self._rt.record_miss(now - req.deadline)

    def _degrade_step(self):
        """Graceful degradation: re-jit the decode step onto the
        ``lookahead=0``/``cores=1`` fallback program (bit-identical outputs
        by the §9/§10 parity contracts).  Models that never opted into the
        program contract keep their step — for them degradation only
        disarms the fault injector."""
        if self.program is None or not _accepts_program(self.model.decode_step):
            return
        self._fallback_program = policy_mod.fallback_program(self.program)
        self._fallback_program.recorder = self.recorder
        self._step = jax.jit(
            functools.partial(self.model.decode_step, program=self._fallback_program)
        )

    def _fill_slots(self):
        filled = []
        for s in range(self.b):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[s] = req
                self.slot_pending[s] = deque(req.prompt)
                self.index[s] = 0
                filled.append(s)
        if filled:
            self._reset_slot_caches(filled)

    def _reset_slot_caches(self, slots: list[int]):
        # One tree traversal for all slots filled this pass — per-slot
        # resets each rebuilt every array of the whole KV cache.
        idx = np.asarray(slots)
        self.cache = jax.tree.map(lambda t: t.at[:, idx].set(0), self.cache)

    def _decode_once(self, finished: list):
        rec = self.recorder
        if rec is not None:
            rec.inc("serve/decode_steps")
            occupied = sum(r is not None for r in self.slot_req)
            rec.observe("serve/slot_occupancy", occupied / self.b)
            rec.gauge("serve/queue_depth", len(self.queue))
        tokens = np.zeros((self.b, 1), dtype=np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.slot_pending[s]:
                tokens[s, 0] = self.slot_pending[s].popleft()
            elif req.output:
                tokens[s, 0] = req.output[-1]
            else:
                tokens[s, 0] = req.prompt[-1]
        step_args = (
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(self.index)
        )
        if self._rt is None:
            logits, self.cache = self._step(*step_args)
        else:
            # The input cache is captured above: a retried attempt replays
            # the identical computation (decode is functional), so outputs
            # of completed requests are bit-identical to a fault-free run.
            logits, new_cache = self._rt.attempt(
                lambda: self._step(*step_args),
                corrupt=lambda out: (faults_mod.corrupt_array(out[0]), out[1]),
                check=lambda out: faults_mod.check_activations(out[0]),
            )
            self.cache = new_cache
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.index[s] += 1
            if self.slot_pending[s]:
                continue  # still prefilling this slot
            req.output.append(int(nxt[s]))
            hit_eos = req.eos_id is not None and int(nxt[s]) == req.eos_id
            if (
                len(req.output) >= req.max_new_tokens
                or hit_eos
                or self.index[s] >= self.max_len - 1
            ):
                req.done = True
                finished.append(req)
                self.slot_req[s] = None
                if rec is not None:
                    t_done = self._now()
                    rec.inc("serve/completed")
                    rec.observe("serve/request_latency_s", t_done - req.t_submit)
                    rec.observe("serve/steps_per_request", int(self.index[s]))
                    if req.deadline is not None:
                        # Completed late: keep the result, account the miss.
                        if t_done > req.deadline:
                            self._rt.record_miss(t_done - req.deadline)
                        else:
                            self._rt.record_met()
