"""Deterministic fault injection for the serving layer (DESIGN.md §14).

A :class:`FaultPlan` is a *pure function* from ``(seed, attempt index)`` to
the faults injected at that decode attempt: step-latency spikes, transient
kernel failures (:class:`TransientKernelError`), and corrupt-activation
faults that the runtime activation check (:func:`check_activations`, a
:mod:`repro.verify` hook) turns into :class:`CorruptActivationError`.  Every
draw comes from ``np.random.default_rng([_STREAM, seed, attempt])`` — no
global RNG, no wall clock — so a schedule is byte-identical across
processes (:meth:`FaultPlan.schedule_bytes`) and every failure path the
serve policy exercises is replayable bit-for-bit in tier-1 tests.

Latency spikes never touch ``time.sleep``: the engines keep a *skew* clock
(``PolicyRuntime.now() = clock() + skew``), and an injected spike simply
advances the skew.  Deadlines, backoff, and latency metrics all read the
skew clock, so fault timing composes with the injectable ``obs.Recorder``
clock and tier-1 asserts exact durations.

:class:`FaultInjector` is the stateful cursor an engine owns: one draw per
decode *attempt* (so a retried step sees the next schedule entry, not the
same one), a ``max_faults`` budget, and a ``disarm()`` switch the
degradation path flips so a degraded engine is guaranteed to make progress.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "CorruptActivationError",
    "FaultInjector",
    "FaultPlan",
    "StepFaults",
    "TransientKernelError",
    "check_activations",
    "corrupt_array",
]

#: Domain separator for fault draws — keeps the fault schedule independent
#: of every other seeded rng in the repo even at equal seeds.
_STREAM = 0xFA017


class TransientKernelError(RuntimeError):
    """An injected (or detected) transient kernel failure.

    Retryable by construction: it is raised *before* any engine state is
    mutated (the decode step's functional outputs are discarded), so a
    retry replays the identical computation.
    """

    def __init__(self, msg: str, *, attempt: int | None = None, kind: str = "transient"):
        self.attempt = attempt
        self.kind = kind
        super().__init__(msg)


class CorruptActivationError(TransientKernelError):
    """Corrupt activations detected after a decode step.

    Carries the structured :class:`repro.verify.Finding` list the runtime
    activation check produced — the same diagnostic currency as the static
    program verifier (DESIGN.md §13).  Subclasses
    :class:`TransientKernelError` because the recovery is the same: discard
    the step's outputs and retry.
    """

    def __init__(self, findings, *, attempt: int | None = None):
        self.findings = list(findings)
        detail = "; ".join(f.format() for f in self.findings) or "corrupt activations"
        super().__init__(
            f"corrupt activations detected by runtime verifier: {detail}",
            attempt=attempt,
            kind="corrupt",
        )


@dataclasses.dataclass(frozen=True)
class StepFaults:
    """The faults drawn for one decode attempt."""

    attempt: int
    latency_s: float = 0.0
    transient: bool = False
    corrupt: bool = False

    @property
    def erroneous(self) -> bool:
        return self.transient or self.corrupt

    @property
    def any(self) -> bool:
        return self.erroneous or self.latency_s > 0.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, deterministic fault schedule.

    Rates are per decode *attempt* (a retried step draws fresh faults).
    ``max_faults`` bounds the total injected transient+corrupt faults — a
    finite budget makes "every accepted request eventually completes"
    unconditional even without degradation.
    """

    seed: int = 0
    transient_rate: float = 0.0
    corrupt_rate: float = 0.0
    latency_rate: float = 0.0
    latency_s: float = 0.005
    max_faults: int | None = None

    def __post_init__(self):
        for name in ("transient_rate", "corrupt_rate", "latency_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultPlan.{name} must be in [0, 1], got {v}")
        if self.latency_s < 0.0:
            raise ValueError(f"FaultPlan.latency_s must be >= 0, got {self.latency_s}")
        if self.max_faults is not None and self.max_faults < 0:
            raise ValueError(f"FaultPlan.max_faults must be >= 0, got {self.max_faults}")

    # -- the schedule --------------------------------------------------------
    def at(self, attempt: int) -> StepFaults:
        """The faults for decode attempt ``attempt`` — pure and
        order-independent: each attempt gets its own seeded generator, so
        the schedule does not depend on how many draws happened before."""
        u = np.random.default_rng([_STREAM, self.seed, attempt]).random(3)
        return StepFaults(
            attempt,
            latency_s=self.latency_s if u[0] < self.latency_rate else 0.0,
            transient=bool(u[1] < self.transient_rate),
            corrupt=bool(u[2] < self.corrupt_rate),
        )

    def schedule(self, n: int) -> list[StepFaults]:
        return [self.at(i) for i in range(n)]

    def schedule_bytes(self, n: int) -> bytes:
        """A canonical byte encoding of the first ``n`` schedule entries —
        the determinism-audit contract (same seed ⇒ identical bytes)."""
        rows = np.zeros((n, 3), dtype=np.float64)
        for i, f in enumerate(self.schedule(n)):
            rows[i] = (f.latency_s, float(f.transient), float(f.corrupt))
        return rows.tobytes()

    # -- presets / CLI -------------------------------------------------------
    @classmethod
    def smoke(cls, seed: int = 0) -> "FaultPlan":
        """The CI chaos-smoke preset: enough transient faults and latency
        spikes to exercise every retry path on a short run, small enough
        that default retry budgets absorb them."""
        return cls(
            seed=seed,
            transient_rate=0.25,
            latency_rate=0.25,
            latency_s=0.002,
        )

    @classmethod
    def parse(cls, spec: str, *, seed: int = 0) -> "FaultPlan | None":
        """Build a plan from a CLI spec: ``none``, ``smoke``, or a
        comma-separated ``key=value`` list over the dataclass fields, e.g.
        ``transient_rate=0.2,latency_rate=0.1,latency_s=0.01``."""
        spec = spec.strip()
        if spec in ("", "none", "off"):
            return None
        if spec == "smoke":
            return cls.smoke(seed)
        fields = {f.name: f for f in dataclasses.fields(cls)}
        kw: dict = {"seed": seed}
        for part in spec.split(","):
            if "=" not in part:
                raise ValueError(
                    f"bad --faults entry {part!r}: expected key=value "
                    f"(keys: {sorted(fields)}), 'smoke', or 'none'"
                )
            k, v = (s.strip() for s in part.split("=", 1))
            if k not in fields:
                raise ValueError(
                    f"unknown --faults key {k!r}; known: {sorted(fields)}"
                )
            kw[k] = None if v == "none" else (int(v) if k in ("seed", "max_faults") else float(v))
        return cls(**kw)


class FaultInjector:
    """Stateful cursor over a :class:`FaultPlan`: one draw per attempt.

    ``disarm()`` (flipped by the degradation path) stops transient/corrupt
    injection while leaving latency spikes alone — the failure was
    attributed to the aggressive config, so the degraded fallback must be
    able to make progress.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.attempts = 0
        self.injected = 0  # erroneous faults actually injected
        self.armed = True

    def next(self) -> StepFaults:
        f = self.plan.at(self.attempts)
        self.attempts += 1
        budget = self.plan.max_faults
        out_of_budget = budget is not None and self.injected >= budget
        if f.erroneous and (not self.armed or out_of_budget):
            f = dataclasses.replace(f, transient=False, corrupt=False)
        if f.erroneous:
            self.injected += 1
        return f

    def disarm(self) -> None:
        self.armed = False


def corrupt_array(x):
    """The injected corruption: every element NaN (dtype-preserving) — the
    loudest possible activation corruption, guaranteed to trip
    :func:`check_activations` on any nonempty array."""
    import jax.numpy as jnp

    return jnp.full_like(x, jnp.nan)


def check_activations(x, *, layer: str = "logits"):
    """Runtime verifier hook: non-finite activations as structured findings.

    Returns a list of :class:`repro.verify.Finding` (empty = clean), rule
    ``runtime/activation-finite`` — the dynamic sibling of the static
    artifact rules in DESIGN.md §13.  The serve policy raises the findings
    as :class:`CorruptActivationError` and retries the step.
    """
    from repro import verify as _verify

    arr = np.asarray(x)
    bad = int(arr.size - np.isfinite(arr).sum())
    if not bad:
        return []
    return [
        _verify.Finding(
            "runtime/activation-finite",
            f"{bad}/{arr.size} non-finite activation value(s) in decode output",
            layer=layer,
        )
    ]
