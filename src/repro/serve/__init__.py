"""Serving engines: token-level LM serving and batched CNN inference.

Both engines take a compiled :class:`repro.program.PhantomProgram` directly
(``CnnServeEngine(program=...)``, ``ServeEngine(..., program=...)``) so
weight-load-time lowering happens once per fleet — see DESIGN.md §8.

Failure semantics (DESIGN.md §14) are opt-in via ``policy=``: a
:class:`ServePolicy` adds per-request deadlines, a bounded admission queue
(:class:`RejectedError`), retry-with-backoff for transient faults
(:class:`TransientKernelError` / :class:`CorruptActivationError`), and
graceful degradation to the ``lookahead=0``/``cores=1`` fallback program.
:class:`FaultPlan` (:mod:`repro.serve.faults`) is the seeded, deterministic
fault-injection harness that proves all of it in tier-1.
"""
from .cnn import CnnRequest, CnnServeEngine, serve_cnn
from .engine import Request, ServeEngine
from .faults import (
    CorruptActivationError,
    FaultInjector,
    FaultPlan,
    TransientKernelError,
)
from .policy import FaultExhaustedError, RejectedError, ServePolicy

__all__ = [
    "ServeEngine",
    "Request",
    "CnnRequest",
    "CnnServeEngine",
    "serve_cnn",
    "ServePolicy",
    "FaultPlan",
    "FaultInjector",
    "RejectedError",
    "TransientKernelError",
    "CorruptActivationError",
    "FaultExhaustedError",
]
