"""Serving engines: token-level LM serving and batched CNN inference.

Both engines take a compiled :class:`repro.program.PhantomProgram` directly
(``CnnServeEngine(program=...)``, ``ServeEngine(..., program=...)``) so
weight-load-time lowering happens once per fleet — see DESIGN.md §8.
"""
from .cnn import CnnRequest, CnnServeEngine, serve_cnn
from .engine import ServeEngine, Request

__all__ = ["ServeEngine", "Request", "CnnRequest", "CnnServeEngine", "serve_cnn"]
