"""Serving engines: token-level LM serving and batched CNN inference."""
from .cnn import CnnRequest, CnnServeEngine, serve_cnn
from .engine import ServeEngine, Request

__all__ = ["ServeEngine", "Request", "CnnRequest", "CnnServeEngine", "serve_cnn"]
