"""Failure semantics for the serve engines (DESIGN.md §14).

:class:`ServePolicy` is the one knob surface: per-request deadlines, a
bounded admission queue with structured rejection (:class:`RejectedError` —
never a silent drop), retry-with-exponential-backoff for transient faults,
and graceful degradation to the ``lookahead=0`` / ``cores=1`` fallback
program after repeated failures.  ``policy=None`` (the default on both
engines) preserves pre-policy behaviour bit-for-bit — no extra clock reads,
no extra metrics, identical outputs (guarded by a parity test).

:class:`PolicyRuntime` is the per-engine mutable half: the skew clock
(injected fault latency and retry backoff advance ``skew`` instead of
sleeping, so failure timing is deterministic under the recorder's fake
clock), the fault injector cursor, and the retry/degradation state machine
around one decode attempt (:meth:`PolicyRuntime.attempt`):

    attempt fails (transient or corrupt)
      ├─ failures ≥ degrade_after and not yet degraded → degrade, retry
      ├─ failures ≤ max_retries → backoff (skew += b·f^(n-1)), retry
      ├─ not yet degraded and degradation enabled → degrade, retry
      └─ else → FaultExhaustedError (engine state untouched; run() again)

Degradation disarms the injector's erroneous faults (the failure is
attributed to the aggressive config), so a degraded engine always makes
progress — with degradation enabled, *every* accepted request completes
under any all-transient :class:`~repro.serve.faults.FaultPlan`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from .faults import (
    CorruptActivationError,
    FaultInjector,
    FaultPlan,
    TransientKernelError,
    check_activations,
)

__all__ = [
    "FaultExhaustedError",
    "PolicyRuntime",
    "RejectedError",
    "ServePolicy",
    "fallback_program",
]

#: `Request.error` reason for a deadline failure (stable string for tests).
DEADLINE_REASON = "deadline exceeded"


class RejectedError(RuntimeError):
    """Structured admission rejection: the bounded queue is full.

    Carries ``reason`` / ``queue_depth`` / ``max_queue`` so callers can
    implement client-side backpressure instead of parsing a message.
    """

    def __init__(self, reason: str, *, queue_depth: int, max_queue: int):
        self.reason = reason
        self.queue_depth = queue_depth
        self.max_queue = max_queue
        super().__init__(
            f"request rejected ({reason}): admission queue at "
            f"{queue_depth}/{max_queue}; drain with run()/step() or raise "
            f"ServePolicy.max_queue"
        )


class FaultExhaustedError(RuntimeError):
    """A decode step kept failing after every retry (and, if enabled, after
    degradation).  Engine state is untouched — the caller may run() again."""

    def __init__(self, failures: int, last: TransientKernelError):
        self.failures = failures
        self.last = last
        super().__init__(
            f"decode step failed {failures} time(s) and the retry budget is "
            f"exhausted (last: {last}); raise ServePolicy.max_retries or "
            f"enable degradation (degrade_after)"
        )


@dataclasses.dataclass(frozen=True)
class ServePolicy:
    """Failure-semantics knobs for :class:`~repro.serve.ServeEngine` /
    :class:`~repro.serve.CnnServeEngine`.

    * ``max_queue`` — admission bound on *waiting* requests (in-slot work
      does not count); ``submit`` raises :class:`RejectedError` beyond it.
    * ``deadline_s`` — default per-request deadline (engine-clock seconds
      from submit); overridable per request at ``submit(deadline_s=...)``.
      A request whose deadline passes while waiting is failed
      (``req.error``), never silently dropped.
    * ``max_retries`` / ``backoff_s`` / ``backoff_factor`` — transient-fault
      retry budget per decode step; the n-th retry waits
      ``backoff_s · backoff_factor**(n-1)`` skew-clock seconds.
    * ``degrade_after`` — consecutive failures of one step before the
      engine swaps in the ``lookahead=0``/``cores=1`` fallback program
      (bit-identical outputs by the §9/§10 parity contracts); ``None``
      disables degradation.
    * ``faults`` — an injected :class:`~repro.serve.faults.FaultPlan`
      (tests / chaos runs); ``None`` serves fault-free.
    """

    max_queue: Optional[int] = None
    deadline_s: Optional[float] = None
    max_retries: int = 3
    backoff_s: float = 0.001
    backoff_factor: float = 2.0
    degrade_after: Optional[int] = 2
    faults: Optional[FaultPlan] = None

    def __post_init__(self):
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.deadline_s is not None and not self.deadline_s > 0:
            raise ValueError(
                f"deadline_s must be positive, got {self.deadline_s} "
                f"(a non-positive deadline is already missed at submit)"
            )
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_s < 0:
            raise ValueError(f"backoff_s must be >= 0, got {self.backoff_s}")
        if self.backoff_factor < 1.0:
            raise ValueError(
                f"backoff_factor must be >= 1, got {self.backoff_factor}"
            )
        if self.degrade_after is not None and self.degrade_after < 1:
            raise ValueError(f"degrade_after must be >= 1, got {self.degrade_after}")


def fallback_program(program):
    """The graceful-degradation target for ``program``: same layers and
    params, ``lookahead=0`` / ``cores=1`` — the classic single-queue path
    every multi-core / compacted plan is asserted bit-identical to
    (DESIGN.md §9/§10), so degrading never changes served outputs."""
    from repro import program as program_mod

    cfg = program.cfg.with_overrides(lookahead=0, cores=1)
    overrides = {}
    for name, diff in program.overrides.items():
        kept = {k: v for k, v in diff.items() if k not in ("lookahead", "cores")}
        if kept:
            overrides[name] = kept
    return program_mod.PhantomProgram(
        program.layers, program.params, cfg, overrides=overrides
    )


class PolicyRuntime:
    """Per-engine policy state: skew clock, injector, retry state machine.

    ``prefix`` namespaces the metrics (``serve`` / ``serve_cnn``);
    ``degrade`` is the engine hook that swaps in the fallback execution
    path (called at most once).
    """

    def __init__(
        self,
        policy: ServePolicy,
        *,
        clock: Callable[[], float],
        recorder=None,
        prefix: str = "serve",
        degrade: Optional[Callable[[], None]] = None,
    ):
        self.policy = policy
        self._clock = clock
        self.recorder = recorder
        self.prefix = prefix
        self._degrade_cb = degrade
        self.skew = 0.0
        self.degraded = False
        self.injector = FaultInjector(policy.faults) if policy.faults is not None else None

    # -- clock ---------------------------------------------------------------
    def now(self) -> float:
        """Engine time: the injected clock plus accumulated fault/backoff
        skew.  Exactly one underlying clock read — policy=None parity."""
        return self._clock() + self.skew

    # -- admission -----------------------------------------------------------
    def admit(self, queue_depth: int) -> None:
        """Raise :class:`RejectedError` when the waiting queue is full."""
        mq = self.policy.max_queue
        if mq is not None and queue_depth >= mq:
            if self.recorder is not None:
                self.recorder.inc(f"{self.prefix}/rejected_queue_full")
            raise RejectedError("queue_full", queue_depth=queue_depth, max_queue=mq)

    def resolve_deadline(self, deadline_s: Optional[float], t_submit: float):
        """Absolute engine-clock deadline for a request submitted at
        ``t_submit`` (explicit per-request value wins over the policy
        default); validates positivity."""
        if deadline_s is None:
            deadline_s = self.policy.deadline_s
        if deadline_s is None:
            return None
        if not deadline_s > 0:
            raise ValueError(
                f"deadline_s must be positive, got {deadline_s} (a "
                f"non-positive deadline is already missed at submit)"
            )
        return t_submit + deadline_s

    # -- deadline accounting -------------------------------------------------
    def record_miss(self, overrun: float) -> None:
        if self.recorder is not None:
            self.recorder.inc(f"{self.prefix}/deadline_missed")
            self._observe_overrun(overrun)

    def record_met(self) -> None:
        if self.recorder is not None:
            self._observe_overrun(0.0)

    def _observe_overrun(self, overrun: float) -> None:
        rec = self.recorder
        rec.observe(f"{self.prefix}/deadline_overrun_s", overrun)
        p = rec.percentiles(f"{self.prefix}/deadline_overrun_s", qs=(99,))
        rec.gauge(f"{self.prefix}/deadline_overrun_p99", p["p99"])

    # -- the retry/degradation state machine ---------------------------------
    def attempt(self, fn, *, corrupt=None, check=None):
        """Run one decode step ``fn`` under the policy.

        ``corrupt`` applies an injected corruption to the step's output
        (engine-specific — e.g. only the logits half of a (logits, cache)
        pair); ``check`` maps the output to verifier findings
        (:func:`~repro.serve.faults.check_activations` shaped).  Both are
        only consulted while an injector is active.

        Returns the (clean) output of the successful attempt; raises
        :class:`FaultExhaustedError` when the budget runs out.
        """
        pol, rec, inj = self.policy, self.recorder, self.injector
        failures = 0
        while True:
            fault = inj.next() if inj is not None else None
            if fault is not None and fault.latency_s > 0.0:
                self.skew += fault.latency_s
                if rec is not None:
                    rec.inc(f"{self.prefix}/faults_injected", kind="latency")
                    rec.observe(f"{self.prefix}/fault_latency_s", fault.latency_s)
            try:
                if fault is not None and fault.transient:
                    if rec is not None:
                        rec.inc(f"{self.prefix}/faults_injected", kind="transient")
                    raise TransientKernelError(
                        f"injected transient kernel fault (attempt {fault.attempt})",
                        attempt=fault.attempt,
                    )
                out = fn()
                if fault is not None and fault.corrupt and corrupt is not None:
                    if rec is not None:
                        rec.inc(f"{self.prefix}/faults_injected", kind="corrupt")
                    out = corrupt(out)
                if inj is not None and check is not None:
                    findings = check(out)
                    if findings:
                        raise CorruptActivationError(
                            findings,
                            attempt=fault.attempt if fault is not None else None,
                        )
                return out
            except TransientKernelError as e:
                failures += 1
                if rec is not None:
                    rec.inc(f"{self.prefix}/step_failures", kind=e.kind)
                da = pol.degrade_after
                if da is not None and not self.degraded and failures >= da:
                    self._degrade()
                    continue
                if failures <= pol.max_retries:
                    delay = pol.backoff_s * pol.backoff_factor ** (failures - 1)
                    self.skew += delay
                    if rec is not None:
                        rec.inc(f"{self.prefix}/retries")
                        rec.observe(f"{self.prefix}/retry_backoff_s", delay)
                    continue
                if da is not None and not self.degraded:
                    # Last resort before giving up: the retry budget is
                    # gone but degradation has not been tried yet.
                    self._degrade()
                    continue
                raise FaultExhaustedError(failures, e) from e

    def _degrade(self) -> None:
        self.degraded = True
        if self.injector is not None:
            self.injector.disarm()
        if self._degrade_cb is not None:
            self._degrade_cb()
        if self.recorder is not None:
            self.recorder.inc(f"{self.prefix}/degradations")
