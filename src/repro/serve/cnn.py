"""Batched CNN serving on the Phantom core: fixed-slot image batching.

Phantom plans are shape-specialised at weight-load time (the work queue's
M-tile count bakes in the batch size), so a serving engine must never change
the batch dimension between requests.  ``CnnServeEngine`` owns a fixed pool
of ``batch_size`` slots over one :class:`repro.program.PhantomProgram`:
incoming images queue up, each engine step fills every slot (padding short
batches with zero images), and the whole compiled program — every conv
through the direct implicit-im2col kernel, every FC through the block-sparse
matmul, §3.8 masks flowing between layers — runs with shapes that never
vary, so nothing recompiles after the first step.

Zero-image padding is correct because samples are independent (conv/FC act
per-row of the batch), and cheap because dead slots stay gated: the program
forward takes a ``slot_mask`` that re-zeroes padded rows after every
bias+ReLU (``relu(0 + b)`` would otherwise light them up from layer 2 on),
so their §3.8 masks gate every padded tile in the direct conv path (m-tiles
are per-sample rows) and every FC tile whose bm rows hold no live sample
(DESIGN.md §4).

Construct from a compiled (possibly :meth:`PhantomProgram.load`-restored)
program — ``CnnServeEngine(program=prog, batch_size=8)`` — so weight-load
-time lowering happens once per fleet, not once per process.  The old
``CnnServeEngine(params, layers, ...)`` form is a deprecated shim that
compiles a program on the spot.  ``serve_cnn`` is the one-shot convenience
wrapper over a list of images.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro import program as program_mod
from repro.core.dataflow import ConvSpec
from repro.core.phantom_linear import PhantomConfig

from . import faults as faults_mod
from . import policy as policy_mod

__all__ = ["CnnRequest", "CnnServeEngine", "serve_cnn"]


@dataclasses.dataclass
class CnnRequest:
    rid: int
    image: np.ndarray  # [H, W, C]
    logits: Optional[np.ndarray] = None
    done: bool = False
    t_submit: float = 0.0  # engine-clock timestamp (observability)
    #: absolute engine-clock deadline (None = no deadline, DESIGN.md §14)
    deadline: Optional[float] = None
    #: failure reason when the request was retired without completing
    error: Optional[str] = None


class CnnServeEngine:
    """Continuous batched inference over a compiled Phantom program.

    ``CnnServeEngine(program=prog, batch_size=b)`` serves ``prog`` at ``b``
    slots (lowered on first use unless already in the program's plan cache
    — e.g. restored by :meth:`PhantomProgram.load`).  The legacy
    ``CnnServeEngine(params, layers, batch_size=b, ...)`` form compiles a
    program from the loose pieces and warns ``DeprecationWarning``.
    """

    def __init__(
        self,
        params=None,
        layers=None,
        *,
        program: "program_mod.PhantomProgram | None" = None,
        batch_size: int,
        block: tuple[int, int, int] | None = None,
        conv_mode: str | None = None,
        act_threshold: float | None = None,
        interpret: bool | None = None,
        recorder=None,
        policy: "policy_mod.ServePolicy | None" = None,
    ):
        if program is None:
            if params is None or layers is None:
                raise TypeError("pass program=, or the legacy (params, layers) pair")
            program_mod.warn_deprecated(
                "CnnServeEngine(params, layers, ...)",
                "CnnServeEngine(program=phantom.compile(...), batch_size=...)",
            )
            # Explicit None checks: falsy-but-meaningful values (0.0, "", ())
            # must reach the config instead of collapsing to the defaults.
            cfg = PhantomConfig(
                enabled=True,
                block=tuple((128, 128, 128) if block is None else block),
                conv_mode="direct" if conv_mode is None else conv_mode,
                act_threshold=0.0 if act_threshold is None else act_threshold,
            )
            program = program_mod.compile(layers, params, cfg, batch=batch_size)
        elif params is not None or layers is not None:
            raise TypeError("pass either program= or (params, layers), not both")
        elif block is not None or conv_mode is not None:
            raise TypeError(
                "block/conv_mode are compile-time knobs: set them on the "
                "program's PhantomConfig, not on the engine"
            )
        self.program = program
        self.b = batch_size
        self.act_threshold = act_threshold  # None ⇒ program.cfg.act_threshold
        self.interpret = interpret
        self.recorder = recorder
        self._clock = recorder.clock if recorder is not None else time.perf_counter
        self.policy = policy
        #: the program batches actually execute on — swapped for the
        #: lookahead=0/cores=1 fallback by graceful degradation (§14)
        self._active = program
        self._rt = (
            policy_mod.PolicyRuntime(
                policy,
                clock=self._clock,
                recorder=recorder,
                prefix="serve_cnn",
                degrade=self._degrade_program,
            )
            if policy is not None
            else None
        )
        if recorder is not None and program.recorder is None:
            # Share the sink: the program's per-layer spans join the
            # engine's serving metrics on one timeline (DESIGN.md §11).
            program.recorder = recorder
        program.at_batch(batch_size)  # no-op when the plan was saved/restored
        first = program.layers[0]
        if not isinstance(first, ConvSpec):
            raise ValueError("CnnServeEngine expects a conv-first network")
        self.in_shape = (first.in_h, first.in_w, first.in_ch)
        self.queue: deque[CnnRequest] = deque()
        self._rid = itertools.count()
        self.batches_run = 0
        self.images_served = 0
        self.padded_slots = 0

    # -- client API ----------------------------------------------------------
    def _now(self) -> float:
        """Engine time: the injected clock, plus fault/backoff skew when a
        policy is active (exactly one clock read either way)."""
        return self._rt.now() if self._rt is not None else self._clock()

    def _degrade_program(self):
        """Graceful degradation: serve from the ``lookahead=0``/``cores=1``
        fallback program (bit-identical outputs by the §9/§10 parity
        contracts); ``self.program`` keeps naming the original."""
        self._active = policy_mod.fallback_program(self.program)
        self._active.recorder = self.program.recorder
        self._active.at_batch(self.b)

    def submit(self, image: np.ndarray, *, deadline_s: float | None = None) -> CnnRequest:
        image = np.asarray(image, dtype=np.float32)
        if image.shape != self.in_shape:
            if self.recorder is not None:
                self.recorder.inc("serve_cnn/rejected_shape")
            raise ValueError(f"image {image.shape} != expected {self.in_shape}")
        if deadline_s is not None and not deadline_s > 0:
            if self.recorder is not None:
                self.recorder.inc("serve_cnn/rejected_invalid_request")
            raise ValueError(
                f"deadline_s must be positive, got {deadline_s} (a "
                f"non-positive deadline is already missed at submit)"
            )
        if deadline_s is not None and self._rt is None:
            raise ValueError(
                "deadline_s requires failure semantics: construct the "
                "engine with policy=ServePolicy(...) to enable deadline "
                "enforcement (DESIGN.md §14)"
            )
        if self._rt is not None:
            self._rt.admit(len(self.queue))
        req = CnnRequest(next(self._rid), image, t_submit=self._now())
        if self._rt is not None:
            req.deadline = self._rt.resolve_deadline(deadline_s, req.t_submit)
        self.queue.append(req)
        if self.recorder is not None:
            self.recorder.inc("serve_cnn/submitted")
            self.recorder.gauge("serve_cnn/queue_depth", len(self.queue))
        return req

    def step(self) -> list[CnnRequest]:
        """Run one full batch: up to ``batch_size`` queued requests, padded
        with zero images that the slot mask keeps gated off layer to layer.

        With a policy, queued requests whose deadline has passed are failed
        (``done=False``, ``error`` set) and returned ahead of this batch —
        retired, never silently dropped."""
        expired: list[CnnRequest] = []
        if self._rt is not None:
            self._expire_overdue(expired)
        if not self.queue:
            return expired
        rec = self.recorder
        reqs = [self.queue.popleft() for _ in range(min(self.b, len(self.queue)))]
        x = np.zeros((self.b,) + self.in_shape, dtype=np.float32)
        slot = np.zeros(self.b, dtype=np.float32)
        for s, req in enumerate(reqs):
            x[s] = req.image
            slot[s] = 1.0
        if rec is not None:
            rec.gauge("serve_cnn/queue_depth", len(self.queue))
            rec.observe("serve_cnn/slot_occupancy", len(reqs) / self.b)
            sp = rec.span("serve_cnn/batch", live=len(reqs))
            sp.__enter__()

        def run_batch():
            # self._active re-read per attempt: a mid-retry degradation
            # swaps in the fallback program for the very next attempt.
            return self._active(
                jnp.asarray(x),
                slot_mask=jnp.asarray(slot),
                act_threshold=self.act_threshold,
                interpret=self.interpret,
            )

        if self._rt is None:
            logits = run_batch()
        else:
            logits = self._rt.attempt(
                run_batch,
                corrupt=faults_mod.corrupt_array,
                check=faults_mod.check_activations,
            )
        logits = np.asarray(logits)  # sync point: the batch is done here
        if rec is not None:
            sp.__exit__(None, None, None)
        for s, req in enumerate(reqs):
            req.logits = logits[s]
            req.done = True
            if rec is not None:
                t_done = self._now()
                rec.inc("serve_cnn/completed")
                rec.observe("serve_cnn/request_latency_s", t_done - req.t_submit)
                if req.deadline is not None:
                    # Completed late: keep the result, account the miss.
                    if t_done > req.deadline:
                        self._rt.record_miss(t_done - req.deadline)
                    else:
                        self._rt.record_met()
        self.batches_run += 1
        self.images_served += len(reqs)
        self.padded_slots += self.b - len(reqs)
        return expired + reqs

    def _expire_overdue(self, retired: list):
        """Fail queued requests whose deadline has passed.  Candidate scan
        first, clock read second — a no-op policy reads no extra clock, so
        it stays bit-identical to ``policy=None`` under a fake clock."""
        if not any(r.deadline is not None for r in self.queue):
            return
        now = self._rt.now()
        if not any(r.deadline is not None and now > r.deadline for r in self.queue):
            return
        keep: deque[CnnRequest] = deque()
        for req in self.queue:
            if req.deadline is not None and now > req.deadline:
                req.error = policy_mod.DEADLINE_REASON
                retired.append(req)
                self._rt.record_miss(now - req.deadline)
            else:
                keep.append(req)
        self.queue = keep

    def run(self) -> list[CnnRequest]:
        """Drain the queue; returns all completed requests in submit order."""
        finished = []
        while self.queue:
            finished.extend(self.step())
        return finished

    @property
    def degraded(self) -> bool:
        """True once graceful degradation swapped in the fallback program."""
        return self._rt is not None and self._rt.degraded

    def stats(self) -> dict:
        """The program's per-layer steps/density/valid_macs at this engine's
        batch size (DESIGN.md §5)."""
        return self.program.stats(self.b)

    # Legacy attribute surface (pre-program engines exposed these).
    @property
    def params(self):
        return self.program.params

    @property
    def layers(self):
        return self.program.layers

    @property
    def prepared(self):
        return self.program.at_batch(self.b)


def serve_cnn(
    params=None,
    layers=None,
    images=None,
    *,
    program: "program_mod.PhantomProgram | None" = None,
    batch_size: int = 4,
    block: tuple[int, int, int] | None = None,
    conv_mode: str | None = None,
    act_threshold: float | None = None,
    interpret: bool | None = None,
    recorder=None,
) -> np.ndarray:
    """One-shot batched inference: ``[N, H, W, C]`` images → ``[N, classes]``
    logits through one fixed-shape compiled program (requests beyond
    ``batch_size`` reuse the jit cache — no recompilation).
    ``act_threshold`` is the runtime τ of §3.8 (``None`` ⇒ the program
    config's τ) — the same knob :class:`CnnServeEngine` accepts.  Prefer
    ``serve_cnn(images=imgs, program=prog)``; the loose
    ``(params, layers)`` form compiles a program on the spot."""
    if images is None:
        raise TypeError("images is required")
    if program is not None:
        if params is not None or layers is not None:
            raise TypeError("pass either program= or (params, layers), not both")
        if block is not None or conv_mode is not None:
            raise TypeError(
                "block/conv_mode are compile-time knobs: set them on the "
                "program's PhantomConfig, not on serve_cnn"
            )
        eng = CnnServeEngine(
            program=program,
            batch_size=batch_size,
            act_threshold=act_threshold,
            interpret=interpret,
            recorder=recorder,
        )
    else:
        program_mod.warn_deprecated(
            "serve_cnn(params, layers, images)",
            "serve_cnn(images=..., program=phantom.compile(...))",
        )
        cfg = PhantomConfig(
            enabled=True,
            block=tuple((128, 128, 128) if block is None else block),
            conv_mode="direct" if conv_mode is None else conv_mode,
            act_threshold=0.0 if act_threshold is None else act_threshold,
        )
        eng = CnnServeEngine(
            program=program_mod.compile(layers, params, cfg, batch=batch_size),
            batch_size=batch_size,
            act_threshold=act_threshold,
            interpret=interpret,
            recorder=recorder,
        )
    reqs = [eng.submit(im) for im in images]
    eng.run()
    return np.stack([r.logits for r in reqs])
