"""Batched CNN serving on the Phantom core: fixed-slot image batching.

The Phantom conv artifacts are shape-specialised at weight-load time (the
work queue's M-tile count bakes in the batch size), so a serving engine must
never change the batch dimension between requests.  ``CnnServeEngine`` owns a
fixed pool of ``batch_size`` slots: incoming images queue up, each engine
step fills every slot (padding short batches with zero images), and the whole
prepared network — every conv through the direct implicit-im2col kernel,
every FC through the block-sparse matmul, §3.8 masks flowing between layers
— runs as one compiled program whose shapes never vary, so nothing ever
recompiles after the first step.

Zero-image padding is correct because samples are independent (conv/FC act
per-row of the batch), and cheap because dead slots stay gated: the forward
takes a ``slot_mask`` that re-zeroes padded rows after every bias+ReLU
(``relu(0 + b)`` would otherwise light them up from layer 2 on), so their
§3.8 masks gate every padded tile in the direct conv path (m-tiles are
per-sample rows) and every FC tile whose bm rows hold no live sample
(DESIGN.md §4).

``serve_cnn`` is the one-shot convenience wrapper over a list of images.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.models.cnn import cnn_forward_phantom, prepare_cnn_phantom

__all__ = ["CnnRequest", "CnnServeEngine", "serve_cnn"]


@dataclasses.dataclass
class CnnRequest:
    rid: int
    image: np.ndarray  # [H, W, C]
    logits: Optional[np.ndarray] = None
    done: bool = False


class CnnServeEngine:
    """Continuous batched inference over a prepared Phantom CNN.

    ``params``/``layers`` as in :func:`repro.models.cnn.cnn_forward`; the
    network is lowered once in the constructor for exactly ``batch_size``
    slots (``conv_mode`` selects the conv lowering, direct by default).
    """

    def __init__(
        self,
        params,
        layers,
        *,
        batch_size: int,
        block: tuple[int, int, int] = (128, 128, 128),
        conv_mode: str = "direct",
        act_threshold: float = 0.0,
        interpret: bool | None = None,
    ):
        self.params, self.layers = params, layers
        self.b = batch_size
        self.act_threshold = act_threshold
        self.interpret = interpret
        self.prepared = prepare_cnn_phantom(
            params, layers, batch_size, block=block, conv_mode=conv_mode
        )
        first = layers[0]
        self.in_shape = (first.in_h, first.in_w, first.in_ch)
        self.queue: deque[CnnRequest] = deque()
        self._rid = itertools.count()
        self.batches_run = 0
        self.images_served = 0
        self.padded_slots = 0

    # -- client API ----------------------------------------------------------
    def submit(self, image: np.ndarray) -> CnnRequest:
        image = np.asarray(image, dtype=np.float32)
        if image.shape != self.in_shape:
            raise ValueError(f"image {image.shape} != expected {self.in_shape}")
        req = CnnRequest(next(self._rid), image)
        self.queue.append(req)
        return req

    def step(self) -> list[CnnRequest]:
        """Run one full batch: up to ``batch_size`` queued requests, padded
        with zero images that the slot mask keeps gated off layer to layer."""
        if not self.queue:
            return []
        reqs = [self.queue.popleft() for _ in range(min(self.b, len(self.queue)))]
        x = np.zeros((self.b,) + self.in_shape, dtype=np.float32)
        slot = np.zeros(self.b, dtype=np.float32)
        for s, req in enumerate(reqs):
            x[s] = req.image
            slot[s] = 1.0
        logits = cnn_forward_phantom(
            self.params,
            self.prepared,
            jnp.asarray(x),
            self.layers,
            act_threshold=self.act_threshold,
            slot_mask=jnp.asarray(slot),
            interpret=self.interpret,
        )
        logits = np.asarray(logits)
        for s, req in enumerate(reqs):
            req.logits = logits[s]
            req.done = True
        self.batches_run += 1
        self.images_served += len(reqs)
        self.padded_slots += self.b - len(reqs)
        return reqs

    def run(self) -> list[CnnRequest]:
        """Drain the queue; returns all completed requests in submit order."""
        finished = []
        while self.queue:
            finished.extend(self.step())
        return finished


def serve_cnn(
    params,
    layers,
    images,
    *,
    batch_size: int = 4,
    block: tuple[int, int, int] = (128, 128, 128),
    conv_mode: str = "direct",
    interpret: bool | None = None,
) -> np.ndarray:
    """One-shot batched inference: ``[N, H, W, C]`` images → ``[N, classes]``
    logits through one fixed-shape compiled program (requests beyond
    ``batch_size`` reuse the jit cache — no recompilation)."""
    eng = CnnServeEngine(
        params,
        layers,
        batch_size=batch_size,
        block=block,
        conv_mode=conv_mode,
        interpret=interpret,
    )
    reqs = [eng.submit(im) for im in images]
    eng.run()
    return np.stack([r.logits for r in reqs])
