"""Chrome-trace (``chrome://tracing`` / Perfetto) export + schema check.

The trace format is the Trace Event Format's JSON object form:
``{"traceEvents": [...], "displayTimeUnit": "ms"}``.  We emit two phases:

* ``"X"`` — complete events (one per :meth:`repro.obs.Recorder.span`),
  requiring ``ts`` (µs since the recorder's epoch) and ``dur`` (µs);
* ``"i"`` — instant events (one per :meth:`repro.obs.Recorder.mark`).

:func:`validate_chrome_trace` is the schema check the tests gate trace
export on — it accepts exactly what Perfetto's JSON importer needs (and the
bare-array form, which the format also allows), and rejects events that
would silently drop or mis-render there (missing ``dur`` on a complete
event, negative timestamps, non-numeric fields).
"""
from __future__ import annotations

import json

__all__ = ["to_chrome_trace", "validate_chrome_trace"]

#: Phases we emit, plus the other common ones a hand-written trace may use.
_PHASES = {"X", "B", "E", "i", "I", "C", "M"}


def to_chrome_trace(events: list[dict]) -> dict:
    """Wrap raw trace events in the JSON-object container form."""
    return {"traceEvents": list(events), "displayTimeUnit": "ms"}


def validate_chrome_trace(trace) -> list[dict]:
    """Schema-check a Chrome-trace document; returns its event list.

    ``trace`` may be the JSON object form, a bare event array, or a JSON
    string of either.  Raises :class:`ValueError` on the first violation —
    the message names the offending event index and field.
    """
    if isinstance(trace, str):
        trace = json.loads(trace)
    if isinstance(trace, list):
        events = trace
    elif isinstance(trace, dict):
        if "traceEvents" not in trace:
            raise ValueError("trace object form requires a 'traceEvents' key")
        events = trace["traceEvents"]
    else:
        raise ValueError(f"trace must be an object or array, got {type(trace).__name__}")
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be an array")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            raise ValueError(f"event {i}: 'name' must be a non-empty string")
        ph = ev.get("ph")
        if ph not in _PHASES:
            raise ValueError(f"event {i} ({name!r}): bad phase {ph!r}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or isinstance(ts, bool) or ts < 0:
            raise ValueError(f"event {i} ({name!r}): 'ts' must be a number >= 0")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or isinstance(dur, bool) or dur < 0:
                raise ValueError(
                    f"event {i} ({name!r}): complete events need 'dur' >= 0"
                )
        for field in ("pid", "tid"):
            v = ev.get(field, 0)
            if not isinstance(v, int) or isinstance(v, bool):
                raise ValueError(f"event {i} ({name!r}): {field!r} must be an int")
        args = ev.get("args", {})
        if not isinstance(args, dict):
            raise ValueError(f"event {i} ({name!r}): 'args' must be an object")
        try:
            json.dumps(args)
        except TypeError as e:
            raise ValueError(
                f"event {i} ({name!r}): 'args' not JSON-serialisable: {e}"
            ) from e
    return events
