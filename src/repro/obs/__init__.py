"""Observability: the one measurement substrate (DESIGN.md §11).

    from repro import obs

    rec = obs.Recorder()
    prog = phantom.compile(layers, params, cfg, batch=8, recorder=rec)
    prog(x)
    rec.save_trace("phantom.trace.json")   # chrome://tracing / Perfetto
    print(rec.to_json())                   # counters / gauges / histograms

Everything that times or counts — the program layer's per-layer spans, the
serve engines' latency percentiles, the trainer's step timing, the
benchmark harness — goes through :class:`Recorder` / :func:`timeit` so the
numbers are warmup-aware and ``block_until_ready``-correct in exactly one
place, and every measurement is exportable as structured JSON and as a
Chrome-trace.
"""
from .recorder import Recorder, Span, timeit
from .trace import to_chrome_trace, validate_chrome_trace

__all__ = ["Recorder", "Span", "timeit", "to_chrome_trace", "validate_chrome_trace"]
