"""The measurement substrate: counters / gauges / histograms + timed spans.

Phantom's claims are *throughput* claims (paper §5: thread utilization, load
balancing), so the repo needs one trustworthy way to measure — not the three
hand-rolled ``time.perf_counter`` loops that used to live in
``benchmarks/common.py``, ``benchmarks/kernel_bench.py`` and
``train/trainer.py``.  This module is that way (DESIGN.md §11):

* :class:`Recorder` — an in-process metrics sink.  Counters accumulate
  (``inc``), gauges hold the latest value (``gauge``), histograms collect
  samples (``observe``) and report percentiles (``percentiles``: the
  p50/p95/p99 the serve engines publish).  Metrics are keyed by name plus
  optional labels (``rec.inc("serve/requests", engine="cnn")``), so one
  recorder can be shared by a program, its serve engine and a trainer
  without collisions.
* :meth:`Recorder.span` — a timing context manager.  Every span lands in a
  histogram (seconds) *and* as a Chrome-trace complete event, so the same
  measurement feeds both the JSON snapshot and ``chrome://tracing`` /
  Perfetto (:mod:`repro.obs.trace`).
* :func:`timeit` — warmup-aware, ``block_until_ready``-correct wall timing
  for benchmarks.  JAX dispatch is async: timing a call without blocking on
  its result measures dispatch, not execution, and timing the first call
  measures compilation.  ``timeit`` blocks on every result and excludes
  ``warmup`` calls from the timed window.

Determinism: every clock read goes through the recorder's (or ``timeit``'s)
injectable ``clock`` callable, so tests drive a fake clock and assert exact
durations — wall-clock flakiness never leaks into tier-1.
"""
from __future__ import annotations

import json
import time
from typing import Callable

from .trace import to_chrome_trace

__all__ = ["Recorder", "Span", "timeit"]


def _key(name: str, labels: dict) -> str:
    """Stable metric key: ``name{k=v,...}`` with labels sorted by key."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Span:
    """One timed region.  Use via ``with rec.span("layer/c1") as sp:`` —
    after exit ``sp.dur`` is the wall seconds (recorder-clock) the region
    took; the recorder has observed it into the span's histogram and emitted
    a Chrome-trace complete event for it."""

    __slots__ = ("name", "labels", "tid", "t0", "dur", "_rec")

    def __init__(self, rec: "Recorder", name: str, tid: int, labels: dict):
        self._rec = rec
        self.name = name
        self.tid = tid
        self.labels = labels
        self.t0 = 0.0
        self.dur = 0.0

    def __enter__(self) -> "Span":
        self.t0 = self._rec.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.dur = self._rec.clock() - self.t0
        self._rec._end_span(self)
        return False


class Recorder:
    """In-process metrics sink: counters, gauges, histograms, trace spans.

    ``clock`` is any zero-arg callable returning seconds (default
    ``time.perf_counter``); tests inject a fake.  ``runtime=True`` asks the
    program layer to additionally account *runtime* per-call stats
    (executed steps / utilization, DESIGN.md §10) — off by default because
    it costs a host-side pass over each layer's activation tile bits.
    """

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter,
                 runtime: bool = False):
        self.clock = clock
        self.runtime = runtime
        self.epoch = clock()  # trace timestamps are relative to this
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.hists: dict[str, list[float]] = {}
        self.events: list[dict] = []

    # -- metric primitives ---------------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels) -> float:
        k = _key(name, labels)
        self.counters[k] = self.counters.get(k, 0.0) + value
        return self.counters[k]

    def gauge(self, name: str, value: float, **labels) -> None:
        self.gauges[_key(name, labels)] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        self.hists.setdefault(_key(name, labels), []).append(float(value))

    def span(self, name: str, *, tid: int = 0, **labels) -> Span:
        """Timing context: histogram sample + Chrome-trace event on exit."""
        return Span(self, name, tid, labels)

    def mark(self, name: str, **labels) -> None:
        """Instant trace event (a point in time, e.g. a rejected request)."""
        self.events.append(
            {
                "name": name,
                "cat": "mark",
                "ph": "i",
                "ts": (self.clock() - self.epoch) * 1e6,
                "pid": 0,
                "tid": 0,
                "s": "t",
                "args": {k: _jsonable(v) for k, v in labels.items()},
            }
        )

    def _end_span(self, sp: Span) -> None:
        self.observe(sp.name, sp.dur, **sp.labels)
        self.events.append(
            {
                "name": sp.name,
                "cat": "span",
                "ph": "X",
                "ts": (sp.t0 - self.epoch) * 1e6,
                "dur": sp.dur * 1e6,
                "pid": 0,
                "tid": sp.tid,
                "args": {k: _jsonable(v) for k, v in sp.labels.items()},
            }
        )

    # -- readout -------------------------------------------------------------
    def percentiles(
        self, name: str, qs=(50, 95, 99), **labels
    ) -> dict[str, float] | None:
        """``{"p50": ..., "p95": ..., "p99": ...}`` over a histogram's
        samples (nearest-rank on the sorted samples; exact for small n).

        Returns ``None`` when the histogram has no samples (unknown name or
        observed zero times) — readout code polls histograms that may simply
        not have fired yet (a serve engine before its first request, a tuner
        with an empty shortlist), and that is an absence, not an error.
        """
        samples = sorted(self.hists.get(_key(name, labels), ()))
        if not samples:
            return None
        n = len(samples)
        out = {}
        for q in qs:
            rank = max(0, min(n - 1, int(round(q / 100 * (n - 1)))))
            out[f"p{q}"] = samples[rank]
        return out

    def snapshot(self) -> dict:
        """Structured, JSON-ready view of everything recorded so far."""
        hists = {}
        for k, v in self.hists.items():
            s = sorted(v)
            n = len(s)
            summary = {
                "count": n,
                "sum": sum(s),
                "mean": sum(s) / n,
                "min": s[0],
                "max": s[-1],
            }
            for q in (50, 95, 99):
                summary[f"p{q}"] = s[max(0, min(n - 1, int(round(q / 100 * (n - 1)))))]
            hists[k] = summary
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": hists,
        }

    def to_json(self, path: str | None = None) -> str:
        """The snapshot as a JSON string; also written to ``path`` if given."""
        text = json.dumps(self.snapshot(), indent=2, sort_keys=True) + "\n"
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def chrome_trace(self) -> dict:
        """The recorded spans/marks as a Chrome-trace (Perfetto) JSON object."""
        return to_chrome_trace(self.events)

    def save_trace(self, path: str) -> str:
        """Write :meth:`chrome_trace` to ``path``; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=2)
            f.write("\n")
        return path

    def clear(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.hists.clear()
        self.events.clear()
        self.epoch = self.clock()


def _jsonable(v):
    """Trace ``args`` must serialise: keep JSON scalars, stringify the rest
    (numpy scalars, dtypes, tuples...)."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


def timeit(
    fn,
    *args,
    reps: int = 3,
    warmup: int = 1,
    clock: Callable[[], float] = time.perf_counter,
    recorder: Recorder | None = None,
    name: str | None = None,
    **kw,
):
    """Time ``fn(*args, **kw)``: mean microseconds per call over ``reps``
    timed calls, after ``warmup`` untimed ones.

    Returns ``(out, us_per_call)`` where ``out`` is the last call's result.
    Every result is passed through ``jax.block_until_ready`` (a no-op for
    non-JAX results) so async dispatch cannot make calls look free, and the
    warmup calls absorb compile/trace time so it cannot make them look slow.
    With ``recorder=``, the per-call time is also observed into the
    histogram ``name`` (default ``fn.__qualname__``), in microseconds.
    """
    if reps < 1:
        raise ValueError(f"reps must be >= 1, got {reps}")
    if warmup < 0:
        raise ValueError(f"warmup must be >= 0, got {warmup}")
    import jax  # local: keep the obs package importable without jax loaded

    out = None
    for _ in range(warmup):
        out = jax.block_until_ready(fn(*args, **kw))
    t0 = clock()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args, **kw))
    us = (clock() - t0) / reps * 1e6
    if recorder is not None:
        recorder.observe(name or getattr(fn, "__qualname__", "timeit"), us)
    return out, us
