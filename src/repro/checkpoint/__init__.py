"""Fault-tolerant checkpointing: atomic, versioned, async, resharding restore.

* **Atomic**: writes go to ``step_<n>.tmp/`` and are ``os.rename``d into
  place only after all payloads + the manifest are flushed — a killed job
  can never leave a half-checkpoint that restore would read.
* **Versioned + latest-k**: every step directory is self-contained; retention
  keeps the newest ``keep`` checkpoints.
* **Async**: ``save(..., blocking=False)`` hands the (host-copied) arrays to
  a writer thread so the train loop is not stalled by I/O; ``wait()`` joins
  before the next save or at exit.
* **Resharding restore**: payloads are stored unsharded (np arrays); restore
  ``jax.device_put``s each leaf against the *target* sharding, so a job
  restarted on a different mesh/device count resumes bit-exactly (elastic
  scaling).  On multi-host deployments the same layout works with each host
  writing its addressable shards; noted in DESIGN.md.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "/"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = True, extra: dict | None = None):
        self.wait()
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}

        def _write():
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            manifest = {
                "step": step,
                "time": time.time(),
                "keys": sorted(flat.keys()),
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._retain()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_flat(self, step: int | None = None) -> tuple[dict, dict]:
        """Raw restore: ``(arrays, extra)`` — the flat ``{key: np.ndarray}``
        payload dict plus the manifest's ``extra`` metadata, with no target
        tree required.  Used by :meth:`repro.program.PhantomProgram.load`,
        whose tree structure lives in the metadata itself."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return arrays, manifest.get("extra", {})

    def restore(self, tree_like, step: int | None = None, shardings=None):
        """Restore into the structure of ``tree_like`` (params or abstract
        tree).  ``shardings``: matching pytree of Shardings for resharded
        placement; None → host arrays."""
        data, _ = self.restore_flat(step)
        flat_keys = list(_flatten(tree_like).keys())
        missing = [k for k in flat_keys if k not in data]
        if missing:
            raise KeyError(f"checkpoint missing keys: {missing[:5]} …")
        leaves, treedef = jax.tree.flatten(tree_like)
        shard_leaves = (
            jax.tree.flatten(shardings)[0] if shardings is not None else [None] * len(leaves)
        )
        out = []
        for key, ref, shd in zip(flat_keys, leaves, shard_leaves):
            arr = data[key]
            if hasattr(ref, "dtype"):
                arr = arr.astype(ref.dtype)
            out.append(jax.device_put(arr, shd) if shd is not None else arr)
        return jax.tree.unflatten(treedef, out)
