"""Trainer: microbatched grad accumulation, mixed precision, sharded step,
fault tolerance (async atomic checkpoints, deterministic resume), straggler
detection, optional int8-compressed cross-pod gradient reduction.

The jitted ``train_step`` is built once per (model, mesh); under a mesh the
in/out shardings come from :mod:`repro.parallel.sharding` (params 2-D
FSDP×TP, batch over the data axes) and XLA's SPMD partitioner inserts the
collectives — overlap is left to the latency-hiding scheduler, while the
framework reduces *what* must move: reduce-scattered (sharded) optimizer
states, bucketless per-tensor reductions, and the optional compressed
cross-pod path (:mod:`repro.optim.compression`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs, optim
from repro.checkpoint import CheckpointManager
from repro.models.common import set_mesh_rules
from repro.parallel import sharding as shd

__all__ = ["TrainConfig", "Trainer", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    micro_batches: int = 1  # gradient accumulation
    compress_cross_pod: bool = False
    log_every: int = 10
    ckpt_every: int = 0  # 0 → disabled
    straggler_ewma: float = 0.9
    straggler_factor: float = 2.5  # step > factor×ewma ⇒ flagged


def make_train_step(model, opt_cfg: optim.AdamWConfig, tcfg: TrainConfig, mesh=None):
    """Build the (optionally sharding-annotated) jitted train step."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if tcfg.micro_batches > 1:
            micro = jax.tree.map(
                lambda t: t.reshape(tcfg.micro_batches, -1, *t.shape[1:]), batch
            )

            def acc(carry, mb):
                (l, g) = carry
                (li, _m), gi = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (l + li, jax.tree.map(jnp.add, g, gi)), None

            zero_g = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), params)
            (l, g), _ = jax.lax.scan(acc, (jnp.zeros(()), zero_g), micro)
            n = tcfg.micro_batches
            return l / n, {}, jax.tree.map(lambda t: t / n, g)
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return l, m, g

    def step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        if tcfg.compress_cross_pod and mesh is not None and "pod" in mesh.shape:
            grads, err = optim.compressed_psum_grads(
                grads, opt_state["err_fb"], mesh
            )
            opt_state = dict(opt_state, err_fb=err)
        err_fb = opt_state.pop("err_fb", None)
        params, opt_state, om = optim.adamw_update(grads, opt_state, params, opt_cfg)
        if err_fb is not None:
            opt_state["err_fb"] = err_fb
        return params, opt_state, {"loss": loss, **metrics, **om}

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1))

    aparams = model.abstract_params()
    pspecs = shd.param_pspecs(aparams, model.axes(), mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    oshard = {
        "m": pshard,
        "v": pshard,
        "step": NamedSharding(mesh, P()),
    }
    if tcfg.compress_cross_pod and "pod" in mesh.shape:
        oshard["err_fb"] = pshard
    return jax.jit(
        step,
        in_shardings=(pshard, oshard, None),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1),
    )


class Trainer:
    def __init__(
        self,
        model,
        data,
        opt_cfg: optim.AdamWConfig,
        tcfg: TrainConfig = TrainConfig(),
        mesh=None,
        ckpt_dir: Optional[str] = None,
        recorder: Optional[obs.Recorder] = None,
    ):
        self.model, self.data, self.opt_cfg, self.tcfg = model, data, opt_cfg, tcfg
        self.mesh = mesh
        if mesh is not None:
            set_mesh_rules(mesh, shd.act_rules(mesh))
        self.step_fn = make_train_step(model, opt_cfg, tcfg, mesh)
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.start_step = 0
        self._ewma: float | None = None
        self.straggler_events = 0
        self.history: list[dict] = []
        #: step timing goes through the observability layer (DESIGN.md §11):
        #: one ``train/step`` span per step feeds both the straggler EWMA and
        #: the exportable trace/metrics; pass a shared Recorder to merge the
        #: trainer's timeline with a program/serve one.
        self.recorder = recorder if recorder is not None else obs.Recorder()

    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        opt_state = optim.init_opt_state(params)
        if self.tcfg.compress_cross_pod and self.mesh is not None and "pod" in self.mesh.shape:
            opt_state["err_fb"] = jax.tree.map(
                lambda t: jnp.zeros(t.shape, jnp.float32), params
            )
        return params, opt_state

    def maybe_restore(self, params, opt_state):
        """Deterministic resume: restore latest checkpoint (if any) and skip
        the data stream ahead — free, the pipeline is counter-based."""
        if self.ckpt and self.ckpt.latest_step() is not None:
            state = self.ckpt.restore({"params": params, "opt": opt_state})
            self.start_step = int(np.asarray(state["opt"]["step"]))
            return state["params"], state["opt"]
        return params, opt_state

    def _tick(self, dt: float):
        if self._ewma is None:
            self._ewma = dt
        alpha = self.tcfg.straggler_ewma
        if dt > self.tcfg.straggler_factor * self._ewma:
            self.straggler_events += 1  # hook: shed microbatch / re-mesh
        self._ewma = alpha * self._ewma + (1 - alpha) * dt

    def run(self, params, opt_state, n_steps: int):
        for s in range(self.start_step, self.start_step + n_steps):
            batch = {k: jnp.asarray(v) for k, v in self.data.batch(s).items()}
            with self.recorder.span("train/step") as sp:
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                # float() blocks on the step's outputs, so the span measures
                # execution, not async dispatch (same sync point the old
                # hand-rolled perf_counter loop relied on).
                metrics = {k: float(v) for k, v in metrics.items()}
            self._tick(sp.dur)
            self.history.append({"step": s, **metrics})
            if self.ckpt and self.tcfg.ckpt_every and (s + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(
                    int(np.asarray(opt_state["step"])),
                    {"params": params, "opt": opt_state},
                    blocking=False,
                )
        if self.ckpt:
            self.ckpt.wait()
        return params, opt_state
