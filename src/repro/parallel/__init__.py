"""Distribution substrate: sharding rules, pipeline parallelism, collectives."""
