"""Pipeline parallelism: microbatched GPipe/1F1B schedule over a 'stage' axis.

Layers are split into contiguous stage groups; inside ``shard_map`` each
stage loops over ``n_micro + n_stages − 1`` ticks, receiving activations
from the previous stage via ``jax.lax.ppermute`` (the TPU-native neighbour
collective), running its layer group, and forwarding.  The steady state is
the standard pipeline diagonal; bubbles = ``(n_stages − 1) / ticks``.

Differentiable end-to-end (ppermute has a transpose rule), so ``jax.grad``
through ``pipeline_apply`` yields 1F1B-equivalent backward scheduling from
XLA's perspective.  Used when ``pipeline_stages > 1``; exercised by tests on
a fake 4-device mesh and composable with the DP/TP axes of the production
mesh (the 'stage' axis is appended by ``make_production_mesh`` when
requested).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_apply", "split_stages"]


def split_stages(stacked_params, n_stages: int):
    """[L, ...] stacked layer params → [S, L/S, ...] stage-major."""
    return jax.tree.map(
        lambda t: t.reshape(n_stages, t.shape[0] // n_stages, *t.shape[1:]),
        stacked_params,
    )


def pipeline_apply(
    block_fn: Callable,  # (layer_params, x) -> x
    staged_params,  # [S, L/S, ...] (sharded over the 'stage' axis)
    x_micro: jnp.ndarray,  # [n_micro, mb, ...] microbatched input
    mesh: Mesh,
    axis: str = "stage",
):
    """Run the pipeline; returns [n_micro, mb, ...] outputs (from the last
    stage, rotated back to global order)."""
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def stage_program(params_local, x_local):
        # params_local: [1, L/S, ...]; x_local: [n_micro, mb, ...] (same copy
        # everywhere — only stage 0 consumes it).
        params_local = jax.tree.map(lambda t: t[0], params_local)
        stage_id = jax.lax.axis_index(axis)
        mb_shape = x_local.shape[1:]
        carry = jnp.zeros(mb_shape, x_local.dtype)
        outputs = jnp.zeros((n_micro, *mb_shape), x_local.dtype)

        def run_block(x):
            def body(h, layer_params):
                return block_fn(layer_params, h), None

            h, _ = jax.lax.scan(body, x, params_local)
            return h

        def tick(t, state):
            carry, outputs = state
            # Stage 0 injects microbatch t; others take the permuted carry.
            inject = jax.lax.dynamic_index_in_dim(
                x_local, jnp.clip(t, 0, n_micro - 1), keepdims=False
            )
            x_in = jnp.where(stage_id == 0, inject, carry)
            y = run_block(x_in)
            # Last stage records microbatch (t - n_stages + 1).
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            record = (stage_id == n_stages - 1) & (t >= n_stages - 1)
            outputs = jax.lax.cond(
                record,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
                lambda o: o,
                outputs,
            )
            # Forward to the next stage (ring; the wraparound write is dead).
            carry = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return carry, outputs

        carry, outputs = jax.lax.fori_loop(0, ticks, tick, (carry, outputs))
        # Broadcast the last stage's outputs to every stage shard (masked
        # psum — only the last stage holds non-zero results).
        outputs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outputs, 0.0), axis
        )
        return outputs

    in_specs = (
        jax.tree.map(lambda _: P(axis), staged_params),
        P(),  # microbatches replicated across stages
    )
    fn = shard_map(
        stage_program, mesh=mesh, in_specs=in_specs, out_specs=P(), check_rep=False
    )
    return fn(staged_params, x_micro)
