"""Logical-axis sharding rules → mesh PartitionSpecs (DP/TP/EP/SP/FSDP).

One rule table drives everything.  Each *logical* axis carries a priority
list of mesh axes; per tensor, resolution walks the dims left→right and
claims the first mesh axis that (a) is still unclaimed within that tensor
and (b) divides the dim — so e.g. grok-1's 8 experts silently fall back from
EP to replication while its 32768-wide FFN still takes the TP axis, and a
batch of 1 (long_500k) falls back from DP to sequence sharding.  Fallbacks
are *by construction*, not special cases, and the dry-run exercises all of
them.

Weight rules give 2-D sharding (FSDP over 'data' × TP over 'model') so even
grok-1-314b fits per-chip HBM; activations shard batch over ('pod','data')
and model-parallel dims over 'model'; decode KV caches shard their sequence
dim over 'model' (sequence parallelism) since a single decode token cannot
use TP on its own.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "PARAM_RULES",
    "act_rules",
    "param_pspecs",
    "param_shardings",
    "batch_pspecs",
    "cache_pspecs",
    "resolve_tensor",
    "compat_make_mesh",
    "cores_mesh",
    "shard_cores_call",
    "run_cores_call",
]


def compat_make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` across jax versions: pass explicit Auto axis types
    only where the installed jax has them (≥0.5); older versions treat all
    axes as Auto implicitly."""
    kw = {} if devices is None else {"devices": devices}
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)


# -- Phantom multi-core → device mesh (DESIGN.md §9) -------------------------


@functools.lru_cache(maxsize=None)
def cores_mesh(cores: int) -> Optional[Mesh]:
    """A 1-axis ``('cores',)`` device mesh for a ``cores``-way Phantom
    artifact, or ``None`` when the cores axis should stay a sequential grid
    dimension (single device, or the device count does not divide the core
    count — per-core queues are identical either way, so the numerics do not
    depend on which path runs).  Cached per core count: the device set is
    fixed for the process and this sits on the per-layer serving hot path."""
    devs = jax.devices()
    if len(devs) > 1 and cores % len(devs) == 0:
        return compat_make_mesh((len(devs),), ("cores",))
    return None


def shard_cores_call(mesh: Mesh, call, replicated: tuple, per_core: tuple):
    """Map the leading cores axis of a multi-core Phantom kernel call onto
    ``mesh``'s ``'cores'`` device axis via ``shard_map``.

    ``replicated`` (the shared activation + the packed weight payload) goes
    to every device; each ``per_core`` array (the [cores, Qpad] queues) is
    split on its leading axis, so a device runs the same ``pallas_call`` on
    its ``cores / n_devices`` local queues and the outputs' leading cores
    axis concatenates back.  Replicating the payload trades HBM for
    simplicity — per-core payload slabs are a follow-up optimisation noted
    in DESIGN.md §9.
    """
    from jax.experimental.shard_map import shard_map

    f = shard_map(
        lambda *args: call(*args),
        mesh=mesh,
        in_specs=(P(),) * len(replicated) + (P("cores"),) * len(per_core),
        out_specs=P("cores"),
        check_rep=False,
    )
    return f(*replicated, *per_core)


def run_cores_call(call, replicated: tuple, per_core: tuple, cores: int):
    """Dispatch one multi-core kernel invocation: over the ``('cores',)``
    device mesh when one is available, else as a single sequential-grid
    ``pallas_call`` — the shared entry point of the spmm and direct-conv
    multi-core runtimes."""
    mesh = cores_mesh(cores)
    if mesh is None:
        return call(*replicated, *per_core)
    return shard_cores_call(mesh, call, replicated, per_core)

# logical axis → priority list of mesh axes (first fit wins)
PARAM_RULES: dict = {
    "embed": ("data",),  # FSDP: weights gathered per layer, sharded at rest
    "mlp": ("model",),
    "kv": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "heads": ("model",),
    "layers": (),
    None: (),
}

ACT_RULES: dict = {
    "batch": (("pod", "data"), ("data",)),
    "seq": (),
    "embed": (),
    "mlp": ("model",),
    "kv": ("model",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": (),
    "expert": ("model",),
    "kv_seq": ("model",),
    None: (),
}


def _axis_size(mesh: Mesh, ax) -> int:
    if isinstance(ax, tuple):
        return math.prod(mesh.shape[a] for a in ax)
    return mesh.shape[ax]


def resolve_tensor(shape, axes, mesh: Mesh, rules: dict) -> P:
    """Per-tensor resolution with divisibility + claimed-axis fallback."""
    claimed: set = set()
    spec = []
    for dim, ax in zip(shape, axes):
        choice = None
        for cand in rules.get(ax, ()):  # priority list
            flat = cand if isinstance(cand, tuple) else (cand,)
            if any(a in claimed for a in flat):
                continue
            if all(a in mesh.shape for a in flat) and dim % _axis_size(mesh, cand) == 0:
                choice = cand
                claimed.update(flat)
                break
        spec.append(choice)
    return P(*spec)


def act_rules(mesh: Mesh) -> dict:
    """Flat rules for shard_act (first applicable candidate per call site)."""
    out = {}
    for k, cands in ACT_RULES.items():
        out[k] = None
        for cand in cands:
            flat = cand if isinstance(cand, tuple) else (cand,)
            if all(a in mesh.shape for a in flat):
                out[k] = cand
                break
    return out


def param_pspecs(abstract_params, axes_tree, mesh: Mesh) -> dict:
    """PartitionSpec tree aligned with the parameter pytree."""
    return jax.tree.map(
        lambda a, ax: resolve_tensor(a.shape, ax, mesh, PARAM_RULES),
        abstract_params,
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def param_shardings(abstract_params, axes_tree, mesh: Mesh):
    specs = param_pspecs(abstract_params, axes_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def _batch_axes(mesh: Mesh, batch: int):
    for cand in ACT_RULES["batch"]:
        flat = cand if isinstance(cand, tuple) else (cand,)
        if all(a in mesh.shape for a in flat) and batch % _axis_size(mesh, cand) == 0:
            return cand
    return None


def batch_pspecs(specs: dict, mesh: Mesh) -> dict:
    """Input shardings for a train/prefill batch of ShapeDtypeStructs:
    leading dim over the data axes (when divisible), rest replicated."""

    def one(leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return P()
        b = leaf.shape[0]
        ba = _batch_axes(mesh, b)
        return P(ba, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(one, specs)


def cache_pspecs(cache_specs, mesh: Mesh) -> dict:
    """Decode-cache shardings: [L, B, S, ...] — batch over data axes when
    divisible, else the sequence dim over 'model' ∪ 'data' (SP decode for
    global_batch=1 long-context)."""

    def one(leaf):
        shape = leaf.shape
        if len(shape) < 3:
            return P()
        _, b, s = shape[0], shape[1], shape[2]
        ba = _batch_axes(mesh, b)
        spec = [None, ba]
        # Sequence dim (KV cache / conv state): shard over 'model'; if batch
        # could not shard, also claim the data axes for S.
        seq_ax: Optional[tuple] = None
        if "model" in mesh.shape and s % mesh.shape["model"] == 0:
            seq_ax = "model"
            if ba is None:
                for cand in (("pod", "data", "model"), ("data", "model")):
                    if all(a in mesh.shape for a in cand) and s % _axis_size(
                        mesh, cand
                    ) == 0:
                        seq_ax = cand
                        break
        spec.append(seq_ax)
        spec.extend([None] * (len(shape) - 3))
        return P(*spec)

    return jax.tree.map(one, cache_specs)
