"""Deterministic, shard-aware synthetic token pipeline.

Counter-based RNG (Philox) keyed on ``(seed, step, shard)`` makes every batch
a pure function of the step index: **skip-ahead is O(1)** (deterministic
resume after checkpoint restore needs no replay) and any host can
regenerate any shard (elastic re-sharding after failures).

Sequences follow a noisy affine-recurrence language — x[t+1] =
(a·x[t] + b + ε) mod V with ε sparse — so a real model's loss demonstrably
falls during the end-to-end training example, while generation stays O(1)
per batch.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["DataConfig", "SyntheticTokens"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05  # fraction of random transitions
    n_shards: int = 1  # data-loading hosts
    shard: int = 0


class SyntheticTokens:
    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_shards:
            raise ValueError("global_batch must divide across data shards")
        self.cfg = cfg
        self._local = cfg.global_batch // cfg.n_shards

    def _rng(self, step: int) -> np.random.Generator:
        c = self.cfg
        return np.random.Generator(
            np.random.Philox(key=c.seed, counter=[step, c.shard, 0, 0])
        )

    def batch(self, step: int) -> dict:
        """Tokens + next-token labels for ``step`` (this shard's slice)."""
        c = self.cfg
        rng = self._rng(step)
        b, s, v = self._local, c.seq_len, c.vocab
        a = 31
        bias = rng.integers(1, v, size=(b, 1))
        x = np.empty((b, s + 1), dtype=np.int64)
        x[:, 0] = rng.integers(0, v, size=b)
        noise = rng.random((b, s)) < c.noise
        rand = rng.integers(0, v, size=(b, s))
        for t in range(s):
            nxt = (a * x[:, t] + bias[:, 0]) % v
            x[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {
            "tokens": x[:, :-1].astype(np.int32),
            "labels": x[:, 1:].astype(np.int32),
        }

    def frontend_batch(self, step: int, d_model: int, frontend_len: int) -> np.ndarray:
        """Stub modality frontend: deterministic pseudo-embeddings."""
        rng = self._rng(step)
        return rng.standard_normal(
            (self._local, frontend_len, d_model), dtype=np.float32
        ) * 0.02
