"""TPU adaptation of the Phantom scheduling algorithm (DESIGN.md §2).

The paper's datapath (scalar multiplier threads fed by a selector) has no TPU
analogue — the MXU wants dense 128-aligned tiles.  What transfers is the
*scheduling*: keep sparsity metadata as cheap binary masks, AND the two
sides' masks to enumerate effectual work, compact that work onto the compute
resource, and balance it at two levels.  Here:

* element sparse mask  → **block mask** over MXU-aligned (bm×bk)/(bk×bn)
  tiles (``BlockMask``),
* LAM (mask AND)       → ``effectual_tiles``: AND of the activation tile mask
  with the weight tile mask per output tile,
* TDS compaction       → ``WorkQueue``: a dense, k-major list of effectual
  (mi, ki, ni) tile triples consumed by a ``pallas_call`` grid via scalar
  prefetch — zero weight tiles never enter VMEM and never occupy a grid
  step,
* inter-core balancing → ``balance_columns``: density-sorted LPT assignment
  of output tile-columns to parallel shards (TP) using weight-mask popcounts
  only, exactly the paper's on-the-fly broadcast ordering (§4.3.1),
* intra-core balancing → ``interleave_queue``: round-robin rotation of work
  so consecutive grid steps draw from different tile-columns, evening the
  per-step accumulation pressure (§4.6),
* output encoding      → ``activation_block_mask`` threshold epilogue: the
  producing layer emits the next layer's activation tile mask (§3.8).

Static weight sparsity is compacted *exactly* (queue built at weight-load
time, like the paper's offline-free balancing).  Dynamic activation sparsity
is handled by in-kernel gating on the prefetched activation tile mask —
TPU grids are static, so a zero activation tile still occupies a grid step
but skips its MXU op (and, with an unchanged index map, its HBM→VMEM copy).
This asymmetry vs. the paper (which skips both sides for free) is recorded
in DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "BlockMask",
    "WorkQueue",
    "ConvWorkQueue",
    "block_mask_from_dense",
    "activation_block_mask_np",
    "build_work_queue",
    "build_conv_work_queue",
    "balance_columns",
    "partition_columns",
    "check_balance",
    "balance_interleaves",
    "BALANCE_MODES",
    "pack_blocks",
    "effectual_tiles",
]


@dataclasses.dataclass(frozen=True)
class BlockMask:
    """Binary mask over (bm × bn) tiles of a 2-D operand (host-side)."""

    mask: np.ndarray  # bool [Mt, Nt]
    block: tuple[int, int]
    shape: tuple[int, int]  # unpadded element shape

    @property
    def density(self) -> float:
        return float(self.mask.mean()) if self.mask.size else 0.0

    @property
    def nnz_blocks(self) -> int:
        return int(self.mask.sum())


def _tiles(n: int, b: int) -> int:
    return math.ceil(n / b)


def block_mask_from_dense(w: np.ndarray, block: tuple[int, int]) -> BlockMask:
    """Tile-level any-nonzero reduction of a dense (possibly pruned) matrix."""
    w = np.asarray(w)
    m, n = w.shape
    bm, bn = block
    mt, nt = _tiles(m, bm), _tiles(n, bn)
    wp = np.zeros((mt * bm, nt * bn), dtype=bool)
    wp[:m, :n] = w != 0
    mask = wp.reshape(mt, bm, nt, bn).any(axis=(1, 3))
    return BlockMask(mask=mask, block=block, shape=(m, n))


def activation_block_mask_np(x: np.ndarray, block: tuple[int, int], threshold: float = 0.0) -> BlockMask:
    """Dynamic activation tile mask: tile kept iff ``any(|x| > τ)`` (τ=0 keeps
    exact-zero semantics — the ReLU case; τ>0 is the lossy serving knob)."""
    x = np.asarray(x)
    m, n = x.shape
    bm, bn = block
    mt, nt = _tiles(m, bm), _tiles(n, bn)
    xp = np.zeros((mt * bm, nt * bn), dtype=x.dtype)
    xp[:m, :n] = x
    mask = (np.abs(xp) > threshold).reshape(mt, bm, nt, bn).any(axis=(1, 3))
    return BlockMask(mask=mask, block=block, shape=(m, n))


def effectual_tiles(act_mask: np.ndarray, w_mask: np.ndarray) -> np.ndarray:
    """LAM analogue: effectual (mi, ki, ni) ⇔ act[mi,ki] ∧ w[ki,ni].

    Returns a boolean [Mt, Kt, Nt] tensor — the paper's AND masks at tile
    granularity.
    """
    a = np.asarray(act_mask, dtype=bool)
    w = np.asarray(w_mask, dtype=bool)
    return a[:, :, None] & w[None, :, :]


@dataclasses.dataclass(frozen=True)
class WorkQueue:
    """Dense, k-major queue of effectual tiles for the Pallas grid.

    ``mi/ni/ki``: int32 [Q] tile indices; ``start``: 1 where a (mi, ni)
    accumulation chain begins (zero-init the accumulator), ``last``: 1 where
    it ends (cast + write out).  ``wq``: packed-weight block id per step.
    Output tiles with *no* effectual k-work are listed in ``empty_out``
    (their result is exactly zero — the §3.8 output-encoding case).
    """

    mi: np.ndarray
    ni: np.ndarray
    ki: np.ndarray
    wq: np.ndarray
    start: np.ndarray
    last: np.ndarray
    empty_out: np.ndarray  # int32 [E, 2] (mi, ni)
    grid_tiles: tuple[int, int, int]  # (Mt, Kt, Nt)

    @property
    def steps(self) -> int:
        return int(self.mi.shape[0])

    def compaction_ratio(self) -> float:
        mt, kt, nt = self.grid_tiles
        dense = mt * kt * nt
        return self.steps / dense if dense else 1.0


def build_work_queue(
    w_bmask: np.ndarray,
    m_tiles: int,
    *,
    interleave: bool = True,
) -> WorkQueue:
    """TDS analogue: compact the static weight-side work into a dense queue.

    ``w_bmask``: bool [Kt, Nt].  Every output tile (mi, ni) gets a k-major
    run over the ki with ``w_bmask[ki, ni]`` set.  ``interleave`` applies the
    intra-core-style rotation: output tile-columns are visited round-robin
    sorted by density so no long run of heavy columns monopolises the tail
    (§4.6 analogue; order within a (mi, ni) run is preserved — accumulation
    correctness does not depend on inter-run order).
    """
    w = np.asarray(w_bmask, dtype=bool)
    kt, nt = w.shape
    # Packed-weight block ids in (ni-major, ki) order — must match pack_blocks.
    wq_id = np.full((kt, nt), -1, dtype=np.int32)
    wq_id.T[w.T] = np.arange(int(w.sum()), dtype=np.int32)

    col_k = [np.flatnonzero(w[:, ni]).astype(np.int32) for ni in range(nt)]
    col_order = np.arange(nt)
    if interleave:
        # Heavy and light columns alternate (densest first, then lightest, …)
        dens = np.array([len(c) for c in col_k])
        srt = np.argsort(-dens, kind="stable")
        half = (nt + 1) // 2
        inter = np.empty(nt, dtype=int)
        inter[0::2] = srt[:half]
        inter[1::2] = srt[half:][::-1]
        col_order = inter

    mi_l, ni_l, ki_l, wq_l, st_l, la_l = [], [], [], [], [], []
    empty = []
    for mi in range(m_tiles):
        for ni in col_order:
            ks = col_k[ni]
            if ks.size == 0:
                empty.append((mi, ni))
                continue
            n_run = ks.size
            mi_l.append(np.full(n_run, mi, dtype=np.int32))
            ni_l.append(np.full(n_run, ni, dtype=np.int32))
            ki_l.append(ks)
            wq_l.append(wq_id[ks, ni])
            s = np.zeros(n_run, dtype=np.int32)
            s[0] = 1
            e = np.zeros(n_run, dtype=np.int32)
            e[-1] = 1
            st_l.append(s)
            la_l.append(e)
    cat = lambda xs: (
        np.concatenate(xs) if xs else np.zeros((0,), dtype=np.int32)
    )
    return WorkQueue(
        mi=cat(mi_l),
        ni=cat(ni_l),
        ki=cat(ki_l),
        wq=cat(wq_l),
        start=cat(st_l),
        last=cat(la_l),
        empty_out=np.asarray(empty, dtype=np.int32).reshape(-1, 2),
        grid_tiles=(m_tiles, kt, nt),
    )


@dataclasses.dataclass(frozen=True)
class ConvWorkQueue(WorkQueue):
    """Work queue whose k-tiles carry conv spatial coordinates.

    For the direct (implicit-im2col) conv lowering the K dimension is tiled
    per filter tap: flat k index ``(ky·kw + kx)·ct + ci`` where ``ct`` is the
    number of Cin blocks.  Each step therefore knows *where* in the padded
    activation its (bm, bk) tile lives — ``ky``/``kx`` are the filter-window
    offsets and ``ci`` the input-channel block — so the kernel's
    scalar-prefetch index maps can place the tile at its strided source
    location and the patch matrix is never materialised.
    """

    ky: np.ndarray = None  # int32 [Q] filter-row of the step's k-tile
    kx: np.ndarray = None  # int32 [Q] filter-col
    ci: np.ndarray = None  # int32 [Q] Cin-block index


def build_conv_work_queue(
    w_bmask: np.ndarray,
    m_tiles: int,
    *,
    kw: int,
    ct: int,
    interleave: bool = True,
) -> ConvWorkQueue:
    """Compact a tap-aligned conv weight mask into a coordinate-carrying queue.

    ``w_bmask``: bool [kh·kw·ct, Nt] over the tap-aligned ``[kh·kw·ct·bk, N]``
    weight matrix (each (ky, kx) channel segment padded to ``ct`` full bk
    blocks, so no k-tile straddles a filter-tap boundary).  The base queue is
    identical to :func:`build_work_queue`; the spatial coordinates are the
    k-index decomposition ``ki = (ky·kw + kx)·ct + ci``.
    """
    q = build_work_queue(w_bmask, m_tiles, interleave=interleave)
    ky = q.ki // (kw * ct)
    kx = (q.ki // ct) % kw
    ci = q.ki % ct
    return ConvWorkQueue(
        **{f.name: getattr(q, f.name) for f in dataclasses.fields(WorkQueue)},
        ky=ky.astype(np.int32),
        kx=kx.astype(np.int32),
        ci=ci.astype(np.int32),
    )


def pack_blocks(w: np.ndarray, w_bmask: np.ndarray, block: tuple[int, int]) -> np.ndarray:
    """Pack the kept (bk × bn) weight tiles into ``[nnzb, bk, bn]``, in
    (ni-major, ki) order — the sparse-mask storage of §3.1 at tile
    granularity (mask + packed payload, no pointer arrays)."""
    bk, bn = block
    kt, nt = np.asarray(w_bmask).shape
    wp = np.zeros((kt * bk, nt * bn), dtype=w.dtype)
    wp[: w.shape[0], : w.shape[1]] = w
    out = []
    for ni in range(nt):
        for ki in range(kt):
            if w_bmask[ki, ni]:
                out.append(wp[ki * bk : (ki + 1) * bk, ni * bn : (ni + 1) * bn])
    if not out:
        return np.zeros((1, bk, bn), dtype=w.dtype)  # dummy block (never read)
    return np.stack(out)


def balance_columns(
    w_bmask: np.ndarray,
    n_shards: int,
    *,
    capacity: int | None = None,
    as_buckets: bool = False,
):
    """Inter-core balancing analogue (§4.3.1): assign output tile-columns to
    ``n_shards`` shards so each receives near-equal effectual work,
    densest-first to the least-loaded shard (LPT on weight-mask popcounts —
    the paper's "low latency, more dense" broadcast order, no offline pass).

    ``capacity`` caps how many columns a shard may take; the default
    ``ceil(nt / n_shards)`` keeps shard widths equal — the TPU adaptation's
    constraint that every core's output slab has the same padded tile width
    (so the cores axis shards evenly over a device mesh).  The tie-breaking
    (stable densest-first order, first least-loaded shard) is exactly
    :func:`repro.core.balance.inter_core_schedule` with the same capacity —
    the engine↔simulator balancing contract (DESIGN.md §5, §9).

    Returns the flat column permutation (shard-major; apply to the N axis of
    the weight *before* sharding, the inverse to the output), or the per-shard
    column lists when ``as_buckets`` is set.
    """
    w = np.asarray(w_bmask, dtype=bool)
    nt = w.shape[1]
    cap = math.ceil(nt / n_shards) if capacity is None else int(capacity)
    if cap * n_shards < nt:
        raise ValueError(
            f"capacity {cap} × {n_shards} shards cannot hold {nt} columns"
        )
    dens = w.sum(axis=0)
    order = np.argsort(-dens, kind="stable")
    load = np.zeros(n_shards)
    buckets: list[list[int]] = [[] for _ in range(n_shards)]
    for c in order:
        elig = [s for s in range(n_shards) if len(buckets[s]) < cap]
        s = min(elig, key=lambda s: load[s])
        buckets[s].append(int(c))
        load[s] += dens[c]
    if as_buckets:
        return [np.asarray(b, dtype=np.int64) for b in buckets]
    perm = [c for b in buckets for c in b]
    return np.asarray(perm, dtype=np.int64)


BALANCE_MODES = ("none", "intra", "inter", "full")


def check_balance(balance: str) -> str:
    """Validate a balance policy name (raises on typos up front — a silent
    fallthrough would just drop the balancing the user asked for)."""
    if balance not in BALANCE_MODES:
        raise ValueError(
            f"balance must be one of {'|'.join(BALANCE_MODES)}, got {balance!r}"
        )
    return balance


def balance_interleaves(balance: str) -> bool:
    """Whether a balance policy enables the §4.6 intra-core-style queue
    rotation — the one definition both lowerings (FC and conv) gate their
    ``interleave`` knob on."""
    return check_balance(balance) in ("intra", "full")


def partition_columns(
    w_bmask: np.ndarray, cores: int, balance: str
) -> list[np.ndarray]:
    """Bucket output tile-columns onto ``cores`` virtual cores (§4.2).

    ``balance`` in ``{"inter", "full"}`` uses the densest-first LPT of
    :func:`balance_columns`; ``{"none", "intra"}`` is the naive baseline —
    columns in natural order, round-robin across cores (core ``c`` takes
    columns ``c, c + cores, ...``), matching the dispatch order of
    ``inter_core_schedule(balanced=False)``.  Every bucket holds at most
    ``ceil(nt / cores)`` columns so core output slabs stay width-equal.
    """
    check_balance(balance)
    nt = np.asarray(w_bmask).shape[1]
    if balance in ("inter", "full"):
        return balance_columns(w_bmask, cores, as_buckets=True)
    return [np.arange(c, nt, cores, dtype=np.int64) for c in range(cores)]
