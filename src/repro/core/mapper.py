"""Thread Mapper (paper §3.5) and L1-adder configuration codes (§3.6).

The mapper turns a TDS selection (a set of entries whose combined popcount
fits the PE's multiplier threads) into per-thread operand assignments plus the
2-bit L1 adder configuration.  For the canonical 3-thread PE the codes are:

  C1 ``00`` — pass all thread outputs individually   (groups 1/1/1 or fewer)
  C2 ``01`` — add th0+th1, pass th2                  (groups 2,1)
  C3 ``10`` — pass th0, add th1+th2                  (groups 1,2)
  C4 ``11`` — add all three                          (group 3)

The module also carries the mapper-memory cost model behind the paper's two
claims: storing only combinations with ≤ ``threads`` ones cuts the table from
512 to 130 entries (74%), and reusing a single mapper serially ``pes`` times
cuts memory by a further ~66% at a cost of ``pes - 1`` fill cycles.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "ThreadMap",
    "map_selection",
    "l1_config",
    "mapper_table_entries",
    "mapper_memory_bytes",
    "MAPPER_REUSE_LATENCY",
]

# Serial reuse of one mapper across the PEs costs pes-1 pipeline-fill cycles
# (paper: "only incurs an initial latency of 2 cycles" for pes=3).
MAPPER_REUSE_LATENCY = lambda pes: pes - 1  # noqa: E731


@dataclasses.dataclass(frozen=True)
class ThreadMap:
    """One PE-cycle worth of mapped work.

    ``assignments[t]`` is ``(entry_id, bit_index)`` for thread ``t`` or
    ``None`` for an idle thread; ``groups`` are the per-entry thread counts
    (contiguous), and ``config`` the L1 adder code.
    """

    assignments: tuple
    groups: tuple[int, ...]
    config: int


def l1_config(groups: tuple[int, ...], threads: int = 3) -> int:
    """L1 adder code for a contiguous thread partition (3-thread PE)."""
    if threads != 3:
        # Generalised PEs use a one-hot boundary code: bit i set ⇔ threads i
        # and i+1 belong to the same entry (adder chain segment).
        code = 0
        pos = 0
        for g in groups:
            for k in range(g - 1):
                code |= 1 << (pos + k)
            pos += g
        return code
    nz = tuple(g for g in groups if g > 0)
    if nz == (3,):
        return 0b11
    if nz == (2, 1) or nz == (2,):
        return 0b01
    if nz == (1, 2):
        return 0b10
    return 0b00  # 1/1/1, 1/1, 1 or empty — pass-through


def map_selection(
    entry_ids: list[int], entry_bits: list[np.ndarray], threads: int = 3
) -> ThreadMap:
    """Pack selected entries' set bits onto threads, contiguously, in order."""
    assignments: list = []
    groups: list[int] = []
    for eid, bits in zip(entry_ids, entry_bits):
        idxs = np.flatnonzero(np.asarray(bits, dtype=bool))
        groups.append(len(idxs))
        for b in idxs:
            assignments.append((eid, int(b)))
    if len(assignments) > threads:
        raise ValueError("selection exceeds multiplier-thread capacity")
    while len(assignments) < threads:
        assignments.append(None)
    return ThreadMap(
        assignments=tuple(assignments),
        groups=tuple(groups),
        config=l1_config(tuple(groups), threads),
    )


def mapper_table_entries(pes: int, threads: int) -> int:
    """Stored map combinations: ≤ ``threads`` ones out of ``pes×threads`` bits
    (paper: C(9,0)+C(9,1)+C(9,2)+C(9,3) = 130 of 512, a 74% reduction)."""
    n = pes * threads
    return sum(math.comb(n, k) for k in range(threads + 1))


def mapper_memory_bytes(
    pes: int, threads: int, *, reuse_single_mapper: bool = True, entry_bits: int = 50
) -> int:
    """Mapper SRAM bytes; single-mapper reuse divides the footprint by ``pes``
    (paper: 2.5 kB → 0.83 kB)."""
    entries = mapper_table_entries(pes, threads)
    mappers = 1 if reuse_single_mapper else pes
    return math.ceil(entries * entry_bits * mappers / 8)
