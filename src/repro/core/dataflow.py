"""Phantom-2D dataflows (paper §4): mapping CNN layers onto the R×C matrix.

The compute unit is an ``R × C`` matrix of Phantom cores plus ``R`` adders for
channel accumulation (§4.1) and L3 adders for column accumulation (§4.4–4.5).
Design choices follow the paper: ``C = 4`` (channel counts are multiples of
4), ``R = 7`` (spatial sizes are multiples of 7).

Per-layer dataflows (each returns the *work decomposition*: for every core, a
stream of TDS entry popcounts, plus the broadcast/round structure that the
inter-core balancer schedules):

* **regular / depthwise convolution** (§4.3, Fig. 15): output rows are split
  into ``R`` bands; filters (regular) or channels (depthwise) go along the
  ``C`` columns; every column processes the same filter at a given time, so
  filter broadcasts are the inter-core balancing unit.  Non-unit strides use
  the same flow (goal G3 — SCNN cannot run these).
* **pointwise convolution** (§4.4, Fig. 16): filters along the ``R`` rows,
  input channels split into batches of ``pes × threads = 9`` along the
  columns; L3 adders accumulate partials across columns.
* **FC** (§4.5, Fig. 17): input vector stationary across rows, weight vectors
  swept; channels again split into batches of 9 along columns.

Everything here is mask-level only — values never enter the simulator; the
functional engine (:mod:`repro.core.engine`) is what proves numerics.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "Phantom2DConfig",
    "ConvSpec",
    "FCSpec",
    "CoreWork",
    "LayerWork",
    "Sampling",
    "conv_work",
    "pointwise_work",
    "fc_work",
    "layer_work",
    "im2col_mask",
]


@dataclasses.dataclass
class Sampling:
    """Work subsampling for full-network simulation (paper §5.2.2 subsamples
    ~25% of channel filters the same way).  Cycle counts are scaled back by
    the sampled fraction: jobs via ``LayerWork.job_scale``, queue entries via
    ``CoreWork.scale``."""

    job_frac: float = 1.0
    max_jobs: int | None = None
    max_entries: int | None = None
    rng: object = None  # np.random.Generator

    def pick_jobs(self, n: int) -> tuple[list[int], float]:
        target = n
        if self.job_frac < 1.0:
            target = max(1, int(math.ceil(n * self.job_frac)))
        if self.max_jobs is not None:
            target = min(target, self.max_jobs)
        if target >= n:
            return list(range(n)), 1.0
        rng = self.rng or np.random.default_rng(0)
        idx = np.sort(rng.choice(n, size=target, replace=False))
        return [int(i) for i in idx], n / target

    def entry_slice(self, n_entries: int, granularity: int = 1) -> tuple[slice, float]:
        """Contiguous sample of a queue, in units of ``granularity`` entries
        (e.g. whole windows), preserving arrival-order locality."""
        if self.max_entries is None or n_entries <= self.max_entries:
            return slice(0, n_entries), 1.0
        units = max(1, self.max_entries // granularity)
        total_units = math.ceil(n_entries / granularity)
        if units >= total_units:
            return slice(0, n_entries), 1.0
        rng = self.rng or np.random.default_rng(0)
        start = int(rng.integers(0, total_units - units + 1)) * granularity
        take = min(units * granularity, n_entries - start)
        return slice(start, start + take), n_entries / take


FULL = Sampling()


@dataclasses.dataclass(frozen=True)
class Phantom2DConfig:
    """Table 1 / Table 2 operation & configuration parameters."""

    rows: int = 7  # R
    cols: int = 4  # C
    pes: int = 3
    threads: int = 3
    lookahead: int = 6  # L_f  (paper sweeps 3..27)
    policy: str = "outoforder"  # TDS_inOrder | TDS_outOrder
    intra_balance: bool = True
    inter_balance: bool = True

    @property
    def macs_per_core(self) -> int:
        return self.pes * self.threads

    @property
    def total_macs(self) -> int:
        return self.rows * self.cols * self.macs_per_core


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """A convolution layer (regular, depthwise, or pointwise when k=1)."""

    name: str
    in_ch: int
    out_ch: int
    in_h: int
    in_w: int
    kh: int = 3
    kw: int = 3
    stride: tuple[int, int] = (1, 1)
    depthwise: bool = False
    pad: str = "same"  # same | valid

    @property
    def pointwise(self) -> bool:
        return self.kh == 1 and self.kw == 1 and not self.depthwise

    @property
    def out_hw(self) -> tuple[int, int]:
        sh, sw = self.stride
        if self.pad == "same":
            return math.ceil(self.in_h / sh), math.ceil(self.in_w / sw)
        return (self.in_h - self.kh) // sh + 1, (self.in_w - self.kw) // sw + 1

    @property
    def macs(self) -> int:
        oh, ow = self.out_hw
        per_pos = self.kh * self.kw * (1 if self.depthwise else self.in_ch)
        return oh * ow * self.out_ch * per_pos


@dataclasses.dataclass(frozen=True)
class FCSpec:
    name: str
    in_dim: int
    out_dim: int
    # How a 4-D conv output enters this FC: plain flatten, max-pool then
    # flatten (VGG16 pool5), or global average pool (MobileNet).  Explicit
    # on the spec so the forwards never guess from shape arithmetic.
    pool: str = "flatten"  # flatten | pool5 | gap

    @property
    def macs(self) -> int:
        return self.in_dim * self.out_dim


@dataclasses.dataclass(frozen=True)
class CoreWork:
    """One core's queue for one broadcast job: TDS entry popcounts.

    ``pops`` is ``[E, pes]`` — per-entry per-PE-column popcounts, already in
    arrival order.  The simulator feeds each PE column to
    :func:`repro.core.tds.batch_cycles` (columns run in lockstep, §4.6).
    """

    pops: np.ndarray  # [E, pes] int8/int32
    valid_macs: int
    total_slots: int  # dense MAC slots covered by the *sampled* entries
    scale: float = 1.0  # full entries / sampled entries


@dataclasses.dataclass(frozen=True)
class LayerWork:
    """Full decomposition of a layer onto the R×C matrix.

    ``jobs[j][r]`` is the :class:`CoreWork` of row ``r`` for broadcast job
    ``j`` (a filter / filter-group / weight-vector batch).  All ``C`` columns
    of the matrix execute jobs drawn from this pool; the inter-core balancer
    decides the job → column assignment and order.
    ``job_density[j]`` is the mask popcount the balancer sorts on (§4.3.1).
    ``reuse`` marks whether weights are re-broadcast (only then does
    inter-core balancing apply — §4.2).  ``job_scale`` is the sampling
    correction applied to the scheduled makespan.
    """

    jobs: list  # list[list[CoreWork]]  (job → per-row work)
    job_density: np.ndarray  # [jobs]
    reuse: bool
    spec: object
    job_scale: float = 1.0


def _pad_mask_same(a_mask: np.ndarray, kh: int, kw: int, sh: int, sw: int):
    h, w = a_mask.shape[:2]
    oh, ow = math.ceil(h / sh), math.ceil(w / sw)
    ph = max((oh - 1) * sh + kh - h, 0)
    pw = max((ow - 1) * sw + kw - w, 0)
    return np.pad(
        a_mask,
        ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2))
        + ((0, 0),) * (a_mask.ndim - 2),
    )


def im2col_mask(
    a_mask: np.ndarray, kh: int, kw: int, stride=(1, 1), pad="same"
) -> np.ndarray:
    """``[H, W, C]`` bool → ``[oh*ow, kh*kw*C]`` window masks (row-major)."""
    a_mask = np.asarray(a_mask, dtype=bool)
    if a_mask.ndim == 2:
        a_mask = a_mask[..., None]
    sh, sw = stride
    if pad == "same":
        a_mask = _pad_mask_same(a_mask, kh, kw, sh, sw)
    h, w, c = a_mask.shape
    oh = (h - kh) // sh + 1
    ow = (w - kw) // sw + 1
    s0, s1, s2 = a_mask.strides
    win = np.lib.stride_tricks.as_strided(
        a_mask,
        shape=(oh, ow, kh, kw, c),
        strides=(s0 * sh, s1 * sw, s0, s1, s2),
    )
    return win.reshape(oh * ow, kh * kw * c)


def _group_pops(and_mask: np.ndarray, pes: int, threads: int) -> np.ndarray:
    """``[n, K]`` AND masks → ``[n*G, pes]`` entry popcounts (batches of
    ``pes × threads`` bits, the §4.4–4.5 'batches of 9')."""
    n, k = and_mask.shape
    if n == 0:  # empty band (fewer output rows than cores)
        return np.zeros((0, pes), dtype=np.int32)
    unit = pes * threads
    pad = (-k) % unit
    if pad:
        and_mask = np.pad(and_mask, ((0, 0), (0, pad)))
    groups = and_mask.reshape(n, -1, pes, threads)
    return groups.sum(axis=3, dtype=np.int32).reshape(-1, pes)


def _window_column_pops(
    and_mask: np.ndarray, kh: int, kw: int, pes: int, threads: int
) -> np.ndarray:
    """Small-kernel layout: filter window columns feed the PE columns
    (Figs. 4–6).  ``[n, kh*kw]`` → ``[n, pes]`` popcounts."""
    n = and_mask.shape[0]
    cols = and_mask.reshape(n, kh, kw).sum(axis=1, dtype=np.int32)  # [n, kw]
    out = np.zeros((n, pes), dtype=np.int32)
    out[:, :kw] = cols
    return out


def _band_slices(n: int, bands: int) -> list[slice]:
    """Split ``n`` output rows into ``bands`` contiguous bands (row dataflow)."""
    base, rem = divmod(n, bands)
    out, start = [], 0
    for r in range(bands):
        size = base + (1 if r < rem else 0)
        out.append(slice(start, start + size))
        start += size
    return out


def conv_work(
    spec: ConvSpec,
    w_mask: np.ndarray,  # [kh, kw, in_ch, out_ch] or [kh, kw, ch] depthwise
    a_mask: np.ndarray,  # [H, W, in_ch]
    cfg: Phantom2DConfig,
    sampling: Sampling = FULL,
) -> LayerWork:
    """Regular / depthwise convolution dataflow (§4.3, Fig. 15).

    Jobs are filters (regular) or channels (depthwise); per job, the R rows
    each own a band of output rows.  Weights are reused across bands, so
    inter-core balancing applies (``reuse=True``).
    """
    kh, kw = spec.kh, spec.kw
    oh, ow = spec.out_hw
    a_mask = np.asarray(a_mask, dtype=bool)
    w_mask = np.asarray(w_mask, dtype=bool)
    bands = _band_slices(oh, cfg.rows)
    unit_k = kh * kw
    small = kw <= cfg.pes and kh <= cfg.threads

    jobs: list[list[CoreWork]] = []
    dens: list[int] = []
    if spec.depthwise:
        sel_jobs, job_scale = sampling.pick_jobs(spec.in_ch)
        for c in sel_jobs:
            win = im2col_mask(a_mask[:, :, c], kh, kw, spec.stride, spec.pad)
            wvec = w_mask[:, :, c].reshape(-1)
            dens.append(int(wvec.sum()))
            rows_work = []
            for b in bands:
                band = win.reshape(oh, ow, unit_k)[b].reshape(-1, unit_k)
                g = 1 if small else math.ceil(unit_k / cfg.macs_per_core)
                sl, e_scale = sampling.entry_slice(band.shape[0] * g, g)
                band = band[sl.start // g : (sl.stop + g - 1) // g]
                anded = band & wvec[None, :]
                pops = (
                    _window_column_pops(anded, kh, kw, cfg.pes, cfg.threads)
                    if small
                    else _group_pops(anded, cfg.pes, cfg.threads)
                )
                rows_work.append(
                    CoreWork(pops, int(anded.sum()), band.shape[0] * unit_k, e_scale)
                )
            jobs.append(rows_work)
    else:
        windows = im2col_mask(a_mask, kh, kw, spec.stride, spec.pad)  # [ohw, K]
        k_full = windows.shape[1]
        g = math.ceil(k_full / cfg.macs_per_core)
        sel_jobs, job_scale = sampling.pick_jobs(spec.out_ch)
        band_views = []
        for b in bands:
            band = windows.reshape(oh, ow, k_full)[b].reshape(-1, k_full)
            sl, e_scale = sampling.entry_slice(band.shape[0] * g, g)
            band_views.append((band[sl.start // g : (sl.stop + g - 1) // g], e_scale))
        for f in sel_jobs:
            wvec = w_mask[:, :, :, f].reshape(-1)
            dens.append(int(wvec.sum()))
            rows_work = []
            for band, e_scale in band_views:
                anded = band & wvec[None, :]
                pops = _group_pops(anded, cfg.pes, cfg.threads)
                rows_work.append(
                    CoreWork(pops, int(anded.sum()), band.shape[0] * k_full, e_scale)
                )
            jobs.append(rows_work)
    return LayerWork(
        jobs, np.asarray(dens, dtype=np.int64), reuse=True, spec=spec, job_scale=job_scale
    )


def pointwise_work(
    spec: ConvSpec,
    w_mask: np.ndarray,  # [in_ch, out_ch]
    a_mask: np.ndarray,  # [H, W, in_ch]
    cfg: Phantom2DConfig,
    sampling: Sampling = FULL,
) -> LayerWork:
    """Pointwise (1×1) convolution dataflow (§4.4, Fig. 16).

    Filters go along the R rows; channels are split into batches of
    ``pes×threads`` along the C columns (L3 adders accumulate).  Weights stay
    resident per core while the input sweeps, so a *job* here is a batch of
    ``R`` filters × one channel batch; within a job every core sees the full
    spatial stream.  Inter-core balancing does not re-order the spatial sweep
    (no filter re-broadcast ⇒ ``reuse=False``).
    """
    a_mask = np.asarray(a_mask, dtype=bool)
    w_mask = np.asarray(w_mask, dtype=bool)
    h, w, cin = a_mask.shape
    unit = cfg.pes * cfg.threads
    n_batches = math.ceil(cin / unit)
    pad = n_batches * unit - cin
    if pad:
        a_mask = np.pad(a_mask, ((0, 0), (0, 0), (0, pad)))
        w_mask = np.pad(w_mask, ((0, pad), (0, 0)))
    flat_a = a_mask.reshape(h * w, n_batches, unit)  # channel-first batches

    n_fgrp = math.ceil(spec.out_ch / cfg.rows)
    sel_jobs, job_scale = sampling.pick_jobs(n_fgrp * n_batches)
    sl, e_scale = sampling.entry_slice(h * w)
    flat_a = flat_a[sl]
    jobs: list[list[CoreWork]] = []
    dens: list[int] = []
    for j in sel_jobs:
        fg, cb = divmod(j, n_batches)
        fgrp = range(fg * cfg.rows, min((fg + 1) * cfg.rows, spec.out_ch))
        rows_work = []
        d = 0
        for f in fgrp:
            wvec = w_mask[cb * unit : (cb + 1) * unit, f]
            d += int(wvec.sum())
            anded = flat_a[:, cb, :] & wvec[None, :]
            pops = anded.reshape(-1, cfg.pes, cfg.threads).sum(axis=2, dtype=np.int32)
            rows_work.append(CoreWork(pops, int(anded.sum()), anded.size, e_scale))
        jobs.append(rows_work)
        dens.append(d)
    return LayerWork(
        jobs, np.asarray(dens, dtype=np.int64), reuse=False, spec=spec, job_scale=job_scale
    )


def fc_work(
    spec: FCSpec,
    w_mask: np.ndarray,  # [in_dim, out_dim]
    a_mask: np.ndarray,  # [in_dim]
    cfg: Phantom2DConfig,
    sampling: Sampling = FULL,
) -> LayerWork:
    """FC dataflow (§4.5, Fig. 17): input stationary across rows, weight
    vectors swept; channel batches of ``pes×threads`` along columns."""
    a_mask = np.asarray(a_mask, dtype=bool).reshape(-1)
    w_mask = np.asarray(w_mask, dtype=bool)
    unit = cfg.pes * cfg.threads
    n_batches = math.ceil(spec.in_dim / unit)
    pad = n_batches * unit - spec.in_dim
    if pad:
        a_mask = np.pad(a_mask, (0, pad))
        w_mask = np.pad(w_mask, ((0, pad), (0, 0)))
    a_b = a_mask.reshape(n_batches, unit)

    # Row r sweeps weight vectors r, r+R, r+2R, ...; each (row, channel batch)
    # core consumes one 9-bit entry per swept vector.
    sel_jobs, job_scale = sampling.pick_jobs(n_batches)
    jobs: list[list[CoreWork]] = []
    dens: list[int] = []
    for cb in sel_jobs:
        rows_work = []
        d = 0
        for r in range(cfg.rows):
            vecs = list(range(r, spec.out_dim, cfg.rows))
            if vecs:
                sl, e_scale = sampling.entry_slice(len(vecs))
                vecs = vecs[sl]
                wcols = w_mask[cb * unit : (cb + 1) * unit, vecs].T  # [V, unit]
                anded = wcols & a_b[cb][None, :]
                pops = anded.reshape(-1, cfg.pes, cfg.threads).sum(
                    axis=2, dtype=np.int32
                )
                d += int(wcols.sum())
                rows_work.append(CoreWork(pops, int(anded.sum()), anded.size, e_scale))
            else:
                rows_work.append(CoreWork(np.zeros((0, cfg.pes), np.int32), 0, 0))
        jobs.append(rows_work)
        dens.append(d)
    return LayerWork(
        jobs, np.asarray(dens, dtype=np.int64), reuse=False, spec=spec, job_scale=job_scale
    )


def layer_work(
    spec, w_mask, a_mask, cfg: Phantom2DConfig, sampling: Sampling = FULL
) -> LayerWork:
    """Dispatch on layer kind (the scheduler entry point)."""
    if isinstance(spec, FCSpec):
        return fc_work(spec, w_mask, a_mask, cfg, sampling)
    if isinstance(spec, ConvSpec) and spec.pointwise:
        return pointwise_work(
            spec, w_mask.reshape(spec.in_ch, spec.out_ch), a_mask, cfg, sampling
        )
    if isinstance(spec, ConvSpec):
        return conv_work(spec, w_mask, a_mask, cfg, sampling)
    raise TypeError(f"unknown layer spec {type(spec)!r}")
