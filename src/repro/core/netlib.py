"""CNN layer tables used by the paper's evaluation (§5.1).

Shapes follow the standard VGG16 [51] and MobileNetV1 [24] ImageNet
configurations.  The simulator consumes these specs plus per-layer weight /
activation densities; :mod:`repro.models.cnn` builds the matching JAX
networks for the functional path.

Published per-layer densities for Han-style pruned VGG16 (Deep Compression
[19], Table 4) are included so the "sparse VGG16" runs use the same weight
sparsity as SCNN / SparTen / Eyeriss-v2 comparisons (paper: average weight /
activation sparsity 77% / 68% ⇒ densities .23 / .32).
"""
from __future__ import annotations

import numpy as np

from .dataflow import ConvSpec, FCSpec

__all__ = [
    "vgg16_layers",
    "mobilenet_layers",
    "VGG16_WEIGHT_DENSITY",
    "VGG16_ACT_DENSITY",
    "MOBILENET_WEIGHT_DENSITY",
    "MOBILENET_ACT_DENSITY",
]


def vgg16_layers(include_fc: bool = True, input_hw: int = 224):
    """The 13 conv + 3 FC layers of VGG16."""
    cfg = [
        (64, 1), (64, 1),
        ("pool", 2),
        (128, 2), (128, 2),
        ("pool", 4),
        (256, 4), (256, 4), (256, 4),
        ("pool", 8),
        (512, 8), (512, 8), (512, 8),
        ("pool", 16),
        (512, 16), (512, 16), (512, 16),
    ]
    layers = []
    in_ch, hw, idx = 3, input_hw, 1
    for entry in cfg:
        if entry[0] == "pool":
            hw = input_hw // entry[1]
            continue
        out_ch, div = entry
        hw = input_hw // div
        layers.append(
            ConvSpec(f"conv{idx}", in_ch, out_ch, hw, hw, 3, 3, (1, 1))
        )
        in_ch = out_ch
        idx += 1
    if include_fc:
        # pool5 halves the conv13 output once more: 224 → 7.  Scale with
        # input_hw so reduced-resolution smoke configs stay consistent.
        fc_hw = max(1, input_hw // 32)
        layers += [
            FCSpec("fc14", 512 * fc_hw * fc_hw, 4096, pool="pool5"),
            FCSpec("fc15", 4096, 4096),
            FCSpec("fc16", 4096, 1000),
        ]
    return layers


def mobilenet_layers(include_fc: bool = True, input_hw: int = 224):
    """MobileNetV1: conv s2 + 13 (depthwise + pointwise) pairs + FC.

    Includes the non-unit-stride depthwise layers SCNN cannot run.
    """
    layers = [ConvSpec("conv1", 3, 32, input_hw, input_hw, 3, 3, (2, 2))]
    # (in_ch, out_ch, input_hw_div, dw_stride)
    blocks = [
        (32, 64, 2, 1),
        (64, 128, 2, 2),
        (128, 128, 4, 1),
        (128, 256, 4, 2),
        (256, 256, 8, 1),
        (256, 512, 8, 2),
        (512, 512, 16, 1), (512, 512, 16, 1), (512, 512, 16, 1),
        (512, 512, 16, 1), (512, 512, 16, 1),
        (512, 1024, 16, 2),
        (1024, 1024, 32, 1),
    ]
    for i, (cin, cout, div, s) in enumerate(blocks, start=2):
        hw = input_hw // div
        layers.append(
            ConvSpec(f"conv{i}-dw", cin, cin, hw, hw, 3, 3, (s, s), depthwise=True)
        )
        ohw = hw // s
        layers.append(ConvSpec(f"conv{i}-pw", cin, cout, ohw, ohw, 1, 1, (1, 1)))
    if include_fc:
        layers.append(FCSpec("fc", 1024, 1000, pool="gap"))
    return layers


# --- Han-style pruned densities (Deep Compression Table 4, VGG16) -----------
VGG16_WEIGHT_DENSITY = {
    "conv1": 0.58, "conv2": 0.22, "conv3": 0.34, "conv4": 0.36,
    "conv5": 0.53, "conv6": 0.24, "conv7": 0.42, "conv8": 0.32,
    "conv9": 0.27, "conv10": 0.34, "conv11": 0.35, "conv12": 0.29,
    "conv13": 0.36, "fc14": 0.04, "fc15": 0.04, "fc16": 0.23,
}
# Average activation density per layer input (ReLU sparsity grows with depth;
# first layer is raw image — effectively dense).  Matches the paper's
# 68% average activation sparsity.
VGG16_ACT_DENSITY = {
    "conv1": 0.99, "conv2": 0.52, "conv3": 0.45, "conv4": 0.39,
    "conv5": 0.35, "conv6": 0.32, "conv7": 0.30, "conv8": 0.28,
    "conv9": 0.26, "conv10": 0.24, "conv11": 0.22, "conv12": 0.20,
    "conv13": 0.19, "fc14": 0.22, "fc15": 0.26, "fc16": 0.30,
}

MOBILENET_WEIGHT_DENSITY = {"conv1": 0.60, "fc": 0.12}
for _i in range(2, 15):
    # Depthwise filters prune poorly (few, critical weights); pointwise prune
    # well.  Average weight density 27% (paper: 73% sparsity).
    MOBILENET_WEIGHT_DENSITY[f"conv{_i}-dw"] = 0.55
    MOBILENET_WEIGHT_DENSITY[f"conv{_i}-pw"] = 0.24

MOBILENET_ACT_DENSITY = {"conv1": 0.99, "fc": 0.35}
for _i in range(2, 15):
    MOBILENET_ACT_DENSITY[f"conv{_i}-dw"] = max(0.30, 0.62 - 0.02 * _i)
    MOBILENET_ACT_DENSITY[f"conv{_i}-pw"] = max(0.28, 0.58 - 0.02 * _i)


def densities_for(layers, table_w, table_a, default_w=0.25, default_a=0.35):
    """Align density tables with a layer list → (w_density[], a_density[])."""
    wd = np.array([table_w.get(l.name, default_w) for l in layers])
    ad = np.array([table_a.get(l.name, default_a) for l in layers])
    return wd, ad
