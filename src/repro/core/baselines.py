"""Competitor cycle models: SCNN [39], SparTen [15], Eyeriss v2 [9].

The paper's simulator "contains routines for SparTen, SCNN, and Eyeriss v2
for performing comparisons" (§5.1).  These are mask-driven structural models,
normalised to the same MAC budget as Phantom-2D (252 multipliers), fed the
*same* synthesized masks as the Phantom runs:

* **SCNN** — input-stationary cartesian-product PEs (4 weights × 4 activations
  per cycle), planar 4×4 spatial tiling.  Costs include multiplier-array
  fragmentation ``ceil(nnz_w/4)·ceil(nnz_a/4)`` per (input-channel, tile) and
  the documented crossbar-contention/drain inefficiency (SparTen's analysis of
  SCNN's arbitrated output crossbar).  No FC layers, no non-unit stride —
  those return ``nan`` (the paper omits them from SCNN comparisons).
* **SparTen** — bitmask inner-join PEs working on 128-wide chunks with a
  prefix-sum match extractor; offline *greedy* load balancing on weight
  density only (activations are unknown offline — the systematic residual
  imbalance Phantom's dynamic balancing removes).  No FC support.
* **Eyeriss v2** — CSC-compressed row-stationary-plus PEs, SIMD-2 MACs with a
  4-wide sparse fetch; per-window cost is decode-bound at
  ``max(matches/2, nnz_act/4)``; static (filter, spatial-band) partitioning
  over PE clusters gives its load imbalance.

Where a micro-architectural stall cannot be reconstructed from masks alone
(SCNN's crossbar arbitration), a single documented efficiency constant is
used, calibrated to the published analyses; everything else is structural.
"""
from __future__ import annotations

import math

import numpy as np

from .dataflow import ConvSpec, FCSpec, im2col_mask

__all__ = [
    "scnn_cycles",
    "sparten_cycles",
    "eyeriss_v2_cycles",
    "ideal_sparse_cycles",
]

# --- documented model constants ---------------------------------------------
MAX_WINDOWS = 3072  # per-layer window subsample (costs scale linearly)
SCNN_F = 4  # weights consumed per cycle
SCNN_I = 4  # activations consumed per cycle
SCNN_TILES = (4, 4)  # planar PE tiling
SCNN_XBAR_EFF = 0.40  # arbitrated-crossbar + pipeline-drain efficiency [15]
SPARTEN_CHUNK = 128  # bitmask chunk width
SPARTEN_MATCH_RATE = 9  # matches retired per PE-cycle (equal-MAC grouping)
SPARTEN_CHUNK_OVERHEAD = 2  # AND + prefix-sum pipeline bubbles per chunk
SPARTEN_PES = 28
EYERISS_PES = 126  # × 2 MACs = 252
EYERISS_SIMD = 2
EYERISS_FETCH = 4  # CSC act-fetch width


def ideal_sparse_cycles(spec, w_mask, a_mask, total_macs=252) -> float:
    """Oracle: effectual MACs / MAC budget — no architecture achieves this."""
    matches = _total_matches(spec, w_mask, a_mask)
    return matches / total_macs


def _sub_windows(win: np.ndarray) -> tuple[np.ndarray, float]:
    """Deterministic contiguous window subsample; costs scale linearly."""
    n = win.shape[0]
    if n <= MAX_WINDOWS:
        return win, 1.0
    start = (n - MAX_WINDOWS) // 2
    return win[start : start + MAX_WINDOWS], n / MAX_WINDOWS


def _conv_matches(spec: ConvSpec, w_mask, a_mask):
    """[windows, filters] effectual-MAC counts via popcount-as-matmul.

    Returns ``(matches, windows, scale)`` — matches are per *sampled*
    window; multiply window-summed costs by ``scale``.
    """
    win = im2col_mask(a_mask, spec.kh, spec.kw, spec.stride, spec.pad)
    win, scale = _sub_windows(win)
    w2 = np.asarray(w_mask).reshape(-1, spec.out_ch)
    return win.astype(np.float32) @ w2.astype(np.float32), win, scale


def _total_matches(spec, w_mask, a_mask) -> float:
    if isinstance(spec, FCSpec):
        a = np.asarray(a_mask, dtype=np.float32).reshape(-1)
        return float(a @ np.asarray(w_mask, dtype=np.float32))
    if spec.depthwise:
        t = 0.0
        for c in range(spec.in_ch):
            win = im2col_mask(a_mask[:, :, c], spec.kh, spec.kw, spec.stride, spec.pad)
            t += float(win.astype(np.float32).sum(0) @ w_mask[:, :, c].reshape(-1))
        return t
    m, _, scale = _conv_matches(spec, w_mask, a_mask)
    return float(m.sum()) * scale


def scnn_cycles(spec, w_mask, a_mask, total_macs=252) -> float:
    if isinstance(spec, FCSpec) or spec.stride != (1, 1):
        return float("nan")  # SCNN supports neither (paper §1, §5.2.4)
    a_mask = np.asarray(a_mask, dtype=bool)
    w_mask = np.asarray(w_mask, dtype=bool)
    th, tw = SCNN_TILES
    h, w = a_mask.shape[:2]
    # nnz activations per (tile, channel); halos ignored (favours SCNN).
    hs, ws = _band_edges(h, th), _band_edges(w, tw)
    nnz_a = np.zeros((th * tw, spec.in_ch), dtype=np.int64)
    for i in range(th):
        for j in range(tw):
            nnz_a[i * tw + j] = a_mask[hs[i] : hs[i + 1], ws[j] : ws[j + 1]].sum((0, 1))
    if spec.depthwise:
        nnz_w = w_mask.sum((0, 1))  # per-channel filter nnz
    else:
        nnz_w = w_mask.sum((0, 1, 3))  # all filters' weights per input channel
    # Cartesian-product fragmentation per (PE, channel), summed over channels.
    per_pe = (np.ceil(nnz_w[None, :] / SCNN_F) * np.ceil(nnz_a / SCNN_I)).sum(1)
    cycles = float(per_pe.max()) / SCNN_XBAR_EFF
    return cycles * (th * tw * SCNN_F * SCNN_I) / total_macs


def sparten_cycles(spec, w_mask, a_mask, total_macs=252) -> float:
    if isinstance(spec, FCSpec):
        return float("nan")  # no FC support (paper §1)
    w_mask = np.asarray(w_mask, dtype=bool)
    a_mask = np.asarray(a_mask, dtype=bool)
    pes = SPARTEN_PES

    if spec.depthwise:
        # One sparse dot per (channel, window); channels are the offline
        # balancing unit.
        job_cost, job_w = [], []
        for c in range(spec.in_ch):
            win = im2col_mask(a_mask[:, :, c], spec.kh, spec.kw, spec.stride, spec.pad)
            win, scale = _sub_windows(win)
            m = win.astype(np.float32) @ w_mask[:, :, c].reshape(-1).astype(np.float32)
            job_cost.append(
                (
                    float(np.maximum(np.ceil(m / SPARTEN_MATCH_RATE), 1).sum())
                    + SPARTEN_CHUNK_OVERHEAD * m.shape[0]
                )
                * scale
            )
            job_w.append(int(w_mask[:, :, c].sum()))
    else:
        win = im2col_mask(a_mask, spec.kh, spec.kw, spec.stride, spec.pad)
        win, scale = _sub_windows(win)
        k = win.shape[1]
        n_chunks = math.ceil(k / SPARTEN_CHUNK)
        wf = np.asarray(w_mask).reshape(k, spec.out_ch).astype(np.float32)
        winf = win.astype(np.float32)
        cost = np.zeros((win.shape[0], spec.out_ch), dtype=np.float64)
        for ci in range(n_chunks):
            sl = slice(ci * SPARTEN_CHUNK, min((ci + 1) * SPARTEN_CHUNK, k))
            m = winf[:, sl] @ wf[sl]
            cost += np.maximum(
                np.ceil(m / SPARTEN_MATCH_RATE), SPARTEN_CHUNK_OVERHEAD
            )
        job_cost = (cost.sum(0) * scale).tolist()  # per-filter total cycles
        job_w = w_mask.reshape(-1, spec.out_ch).sum(0).tolist()
    # Offline greedy balancing: sort by *weight* density (activations unknown
    # offline), LPT onto PEs; makespan exposes the residual imbalance.
    order = np.argsort(-np.asarray(job_w), kind="stable")
    fin = np.zeros(pes)
    for j in order:
        w_id = int(np.argmin(fin))
        fin[w_id] += job_cost[j]
    return float(fin.max()) * (pes * SPARTEN_MATCH_RATE) / total_macs


def eyeriss_v2_cycles(spec, w_mask, a_mask, total_macs=252) -> float:
    w_mask = np.asarray(w_mask, dtype=bool)
    a_mask = np.asarray(a_mask, dtype=bool)
    if isinstance(spec, FCSpec):
        a = a_mask.reshape(-1)
        m = a.astype(np.float32) @ w_mask.astype(np.float32)  # [out]
        nnz_a = float(a.sum())
        cost = np.maximum(np.ceil(m / EYERISS_SIMD), math.ceil(nnz_a / EYERISS_FETCH))
        fin = np.zeros(EYERISS_PES)
        for j in range(cost.shape[0]):  # static round-robin filter partition
            fin[j % EYERISS_PES] += cost[j]
        return float(fin.max()) * (EYERISS_PES * EYERISS_SIMD) / total_macs

    if spec.depthwise:
        per_job = []
        for c in range(spec.in_ch):
            win = im2col_mask(a_mask[:, :, c], spec.kh, spec.kw, spec.stride, spec.pad)
            win, scale = _sub_windows(win)
            m = win.astype(np.float32) @ w_mask[:, :, c].reshape(-1).astype(np.float32)
            nnz_a = win.sum(1)
            cost = np.maximum(
                np.ceil(m / EYERISS_SIMD), np.ceil(nnz_a / EYERISS_FETCH)
            ).sum()
            per_job.append(float(cost) * scale)
    else:
        m, win, scale = _conv_matches(spec, w_mask, a_mask)
        nnz_a = win.sum(1, dtype=np.float32)
        cost = np.maximum(
            np.ceil(m / EYERISS_SIMD), np.ceil(nnz_a / EYERISS_FETCH)[:, None]
        )
        per_job = (cost.sum(0) * scale).tolist()  # per output filter
    fin = np.zeros(EYERISS_PES)
    for j, c in enumerate(per_job):  # static partition — no dynamic balance
        fin[j % EYERISS_PES] += c
    return float(fin.max()) * (EYERISS_PES * EYERISS_SIMD) / total_macs


def _band_edges(n: int, parts: int):
    base, rem = divmod(n, parts)
    edges = [0]
    for i in range(parts):
        edges.append(edges[-1] + base + (1 if i < rem else 0))
    return edges
