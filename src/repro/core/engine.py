"""Functional Phantom core (paper §3.2–3.8).

Executes the complete pipeline — sparse masks → LAM → (intra-core balance) →
TDS → thread mapper → compute engine (multiplier threads + L1 adders) →
output buffer (FIFOs, tags, L2 accumulation) — producing *actual numeric
outputs* that must bit-match a dense oracle, while counting cycles on the very
schedule that produced those numbers.  The cycle model is therefore never
detached from a correct execution.

Timing summary per work assignment (one weight chunk × a stream of activation
chunks):

  cycles     = max over PE columns of TDS selection cycles (§4.6 lockstep)
               + pipeline-fill latency of the serially-reused mapper (§3.5)
  dense      = ceil(total MAC slots / (pes × threads)) — an equally-provisioned
               dense core that cannot skip zeros
  lam_cycles = ceil(chunks / L_f) — the AND front-end (never the bottleneck
               for L_f ≥ 1; reported for completeness)
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from . import balance as balance_mod
from . import lam as lam_mod
from . import mapper as mapper_mod
from . import tds as tds_mod

__all__ = ["CoreStats", "CoreResult", "phantom_dot_chunks", "phantom_conv2d", "phantom_fc"]


@dataclasses.dataclass(frozen=True)
class CoreStats:
    cycles: int  # TDS/CE cycles incl. mapper fill
    lam_cycles: int
    dense_cycles: int
    valid_macs: int
    total_mac_slots: int
    utilization: float
    column_cycles: tuple[int, ...]

    @property
    def speedup_vs_dense(self) -> float:
        return self.dense_cycles / self.cycles if self.cycles else float("inf")


@dataclasses.dataclass(frozen=True)
class CoreResult:
    outputs: np.ndarray  # [chunks] dot-product results
    out_mask: np.ndarray  # [chunks] §3.8 output encoding (pre-activation)
    stats: CoreStats


def phantom_dot_chunks(
    weight: np.ndarray,
    act_chunks: np.ndarray,
    *,
    lookahead: int = 3,
    policy: str = "outoforder",
    intra_balance: bool = True,
    pes: int = 3,
    threads: int = 3,
) -> CoreResult:
    """Compute ``out[i] = Σ weight ⊙ act_chunks[i]`` through the Phantom core.

    ``weight`` is the stationary operand (a filter window or, for FC layers,
    the stationary input vector); ``act_chunks`` is ``[n, *weight.shape]``.
    """
    weight = np.asarray(weight)
    act_chunks = np.asarray(act_chunks)
    n_chunks = act_chunks.shape[0]
    if act_chunks.shape[1:] != weight.shape:
        raise ValueError("chunk shape mismatch")

    w_mask = weight != 0
    a_masks = act_chunks != 0
    lam_out = lam_mod.lam_and(w_mask, a_masks)  # [n, *shape]
    out_mask = lam_mod.output_mask(lam_out.reshape(n_chunks, -1))

    entries, chunk_ids = lam_mod.to_tds_columns(lam_out, pes, threads)
    # Operand lookup tables aligned with the entry layout.
    w_vals, a_vals = _operand_tables(weight, act_chunks, entries.shape, chunk_ids, pes, threads)

    shifts = np.zeros(entries.shape[0], dtype=np.int64)
    if intra_balance:
        entries, shifts = balance_mod.intra_core_shift(entries)
        w_vals, _ = balance_mod.intra_core_shift(w_vals)
        a_vals, _ = balance_mod.intra_core_shift(a_vals)

    sched = tds_mod.schedule_entries(entries, lookahead=lookahead, policy=policy)

    # --- Compute engine + output buffer ------------------------------------
    outputs = np.zeros(n_chunks, dtype=np.result_type(weight, act_chunks, np.float64))
    fifo_tags = np.zeros((n_chunks, pes), dtype=bool)  # §3.7 tag bits
    for j, col in enumerate(sched.columns):
        for cycle_sel in col.selections:
            bits_list = [entries[e, j] for e in cycle_sel]
            tmap = mapper_mod.map_selection(cycle_sel, bits_list, threads)
            # Multiplier threads + L1 adder: one partial per selected entry.
            for eid, bits in zip(cycle_sel, bits_list):
                idx = np.flatnonzero(bits)
                partial = (w_vals[eid, j, idx] * a_vals[eid, j, idx]).sum()
                # L2 accumulation keyed by the originating chunk (tag bits).
                outputs[chunk_ids[eid]] += partial
                fifo_tags[chunk_ids[eid], (j - shifts[eid]) % pes] = True
            del tmap  # mapping validated by construction; config exercised in tests

    valid = int(entries.sum())
    total_slots = int(np.prod(act_chunks.shape))
    cycles = sched.cycles + mapper_mod.MAPPER_REUSE_LATENCY(pes)
    stats = CoreStats(
        cycles=cycles,
        lam_cycles=lam_mod.lam_cycles(n_chunks, lookahead),
        dense_cycles=math.ceil(total_slots / (pes * threads)),
        valid_macs=valid,
        total_mac_slots=total_slots,
        utilization=sched.utilization,
        column_cycles=tuple(c.cycles for c in sched.columns),
    )
    return CoreResult(outputs=outputs, out_mask=out_mask, stats=stats)


def _operand_tables(weight, act_chunks, entry_shape, chunk_ids, pes, threads):
    """Build ``[E, pes, threads]`` operand values aligned with the TDS entries."""
    n = act_chunks.shape[0]
    if weight.ndim == 2 and weight.shape[1] <= pes and weight.shape[0] <= threads:
        kh, kw = weight.shape
        w = np.zeros((pes, threads), dtype=weight.dtype)
        w[:kw, :kh] = weight.T
        w_vals = np.broadcast_to(w, (n, pes, threads)).copy()
        a = np.zeros((n, pes, threads), dtype=act_chunks.dtype)
        a[:, :kw, :kh] = np.moveaxis(act_chunks, 2, 1)
        return w_vals, a
    flat_w = weight.reshape(-1)
    flat_a = act_chunks.reshape(n, -1)
    pad = (-flat_w.shape[0]) % (pes * threads)
    flat_w = np.pad(flat_w, (0, pad))
    flat_a = np.pad(flat_a, ((0, 0), (0, pad)))
    g = flat_w.shape[0] // (pes * threads)
    w_vals = np.broadcast_to(
        flat_w.reshape(g, pes, threads), (n, g, pes, threads)
    ).reshape(-1, pes, threads)
    a_vals = flat_a.reshape(n * g, pes, threads)
    assert w_vals.shape[0] == entry_shape[0]
    return w_vals.copy(), a_vals


def phantom_conv2d(
    activation: np.ndarray,
    weight: np.ndarray,
    *,
    stride: tuple[int, int] = (1, 1),
    **core_kw,
) -> CoreResult:
    """Single-channel 2-D convolution through one Phantom core (Fig. 1 flow)."""
    windows = _value_windows(activation, weight.shape, stride)
    return phantom_dot_chunks(weight, windows, **core_kw)


def phantom_fc(
    activation: np.ndarray, weight: np.ndarray, **core_kw
) -> CoreResult:
    """FC layer (§4.5): input-stationary, weight columns swept as chunks."""
    return phantom_dot_chunks(np.asarray(activation), np.asarray(weight).T, **core_kw)


def _value_windows(activation, kshape, stride):
    a = np.asarray(activation)
    kh, kw = kshape
    sh, sw = stride
    oh = (a.shape[0] - kh) // sh + 1
    ow = (a.shape[1] - kw) // sw + 1
    out = np.empty((oh * ow, kh, kw), dtype=a.dtype)
    for i in range(oh):
        for j in range(ow):
            out[i * ow + j] = a[i * sh : i * sh + kh, j * sw : j * sw + kw]
    return out
