"""PhantomLinear: the paper's technique as a first-class framework feature.

A linear layer whose weight carries a static block-sparsity mask (Han-style
pruning at TPU tile granularity) and whose input may carry a dynamic
activation tile mask.  Three execution modes:

* ``dense``  — plain ``x @ w`` (training default; Phantom is an inference
  architecture, matching the paper's use of offline-pruned nets),
* ``masked`` — ``x @ (w ⊙ mask)`` with the mask stored alongside the weight
  (straight-through: gradients flow to the surviving blocks only).  This is
  the mode the distributed dry-run lowers — it is pure traced JAX, and XLA
  sees the exact FLOPs the masked model performs.
* ``kernel`` — the Pallas two-sided block-sparse kernel
  (:mod:`repro.kernels.ops`): weight-side work compacted away, activation
  tiles gated.  Host-prepared (`prepare_weight`) — used at serving time on
  concrete weights.

``auto`` picks ``kernel`` when a prepared weight is supplied, else ``masked``
when a mask exists, else ``dense``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PhantomConfig", "phantom_linear", "prune_params", "PHANTOM_DISABLED"]


@dataclasses.dataclass(frozen=True)
class PhantomConfig:
    """Serving/training knobs for the Phantom technique (DESIGN.md §4).

    This is the *only* knob surface for weight-load-time lowering: the
    ``block`` / ``interleave`` / ``conv_mode`` / ``dtype`` kwargs that used
    to be duplicated across ``ops.prepare_weight``,
    ``phantom_conv.prepare_conv_weight`` and ``prepare_cnn_phantom`` all
    live here and flow through :func:`repro.program.compile`
    (DESIGN.md §8).
    """

    enabled: bool = False
    block: tuple[int, int, int] = (256, 256, 256)  # (bm, bk, bn)
    weight_density: float = 0.25
    act_threshold: float = 0.0  # τ=0 ⇔ exact-zero skipping (ReLU semantics)
    interleave: bool = True  # intra-core-style queue rotation
    balance: str = "full"  # none | intra | inter | full
    mode: str = "auto"  # dense | masked | kernel | auto
    conv_mode: str = "direct"  # direct (implicit im2col) | im2col (oracle)
    dtype: str = "float32"  # packed-payload dtype (string: keeps cfg hashable)
    # Virtual Phantom cores (§4.2 / DESIGN.md §9): output tile-columns are
    # partitioned across `cores` per-core work queues at weight-load time —
    # densest-first LPT when `balance` enables inter-core balancing, naive
    # round-robin otherwise — and executed as a leading grid axis of one
    # pallas_call (shardable over a device mesh).  cores=1 is the classic
    # single-queue path, bit-identical to cores>1.
    cores: int = 1
    # TDS lookahead window L_f (§3.4 / DESIGN.md §10): at call time the work
    # queue is compacted against the activation bits so activation-dead
    # steps cost no grid iterations — each executed step retires up to
    # `lookahead` queue entries (at most one effectual MAC, the threads=1
    # in-order selector).  0/None keeps today's gating behaviour (every
    # queue slot is a grid step), the parity oracle the compacted path is
    # asserted bit-identical against.
    lookahead: int | None = 0

    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def with_overrides(self, **fields) -> "PhantomConfig":
        """A copy of this config with ``fields`` replaced — the per-layer
        override application point of the autotuner (DESIGN.md §12).

        Accepts exactly the dataclass field names; ``block`` may arrive as a
        JSON list (tune-cache entries and saved programs round-trip through
        JSON) and is normalised back to a tuple so configs stay hashable.
        Unknown field names raise instead of being silently dropped — a
        stale cache entry must fail loudly, not mis-tune.
        """
        if not fields:
            return self
        known = {f.name for f in dataclasses.fields(self)}
        bad = sorted(set(fields) - known)
        if bad:
            raise ValueError(
                f"unknown PhantomConfig override field(s) {bad}; known: {sorted(known)}"
            )
        if "block" in fields and fields["block"] is not None:
            fields["block"] = tuple(fields["block"])
        return dataclasses.replace(self, **fields)


PHANTOM_DISABLED = PhantomConfig(enabled=False)


def phantom_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    wmask: Optional[jnp.ndarray],
    cfg: PhantomConfig,
    *,
    prepared=None,
    bias: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Apply a (possibly Phantom-sparse) linear layer.

    ``wmask`` is the element-expanded block mask stored with the weight (same
    dtype as ``w``; 0/1).  ``prepared`` is a
    :class:`repro.kernels.ops.PhantomWeight` for the kernel path.
    """
    mode = cfg.mode
    if mode == "auto":
        if prepared is not None and cfg.enabled:
            mode = "kernel"
        elif wmask is not None and cfg.enabled:
            mode = "masked"
        else:
            mode = "dense"
    if mode == "kernel":
        from repro.kernels import ops  # local: kernels are optional at import

        y = ops.phantom_matmul(x, prepared, act_threshold=cfg.act_threshold)
    else:
        weff = w if (mode == "dense" or wmask is None) else w * wmask
        y = jnp.einsum(
            "...k,kn->...n", x, weff,
        )
    if bias is not None:
        y = y + bias
    return y


def prune_params(w: np.ndarray, cfg: PhantomConfig, rng=None) -> np.ndarray:
    """Block-prune a weight to ``cfg.weight_density`` → element mask (0/1,
    ``w.dtype``), TPU-tile aligned (DESIGN.md §2 granularity change)."""
    from repro.core.sparsity import block_prune

    mask = block_prune(np.asarray(w), cfg.weight_density, cfg.block[1:])
    return mask.astype(np.asarray(w).dtype)
