"""Cycle-level Phantom / Phantom-2D performance simulator (paper §5.1).

Drives the mask-level dataflow decomposition (:mod:`repro.core.dataflow`)
through the exact vectorised TDS timing (:func:`repro.core.tds.batch_cycles`)
and the two-level balancers, for whole networks.  Matches the paper's
methodology:

* only sparse masks are simulated — "only this information is needed to
  efficiently represent the MAC operations needed per layer" (§5.1);
* per-layer activation masks are synthesised at the measured average density
  (the paper averages over a batch of 100 inputs);
* the dense architecture is the same datapath with ``L_f = 1`` — every entry
  costs one cycle, no lookahead (§5.2.1) — which reduces to one cycle per
  ``pes×threads`` MAC-slot group;
* like the paper ("we only use approximately 25% of the channel filters"),
  work is subsampled for tractability: ``job_frac``/``max_jobs`` subsample
  broadcast jobs and ``max_entries`` subsamples each core queue, with cycle
  counts scaled back by the sampled fraction.  Sampling is seeded and
  recorded in the result.

The same synthesized masks feed the competitor cycle models
(:mod:`repro.core.baselines`), so every architecture sees identical work.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from . import balance as balance_mod
from . import baselines as baselines_mod
from . import dataflow as df
from . import mapper as mapper_mod
from . import netlib
from . import sparsity
from . import tds as tds_mod

__all__ = [
    "SimOptions",
    "LayerResult",
    "VARIANTS",
    "time_work",
    "evaluate_layer",
    "simulate_network",
    "network_summary",
    "default_variants",
]


@dataclasses.dataclass(frozen=True)
class SimOptions:
    job_frac: float = 0.25  # fraction of broadcast jobs simulated (paper: ~25%)
    max_jobs: int = 48  # hard cap on sampled jobs per layer
    max_entries: int = 384  # per-queue entry cap (contiguous sample)
    seed: int = 0
    # Structured-mask synthesis: real pruned filters are not iid Bernoulli —
    # per-filter density varies (what inter-core balancing exploits, §4.3.1)
    # and surviving weights cluster around the filter centre (what intra-core
    # balancing exploits, §4.6).  ``filter_jitter`` is the lognormal sigma on
    # per-filter density; ``spatial_bias`` the centre-bias strength.
    filter_jitter: float = 0.6
    spatial_bias: float = 0.8


@dataclasses.dataclass
class LayerResult:
    name: str
    kind: str
    macs: int  # dense MACs of the full layer
    valid_frac: float  # effectual / total MAC slots (sampled estimate)
    cycles: dict  # variant/baseline -> cycles (scaled to full layer)
    utilization: dict  # variant -> multiplier-thread utilization

    def speedup(self, variant: str, base: str = "dense") -> float:
        return self.cycles[base] / self.cycles[variant]


def default_variants(lookahead: int = 6) -> dict:
    """The Table 1 operating points used throughout §5."""
    mk = lambda **kw: df.Phantom2DConfig(lookahead=lookahead, **kw)
    return {
        "tds_io": mk(policy="inorder"),
        "tds_oo": mk(policy="outoforder"),
        "unbalanced": mk(intra_balance=False, inter_balance=False),
        "balanced": mk(intra_balance=True, inter_balance=True),
    }


VARIANTS = default_variants()


def time_work(
    work: df.LayerWork, cfg: df.Phantom2DConfig
) -> tuple[float, float]:
    """Cycle count + thread utilization of one layer under one configuration.

    Columns of the R×C matrix are the schedulable workers; each job occupies
    the R rows in parallel, so a job's cost is the slowest row (§4.6 lockstep
    applies *within* a core's PE columns, and rows sync per broadcast).
    """
    queues, qscale, meta = [], [], []  # meta: (job_idx, row)
    for jl, rows in enumerate(work.jobs):
        for r, cw in enumerate(rows):
            pops = cw.pops
            if cfg.intra_balance and pops.shape[0]:
                shifts = (np.arange(pops.shape[0]) % cfg.pes)[:, None]
                cols = np.arange(cfg.pes)[None, :]
                pops = np.take_along_axis(pops, (cols - shifts) % cfg.pes, axis=1)
            queues.append(pops)
            qscale.append(cw.scale)
            meta.append((jl, r))
    n_jobs = len(work.jobs)
    lengths = np.array([q.shape[0] for q in queues], dtype=np.int64)
    lmax = max(1, int(lengths.max(initial=0)))
    q_arr = np.zeros((len(queues) * cfg.pes, lmax), dtype=np.int32)
    for qi, q in enumerate(queues):
        if q.shape[0]:
            q_arr[qi * cfg.pes : (qi + 1) * cfg.pes, : q.shape[0]] = q.T
    col_lengths = np.repeat(lengths, cfg.pes)
    cyc = tds_mod.batch_cycles(
        q_arr,
        col_lengths,
        lookahead=cfg.lookahead,
        threads=cfg.threads,
        policy=cfg.policy,
    ).reshape(len(queues), cfg.pes)
    core_cycles = cyc.max(axis=1) * np.asarray(qscale) + mapper_mod.MAPPER_REUSE_LATENCY(
        cfg.pes
    )

    job_cost = np.zeros(n_jobs)
    for (jl, _r), c in zip(meta, core_cycles):
        job_cost[jl] = max(job_cost[jl], c)
    balanced = cfg.inter_balance and work.reuse
    sched = balance_mod.inter_core_schedule(
        job_cost,
        cfg.cols,
        balanced=balanced,
        densities=work.job_density if balanced else None,
    )
    cycles = sched.makespan * work.job_scale
    # Thread utilization: effectual MACs over provisioned MAC-cycles.  Each
    # job engages one column (R rows × pes × threads threads).
    valid = sum(cw.valid_macs * cw.scale for rows in work.jobs for cw in rows)
    engaged = sched.makespan * cfg.cols * cfg.rows * cfg.macs_per_core
    util = float(valid / max(engaged, 1e-12))
    return float(cycles), min(util, 1.0)


def dense_cycles_from_work(work: df.LayerWork, cfg: df.Phantom2DConfig) -> float:
    """Equally-provisioned dense datapath: one cycle per entry (``L_f = 1``),
    identical dataflow, scheduling structure and mapper fill latency, no
    zero skipping."""
    fill = mapper_mod.MAPPER_REUSE_LATENCY(cfg.pes)
    job_cost = np.array(
        [
            max(
                math.ceil(cw.total_slots / cfg.macs_per_core) * cw.scale + fill
                for cw in rows
            )
            for rows in work.jobs
        ],
        dtype=np.float64,
    )
    sched = balance_mod.inter_core_schedule(job_cost, cfg.cols, balanced=False)
    return float(sched.makespan) * work.job_scale


def evaluate_layer(
    spec,
    w_mask: np.ndarray,
    a_mask: np.ndarray,
    variants: dict,
    opts: SimOptions,
    rng,
    baselines: tuple = (),
) -> LayerResult:
    geometry = next(iter(variants.values())) if variants else df.Phantom2DConfig()
    sampling = df.Sampling(
        job_frac=opts.job_frac,
        max_jobs=opts.max_jobs,
        max_entries=opts.max_entries,
        rng=rng,
    )
    work = df.layer_work(spec, w_mask, a_mask, geometry, sampling)
    cycles: dict = {}
    util: dict = {}
    cycles["dense"] = dense_cycles_from_work(work, geometry)
    slots = sum(cw.total_slots for rows in work.jobs for cw in rows)
    valid = sum(cw.valid_macs for rows in work.jobs for cw in rows)
    for name, cfg in variants.items():
        c, u = time_work(work, cfg)
        cycles[name] = c
        util[name] = u
    util["dense"] = valid / max(slots, 1)
    for b in baselines:
        fn = getattr(baselines_mod, f"{b}_cycles")
        cycles[b] = fn(spec, w_mask, a_mask, total_macs=geometry.total_macs)
    kind = (
        "fc"
        if isinstance(spec, df.FCSpec)
        else ("pw" if spec.pointwise else ("dw" if spec.depthwise else "conv"))
    )
    return LayerResult(
        name=spec.name,
        kind=kind,
        macs=spec.macs,
        valid_frac=valid / max(slots, 1),
        cycles=cycles,
        utilization=util,
    )


def simulate_network(
    layers,
    w_density,
    a_density,
    variants: dict | None = None,
    opts: SimOptions = SimOptions(),
    baselines: tuple = (),
    skip_fc_for=(),
) -> list[LayerResult]:
    """Simulate a whole network from per-layer densities (Bernoulli masks,
    seeded).  ``skip_fc_for`` lists baselines that cannot run FC layers
    (SCNN, SparTen — their cycles are reported as ``nan`` there)."""
    variants = variants or default_variants()
    rng = np.random.default_rng(opts.seed)
    results = []
    for li, spec in enumerate(layers):
        wd, ad = float(w_density[li]), float(a_density[li])
        w_mask, a_mask, spec_eff, pre_scale = _make_masks(spec, wd, ad, rng, opts)
        res = evaluate_layer(
            spec_eff, w_mask, a_mask, variants, opts, rng, baselines=baselines
        )
        if pre_scale != 1.0:
            res.cycles = {k: v * pre_scale for k, v in res.cycles.items()}
        res.name = spec.name
        res.macs = spec.macs
        for b in skip_fc_for:
            if res.kind == "fc" and b in res.cycles:
                res.cycles[b] = float("nan")
        results.append(res)
    return results


def _filter_densities(n: int, wd: float, rng, opts: SimOptions) -> np.ndarray:
    """Per-filter densities: lognormal jitter around ``wd`` (real magnitude
    pruning leaves filters with very different survival rates)."""
    if opts.filter_jitter <= 0:
        return np.full(n, wd)
    d = wd * rng.lognormal(-(opts.filter_jitter**2) / 2, opts.filter_jitter, n)
    return np.clip(d, 0.01, 1.0)


def _spatial_profile(kh: int, kw: int, bias: float) -> np.ndarray:
    """Centre-heavy keep-probability profile over the filter window (mean 1)."""
    if bias <= 0 or (kh == 1 and kw == 1):
        return np.ones((kh, kw))
    yy, xx = np.mgrid[0:kh, 0:kw]
    cy, cx = (kh - 1) / 2, (kw - 1) / 2
    r2 = ((yy - cy) / max(cy, 1)) ** 2 + ((xx - cx) / max(cx, 1)) ** 2
    prof = np.exp(-bias * r2)
    return prof / prof.mean()


def _make_masks(spec, wd, ad, rng, opts: SimOptions):
    """Synthesize masks at layer densities; FC weight matrices are sampled
    down *before* synthesis (their full masks are enormous)."""
    if isinstance(spec, df.FCSpec):
        unit = 9
        n_batches = math.ceil(spec.in_dim / unit)
        target = max(1, min(opts.max_jobs, int(math.ceil(n_batches * opts.job_frac))))
        in_red = min(spec.in_dim, target * unit)
        scale = spec.in_dim / in_red
        spec_eff = df.FCSpec(spec.name, in_red, spec.out_dim)
        w_mask = sparsity.bernoulli_mask((in_red, spec.out_dim), wd, rng)
        a_mask = sparsity.bernoulli_mask((in_red,), ad, rng)
        return w_mask, a_mask, spec_eff, scale
    a_mask = sparsity.bernoulli_mask((spec.in_h, spec.in_w, spec.in_ch), ad, rng)
    prof = _spatial_profile(spec.kh, spec.kw, opts.spatial_bias)
    if spec.depthwise:
        dens = _filter_densities(spec.in_ch, wd, rng, opts)
        keep = np.clip(prof[:, :, None] * dens[None, None, :], 0, 1)
        w_mask = rng.random((spec.kh, spec.kw, spec.in_ch)) < keep
    else:
        dens = _filter_densities(spec.out_ch, wd, rng, opts)
        keep = np.clip(
            prof[:, :, None, None] * dens[None, None, None, :], 0, 1
        )
        w_mask = (
            rng.random((spec.kh, spec.kw, spec.in_ch, spec.out_ch)) < keep
        )
    return w_mask, a_mask, spec, 1.0


def network_summary(results: list[LayerResult], variant: str, base: str = "dense"):
    """Whole-network speedup = Σ base cycles / Σ variant cycles (nan-safe:
    layers a baseline cannot run are excluded from *both* sums)."""
    num = den = 0.0
    for r in results:
        b, v = r.cycles.get(base), r.cycles.get(variant)
        if b is None or v is None or math.isnan(b) or math.isnan(v):
            continue
        num += b
        den += v
    return num / den if den else float("nan")


def vgg16_simulation(opts=SimOptions(), variants=None, baselines=(), include_fc=True):
    layers = netlib.vgg16_layers(include_fc=include_fc)
    wd, ad = netlib.densities_for(
        layers, netlib.VGG16_WEIGHT_DENSITY, netlib.VGG16_ACT_DENSITY
    )
    return simulate_network(layers, wd, ad, variants, opts, baselines)


def mobilenet_simulation(opts=SimOptions(), variants=None, baselines=(), include_fc=True):
    layers = netlib.mobilenet_layers(include_fc=include_fc)
    wd, ad = netlib.densities_for(
        layers, netlib.MOBILENET_WEIGHT_DENSITY, netlib.MOBILENET_ACT_DENSITY
    )
    return simulate_network(layers, wd, ad, variants, opts, baselines)
