"""Sparse binary mask representation (paper §3.1).

A matrix is stored as two arrays, both column-major:
  * ``data``: the packed non-zero values,
  * ``mask``: a binary array; 1 marks a *stored* non-zero, 0 an *unstored* zero.

Unlike CSC/CSR there are no ``count``/``pointer`` side arrays, which makes
"looking ahead" (paper §3.3) a pure bitwise-AND and keeps the metadata cost a
single bit per element.  This module also carries the byte-cost models used to
reproduce Fig. 25 (sparse-mask vs. CSC DRAM traffic).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "SparseMask",
    "to_sparse_mask",
    "from_sparse_mask",
    "mask_traffic_bytes",
    "csc_traffic_bytes",
    "csr_traffic_bytes",
    "density",
]


@dataclasses.dataclass(frozen=True)
class SparseMask:
    """Column-major sparse-mask storage of a 2-D matrix (paper Fig. 2)."""

    shape: tuple[int, ...]
    mask: np.ndarray  # bool, ``shape``
    data: np.ndarray  # 1-D packed non-zeros, column-major order

    @property
    def nnz(self) -> int:
        return int(self.data.size)

    @property
    def density(self) -> float:
        size = int(np.prod(self.shape))
        return self.nnz / size if size else 0.0


def to_sparse_mask(x: np.ndarray) -> SparseMask:
    """Pack ``x`` into sparse-mask form (column-major, per paper Fig. 2)."""
    x = np.asarray(x)
    mask = x != 0
    # Column-major ("F") traversal matches the paper's storage order.
    flat = np.asarray(x).flatten(order="F")
    data = flat[np.asarray(mask).flatten(order="F")]
    return SparseMask(shape=tuple(x.shape), mask=mask, data=data)


def from_sparse_mask(sm: SparseMask, dtype=None) -> np.ndarray:
    """Inverse of :func:`to_sparse_mask` (exact round-trip)."""
    dtype = dtype or sm.data.dtype
    flat = np.zeros(int(np.prod(sm.shape)), dtype=dtype)
    flat[np.asarray(sm.mask).flatten(order="F")] = sm.data
    return flat.reshape(sm.shape, order="F")


def density(mask: np.ndarray) -> float:
    mask = np.asarray(mask)
    return float(mask.sum()) / mask.size if mask.size else 0.0


# ---------------------------------------------------------------------------
# Metadata-traffic cost models (paper Fig. 25).
#
# Per the paper's footnote, the comparison covers *metadata only*: the binary
# sparse mask on one side, and the CSC location vectors (column pointers +
# row indices) on the other — the packed non-zero payload is identical for
# both formats and is therefore excluded.
# ---------------------------------------------------------------------------


def mask_traffic_bytes(shape: tuple[int, ...]) -> int:
    """Bytes moved for the binary sparse mask: one bit per element."""
    return math.ceil(int(np.prod(shape)) / 8)


def csc_traffic_bytes(
    mask: np.ndarray, *, index_bits: int | None = None, pointer_bits: int | None = None
) -> int:
    """Bytes moved for CSC metadata: row index per nnz + per-column pointer.

    ``index_bits`` defaults to the bits needed to address a row;
    ``pointer_bits`` to the bits needed to count all nnz.  Both are rounded up
    to whole bytes per entry, matching byte-addressable DRAM bursts.
    """
    mask = np.asarray(mask)
    if mask.ndim == 1:
        mask = mask[:, None]
    rows, cols = mask.shape[0], int(np.prod(mask.shape[1:]))
    nnz = int(mask.sum())
    if index_bits is None:
        index_bits = max(1, math.ceil(math.log2(max(rows, 2))))
    if pointer_bits is None:
        pointer_bits = max(1, math.ceil(math.log2(max(nnz + 1, 2))))
    index_bytes = math.ceil(index_bits / 8)
    pointer_bytes = math.ceil(pointer_bits / 8)
    return nnz * index_bytes + (cols + 1) * pointer_bytes


def csr_traffic_bytes(mask: np.ndarray, **kw) -> int:
    """CSR metadata traffic — CSC of the transpose."""
    return csc_traffic_bytes(np.asarray(mask).T, **kw)
