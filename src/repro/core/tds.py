"""Top-Down Selector (paper §3.4) — in-order and out-of-order variants.

Each of the ``pes`` parallel selectors owns one column of LAM entries (one
entry per convolution chunk, ``threads`` bits each) and packs entries onto its
PE's multiplier threads cycle by cycle, under two hardware constraints:

  * **multiplier capacity** — at most ``threads`` ones per selection,
  * **output slots**       — at most ``threads`` entries per selection (the
    L1 adder emits one partial per entry; there are ``threads`` FIFO ports).

Per cycle a selector examines a window of the next ``L_f`` pending entries:

  * **zero entries are free**: the LAM's all-zero check (§3.8) already routes
    all-zero chunks to the output encoder, so a zero-popcount entry consumes
    neither a multiplier nor a mapper slot — the window logic shifts past it.
    This is what lets speedup scale with ``L_f`` (up to ``L_f`` entries
    retired per cycle when the stream is zero-dominated: Fig. 19b, and the
    ~25×-over-dense pointwise layers at ``L_f = 27``, §5.2.4); with
    ``L_f = 1`` exactly one entry retires per cycle, replicating a dense
    accelerator (§5.2.1).
  * **in-order** (TDS-IO): take the maximal *prefix* that fits; the first
    non-zero entry that does not fit ends the cycle (paper Fig. 6a).
  * **out-of-order** (TDS-OO): keep scanning the window past a non-fitting
    entry and take anything that still fits (Fig. 6b).  Entries skipped in a
    cycle stay at the head of the queue, so they get highest priority on the
    next cycle (the paper's P1/P2 priority flip).

Core synchronisation: the columns of one work assignment proceed in lockstep,
so the assignment costs ``max`` over columns of per-column cycles (§4.6).

Two implementations with identical semantics (cross-checked by tests):
:func:`select_column` returns the exact per-cycle selections for the
functional engine; :func:`batch_cycles` is a NumPy-vectorised version that
times thousands of column queues at once for the full-network simulator.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "ColumnSchedule",
    "TdsSchedule",
    "select_column",
    "schedule_entries",
    "batch_cycles",
    "POLICIES",
]

POLICIES = ("inorder", "outoforder")


@dataclasses.dataclass(frozen=True)
class ColumnSchedule:
    """Exact selection trace of one column: ``selections[c]`` = entry ids
    picked on cycle ``c`` (queue order == arrival order of LAM outputs)."""

    selections: list[list[int]]

    @property
    def cycles(self) -> int:
        return len(self.selections)


@dataclasses.dataclass(frozen=True)
class TdsSchedule:
    columns: list[ColumnSchedule]
    pes: int
    threads: int
    policy: str
    valid_macs: int

    @property
    def cycles(self) -> int:
        """Assignment latency: columns run in lockstep (§4.6)."""
        return max((c.cycles for c in self.columns), default=0)

    @property
    def utilization(self) -> float:
        denom = self.cycles * self.pes * self.threads
        return self.valid_macs / denom if denom else 1.0


def select_column(
    pops: np.ndarray, *, lookahead: int, threads: int, policy: str
) -> ColumnSchedule:
    """Exact schedule of one column queue given per-entry popcounts."""
    if policy not in POLICIES:
        raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
    pops = [int(p) for p in np.asarray(pops).ravel()]
    if any(p > threads for p in pops):
        raise ValueError("entry popcount exceeds multiplier-thread capacity")
    queue = list(range(len(pops)))
    selections: list[list[int]] = []
    while queue:
        window = queue[: max(1, lookahead)]
        cap, slots, sel, passed = threads, threads, [], []
        for idx in window:
            if pops[idx] == 0:  # all-zero entry: shifted past for free (§3.8)
                passed.append(idx)
                continue
            fits = pops[idx] <= cap and slots > 0
            if fits:
                sel.append(idx)
                cap -= pops[idx]
                slots -= 1
            elif policy == "inorder":
                break  # IO stops at the first non-fitting non-zero entry
        selections.append(sel)
        gone = set(sel) | set(passed)
        queue = [i for i in queue if i not in gone]
    return ColumnSchedule(selections=selections)


def schedule_entries(
    entries: np.ndarray, *, lookahead: int, policy: str
) -> TdsSchedule:
    """Schedule a full assignment: ``entries`` is ``[E, pes, threads]`` bool."""
    entries = np.asarray(entries, dtype=bool)
    _, pes, threads = entries.shape
    pops = entries.sum(axis=2)  # [E, pes]
    cols = [
        select_column(pops[:, j], lookahead=lookahead, threads=threads, policy=policy)
        for j in range(pes)
    ]
    return TdsSchedule(
        columns=cols,
        pes=pes,
        threads=threads,
        policy=policy,
        valid_macs=int(entries.sum()),
    )


# ---------------------------------------------------------------------------
# Vectorised batch timing — same semantics, thousands of queues at once.
# ---------------------------------------------------------------------------


def batch_cycles(
    pops: np.ndarray,
    lengths: np.ndarray,
    *,
    lookahead: int,
    threads: int,
    policy: str,
) -> np.ndarray:
    """Cycle counts for ``Q`` column queues.

    ``pops``:    ``[Q, L]`` uint popcounts, padded past ``lengths`` (ignored).
    ``lengths``: ``[Q]`` valid entry counts per queue.
    Returns ``[Q]`` int cycles.  Exactly matches :func:`select_column`
    (property-tested), but runs the per-cycle window scan as vector ops.
    """
    if policy not in POLICIES:
        raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
    pops = np.ascontiguousarray(pops, dtype=np.int32)
    lengths = np.asarray(lengths, dtype=np.int64)
    Q, L = pops.shape
    n = int(max(1, lookahead))
    BIG = np.int32(1 << 20)  # sentinel: never fits

    # Queue state: a carry buffer of ≤ n previously-skipped entries (OO only —
    # IO consumes prefixes so its carry is always empty) plus a pointer into
    # the untouched entry stream.
    carry = np.full((Q, n), BIG, dtype=np.int32)
    carry_len = np.zeros(Q, dtype=np.int64)
    ptr = np.zeros(Q, dtype=np.int64)
    cycles = np.zeros(Q, dtype=np.int64)
    pad = np.full((Q, n), BIG, dtype=np.int32)
    pops_pad = np.concatenate([pops, pad], axis=1)  # safe windowed gather
    # Mask out entries beyond each queue's valid length.
    idx_all = np.arange(L + n)[None, :]
    pops_pad = np.where(idx_all < lengths[:, None], pops_pad, BIG)

    active = (carry_len + np.maximum(lengths - ptr, 0)) > 0
    while active.any():
        # Build the window: carry entries first (highest priority), then fresh.
        fresh_need = np.clip(n - carry_len, 0, None)
        gidx = ptr[:, None] + np.arange(n)[None, :]
        fresh = np.take_along_axis(pops_pad, np.minimum(gidx, L + n - 1), axis=1)
        fresh = np.where(np.arange(n)[None, :] < fresh_need[:, None], fresh, BIG)
        window = np.full((Q, n), BIG, dtype=np.int32)
        crange = np.arange(n)[None, :]
        np.copyto(window, np.where(crange < carry_len[:, None], carry, window))
        # Append fresh after carry: position of fresh j is carry_len + j.
        fpos = carry_len[:, None] + np.arange(n)[None, :]
        take = (np.arange(n)[None, :] < fresh_need[:, None]) & (fpos < n)
        rows, cols_ = np.nonzero(take)
        window[rows, np.minimum(fpos[rows, cols_], n - 1)] = fresh[rows, cols_]

        fresh_taken = np.minimum(fresh_need, np.maximum(lengths - ptr, 0))
        valid = window < BIG

        # Greedy scan over the window (n is small: ≤ L_f ≤ 27).
        cap = np.full(Q, threads, dtype=np.int32)
        slots = np.full(Q, threads, dtype=np.int32)
        alive = np.ones(Q, dtype=bool)  # IO: false after first non-fit
        consumed = np.zeros((Q, n), dtype=bool)
        for j in range(n):
            pj = window[:, j]
            vj = valid[:, j]
            zero = (pj == 0) & vj  # all-zero entries shift past for free
            fits = (pj > 0) & (pj <= cap) & (slots > 0) & vj
            if policy == "inorder":
                fits &= alive
                zero &= alive
                # The prefix survives padding and zero entries but ends at
                # the first real non-zero entry that does not fit.
                alive = alive & (fits | zero | ~vj)
            consumed[:, j] = fits | zero
            cap = cap - np.where(fits, pj, 0).astype(np.int32)
            slots = slots - fits.astype(np.int32)

        # Entries not consumed become the next carry (order preserved).
        leftover = valid & ~consumed
        order = np.argsort(~leftover, axis=1, kind="stable")  # leftovers first
        new_carry = np.take_along_axis(window, order, axis=1)
        new_len = leftover.sum(axis=1).astype(np.int64)
        new_carry = np.where(np.arange(n)[None, :] < new_len[:, None], new_carry, BIG)

        progressed = active
        carry = np.where(progressed[:, None], new_carry, carry)
        carry_len = np.where(progressed, new_len, carry_len)
        ptr = ptr + np.where(progressed, fresh_taken, 0)
        cycles += progressed
        active = (carry_len + np.maximum(lengths - ptr, 0)) > 0
    return cycles
