"""Two-level load balancing (paper §4.2, §4.3.1, §4.6).

**Intra-core** (always on when enabled): a circular right-shift of each LAM
output's columns — entry ``i`` is rotated by ``i mod pes`` — evens out the
per-column density before the TDS, and the produced maps are rotated back so
operand addressing stays valid (paper Fig. 18: 33% → 100% thread utilisation
on the worked example, a 3× speedup).

**Inter-core**: work units whose weights are reused (filters in regular /
depthwise convolution) are dispatched **densest-first to the
earliest-finishing worker** — the paper's "low latency, more dense / high
latency, less dense" broadcast order, driven by mask popcounts only, with no
offline pass (contra SparTen's greedy balancing).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "intra_core_shift",
    "intra_core_unshift_maps",
    "InterCoreSchedule",
    "inter_core_schedule",
]


def intra_core_shift(entries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Rotate entry ``i``'s PE-columns right by ``i mod pes`` (Fig. 18c).

    Returns the shifted entries and the per-entry shift amounts needed to
    rotate the generated maps back (:func:`intra_core_unshift_maps`).
    """
    entries = np.asarray(entries)
    n, pes = entries.shape[0], entries.shape[1]
    shifts = np.arange(n) % pes
    cols = np.arange(pes)
    # right circular shift by s: out[:, j] = in[:, (j - s) % pes]
    src = (cols[None, :] - shifts[:, None]) % pes
    shifted = np.take_along_axis(entries, src[..., None], axis=1)
    return shifted, shifts


def intra_core_unshift_maps(maps: np.ndarray, shifts: np.ndarray) -> np.ndarray:
    """Circular *left* shift of per-entry maps, undoing :func:`intra_core_shift`."""
    maps = np.asarray(maps)
    pes = maps.shape[1]
    cols = np.arange(pes)
    src = (cols[None, :] + shifts[:, None]) % pes
    return np.take_along_axis(maps, src[..., None], axis=1)


@dataclasses.dataclass(frozen=True)
class InterCoreSchedule:
    """Assignment of jobs to workers plus the resulting makespan."""

    assignment: list[list[int]]  # worker -> job ids, in dispatch order
    finish_times: np.ndarray  # [workers]
    makespan: float

    @property
    def imbalance(self) -> float:
        f = self.finish_times
        return float(f.max() / f.mean()) if f.size and f.mean() > 0 else 1.0


def inter_core_schedule(
    costs: np.ndarray,
    n_workers: int,
    *,
    balanced: bool,
    densities: np.ndarray | None = None,
    capacity: int | None = None,
) -> InterCoreSchedule:
    """Dispatch jobs (filter broadcasts) onto workers (core columns).

    ``balanced=False`` reproduces the naive schedule: jobs in natural order,
    round-robin across workers (all columns advance together, so a dense
    filter stalls its round).  ``balanced=True`` reproduces the paper's
    dynamic policy: order jobs densest-first (``densities`` defaults to the
    true costs — popcount of the filter mask is the paper's proxy) and give
    each to the worker that finishes earliest.  ``capacity`` caps the number
    of jobs per worker (the TPU adaptation's equal-output-slab constraint —
    matches ``blocksparse.balance_columns`` with the same cap; the classic
    unconstrained LPT is ``capacity=None``).
    """
    costs = np.asarray(costs, dtype=np.float64)
    n = costs.shape[0]
    if capacity is not None and capacity * n_workers < n:
        raise ValueError(
            f"capacity {capacity} × {n_workers} workers cannot hold {n} jobs"
        )
    workers: list[list[int]] = [[] for _ in range(n_workers)]
    finish = np.zeros(n_workers, dtype=np.float64)
    if not balanced:
        # Lock-step rounds: each round dispatches one job per column and the
        # round ends when the slowest column finishes (systematic imbalance —
        # idle columns wait inside the round).  Every column advances with
        # the round, including columns with no job in a partial final round,
        # so finish times never lag the true end.
        t = 0.0
        for start in range(0, n, n_workers):
            round_jobs = list(range(start, min(start + n_workers, n)))
            round_len = max(costs[j] for j in round_jobs)
            for w, j in enumerate(round_jobs):
                workers[w].append(j)
            t += round_len
            finish[:] = t
        return InterCoreSchedule(workers, finish, float(t))
    order = np.argsort(
        -(np.asarray(densities, dtype=np.float64) if densities is not None else costs),
        kind="stable",
    )
    sizes = np.zeros(n_workers, dtype=np.int64)
    for j in order:
        if capacity is None:
            w = int(np.argmin(finish))
        else:
            elig = np.flatnonzero(sizes < capacity)
            w = int(elig[np.argmin(finish[elig])])
        workers[w].append(int(j))
        finish[w] += costs[j]
        sizes[w] += 1
    return InterCoreSchedule(workers, finish, float(finish.max()))
