"""Lookahead masking (paper §3.3).

The LAM block ANDs the weight sparse mask with the sparse masks of ``n = L_f``
convolution chunks per cycle, yielding — for every chunk — the exact positions
of *valid* multiplications (``nz_w × nz_a``).  Everything downstream (TDS,
mapper, compute engine) operates on these AND masks only; zeros never reach a
multiplier thread.

A "chunk" is one dot-product worth of work: a sliding conv window, or one
weight column of an FC/GEMM layer.  For TDS consumption each chunk's AND mask
is laid out as ``pes`` columns of ``threads`` bits (paper Figs. 4–6: the 3×3
filter's 3 window-columns feed the 3 per-PE selectors).
"""
from __future__ import annotations

import math

import numpy as np

__all__ = [
    "conv1d_windows",
    "conv2d_windows",
    "fc_chunks",
    "lam_and",
    "to_tds_columns",
    "lam_cycles",
    "output_mask",
]


def conv1d_windows(a_mask: np.ndarray, kernel: int, stride: int = 1) -> np.ndarray:
    """Sliding-window view of a 1-D activation mask → ``[chunks, kernel]``."""
    a_mask = np.asarray(a_mask, dtype=bool)
    n_out = (a_mask.shape[-1] - kernel) // stride + 1
    idx = stride * np.arange(n_out)[:, None] + np.arange(kernel)[None, :]
    return a_mask[..., idx]


def conv2d_windows(
    a_mask: np.ndarray, kernel: tuple[int, int], stride: tuple[int, int] = (1, 1)
) -> np.ndarray:
    """``[H, W]`` activation mask → ``[chunks, kh, kw]`` window masks.

    Chunks are emitted row-major over output positions; supports non-unit
    stride (design goal G3 — SCNN cannot run these layers).
    """
    a_mask = np.asarray(a_mask, dtype=bool)
    kh, kw = kernel
    sh, sw = stride
    oh = (a_mask.shape[0] - kh) // sh + 1
    ow = (a_mask.shape[1] - kw) // sw + 1
    out = np.empty((oh * ow, kh, kw), dtype=bool)
    for i in range(oh):
        for j in range(ow):
            out[i * ow + j] = a_mask[i * sh : i * sh + kh, j * sw : j * sw + kw]
    return out


def fc_chunks(w_mask: np.ndarray) -> np.ndarray:
    """FC layer: every weight column is one chunk → ``[cols, len]`` masks."""
    return np.asarray(w_mask, dtype=bool).T


def lam_and(w_mask: np.ndarray, chunk_masks: np.ndarray) -> np.ndarray:
    """Bitwise AND of the weight mask with each chunk mask (Fig. 4)."""
    w = np.asarray(w_mask, dtype=bool)
    c = np.asarray(chunk_masks, dtype=bool)
    return np.logical_and(c, w[None, ...])


def lam_cycles(n_chunks: int, lookahead: int) -> int:
    """LAM throughput: ``n = L_f`` AND gates emit L_f chunk masks per cycle."""
    return math.ceil(n_chunks / max(1, lookahead))


def to_tds_columns(
    lam_out: np.ndarray, pes: int, threads: int
) -> tuple[np.ndarray, np.ndarray]:
    """Lay out chunk AND masks as TDS entries → ``([E, pes, threads], chunk_id[E])``.

    2-D conv masks ``[chunks, kh, kw]`` use the window columns directly
    (column ``j`` of the filter → selector ``j``), zero-padded to the selector
    geometry.  Flat masks ``[chunks, k]`` are split into row-groups of
    ``pes × threads`` bits (the Phantom-2D "batches of 9" for FC / pointwise
    layers, §4.4–4.5); ``chunk_id`` records which original chunk each entry
    row belongs to, for L2 accumulation in the output buffer.
    """
    lam_out = np.asarray(lam_out, dtype=bool)
    n = lam_out.shape[0]
    if lam_out.ndim == 3:  # [chunks, kh, kw] — window-column layout
        kh, kw = lam_out.shape[1:]
        if kw > pes or kh > threads:
            # Wide/tall kernels fall back to the flat layout, exactly like
            # FC / pointwise chunks.
            return to_tds_columns(lam_out.reshape(n, kh * kw), pes, threads)
        cols = np.moveaxis(lam_out, 2, 1)  # [chunks, kw, kh]
        out = np.zeros((n, pes, threads), dtype=bool)
        out[:, :kw, :kh] = cols
        return out, np.arange(n)
    k = lam_out.shape[1]
    pad = (-k) % (pes * threads)
    flat = np.pad(lam_out, ((0, 0), (0, pad)))
    groups = flat.reshape(n, -1, pes, threads)  # chunk → row-groups
    g = groups.shape[1]
    return groups.reshape(n * g, pes, threads), np.repeat(np.arange(n), g)


def output_mask(lam_out: np.ndarray) -> np.ndarray:
    """Output sparse-mask generation, pre-ReLU (paper §3.8, Fig. 13a).

    A chunk with *any* valid multiplication yields a (potentially) non-zero
    output; the all-zero check OR-reduces each chunk's LAM bits.
    """
    lam_out = np.asarray(lam_out, dtype=bool)
    return lam_out.reshape(lam_out.shape[0], -1).any(axis=1)
