"""Sparsity substrate: pruning, activation thresholding, mask synthesis.

The paper evaluates Han-style pruned networks (Deep Compression [19]): static
weight sparsity from iterative magnitude pruning, dynamic activation sparsity
from ReLU.  This module provides
  * magnitude / block-magnitude pruning (the block variant feeds the TPU
    adaptation in :mod:`repro.core.blocksparse`),
  * activation thresholding (τ=0 ⇔ exact ReLU zero semantics, §3.8),
  * seeded Bernoulli mask synthesis at target densities (the simulator's
    stand-in for "average over 100 inputs"),
  * density bookkeeping shared by the balancers.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "magnitude_prune",
    "block_prune",
    "activation_mask",
    "bernoulli_mask",
    "layer_density",
]


def magnitude_prune(w: np.ndarray, density: float) -> np.ndarray:
    """Keep the top-``density`` fraction of |w|; returns a boolean mask."""
    w = np.asarray(w)
    if not 0.0 <= density <= 1.0:
        raise ValueError("density must be in [0, 1]")
    k = int(round(density * w.size))
    if k == 0:
        return np.zeros(w.shape, dtype=bool)
    if k >= w.size:
        return np.ones(w.shape, dtype=bool)
    thresh = np.partition(np.abs(w).ravel(), w.size - k)[w.size - k]
    mask = np.abs(w) >= thresh
    # Tie-break deterministically so exactly k survive.
    extra = int(mask.sum()) - k
    if extra > 0:
        ties = np.flatnonzero((np.abs(w) == thresh).ravel() & mask.ravel())
        flat = mask.ravel()
        flat[ties[:extra]] = False
        mask = flat.reshape(w.shape)
    return mask


def block_prune(w: np.ndarray, density: float, block: tuple[int, int]) -> np.ndarray:
    """Prune whole (bm × bn) blocks by L2 norm — the TPU-aligned variant.

    Returns an element mask in which surviving blocks are fully dense; the
    block mask itself is recovered by any-reduction over blocks.
    """
    w = np.asarray(w)
    bm, bn = block
    m, n = w.shape
    pm, pn = (-m) % bm, (-n) % bn
    wp = np.pad(w, ((0, pm), (0, pn)))
    blocks = wp.reshape((m + pm) // bm, bm, (n + pn) // bn, bn)
    norms = np.sqrt((blocks.astype(np.float64) ** 2).sum(axis=(1, 3)))
    bmask = magnitude_prune(norms, density)
    emask = np.repeat(np.repeat(bmask, bm, axis=0), bn, axis=1)
    return emask[:m, :n]


def activation_mask(x: np.ndarray, threshold: float = 0.0) -> np.ndarray:
    """Dynamic activation mask: ``|x| > threshold`` (τ=0 keeps exact zeros
    only — the ReLU case of §3.8; τ>0 is the lossy LM serving knob)."""
    return np.abs(np.asarray(x)) > threshold


def bernoulli_mask(shape, density: float, rng: np.random.Generator) -> np.ndarray:
    """Seeded random mask at a target density (simulator input synthesis)."""
    return rng.random(shape) < density


def layer_density(mask: np.ndarray, axis=None):
    mask = np.asarray(mask, dtype=np.float64)
    return mask.mean(axis=axis)
