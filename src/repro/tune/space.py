"""Candidate enumeration + pruning for the per-layer config search.

The knob surface (DESIGN.md §12) is the scheduling subset of
:class:`~repro.core.phantom_linear.PhantomConfig`: ``block`` (tile shape),
``cores`` (virtual-core partition width), ``balance`` (partition policy),
``conv_mode`` (lowering) and ``lookahead`` (runtime compaction window).
Candidates are *partial field dicts* — the same representation the tune
cache stores and ``PhantomProgram`` carries per node — resolved against the
layer's base config with :meth:`PhantomConfig.with_overrides`.

Pruning is structural, not heuristic: a candidate that cannot differ from
another already-emitted candidate (``balance`` with one core, ``conv_mode``
on an FC layer, more cores than output tile-columns) is dropped before
costing, so the cost model only sees configurations that could actually win.
The empty override ``{}`` — the base config itself — is always candidate 0:
the search can therefore never return something worse than the default on
the cost metric.
"""
from __future__ import annotations

import dataclasses
import itertools
import math

from repro.core.dataflow import ConvSpec

__all__ = [
    "SearchSpace",
    "DEFAULT_SPACE",
    "BENCH_SPACE",
    "candidates",
    "override_in_space",
]


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Value pools per searched knob.  ``None`` pools mean "keep the base
    config's value" (the knob is not searched)."""

    cores: tuple[int, ...] | None = (1, 2, 4)
    balance: tuple[str, ...] | None = ("none", "full")
    lookahead: tuple[int, ...] | None = (0, 8)
    conv_mode: tuple[str, ...] | None = ("direct", "im2col")
    #: Extra block shapes besides the base config's.  Off by default in the
    #: bench space: cross-block costs compare normalised MAC volume rather
    #: than raw step counts, so keep the deterministic step-count acceptance
    #: comparisons single-block.
    blocks: tuple[tuple[int, int, int], ...] | None = None


DEFAULT_SPACE = SearchSpace()
#: Single-grid space used by the kernel-bench acceptance row: every
#: candidate shares the base block and lowering, so raw makespan / executed
#: steps are directly comparable across candidates.
BENCH_SPACE = SearchSpace(conv_mode=None, blocks=None)


def _pool(space_val, base_val):
    if space_val is None:
        return (base_val,)
    vals = list(space_val)
    if base_val not in vals:
        vals.insert(0, base_val)
    return tuple(vals)


def override_in_space(override: dict, base_cfg, space: SearchSpace = DEFAULT_SPACE) -> bool:
    """Whether a per-layer override diff is reachable by a search over
    ``space`` under ``base_cfg``.

    The membership contract behind two consumers: the tune cache treats an
    entry whose override left the live space as *stale* (warn + re-search,
    never apply), and the program verifier's ``config/overrides`` rule
    flags out-of-space tunings at warn level.  Each knob's legal pool is
    exactly :func:`candidates`'s pool — the space values plus the base
    config's own value; unknown fields are by definition unreachable.
    """
    pools = {
        "cores": _pool(space.cores, base_cfg.cores),
        "balance": _pool(space.balance, base_cfg.balance),
        "lookahead": _pool(space.lookahead, int(base_cfg.lookahead or 0)),
        "conv_mode": _pool(space.conv_mode, base_cfg.conv_mode),
        "block": _pool(
            tuple(space.blocks) if space.blocks else None, tuple(base_cfg.block)
        ),
    }
    for field, val in (override or {}).items():
        if field not in pools:
            return False
        if field == "block":
            try:
                val = tuple(val)
            except TypeError:
                return False
        elif field == "lookahead":
            if val is not None and not isinstance(val, (int, bool)):
                return False
            val = int(val or 0)
        if val not in pools[field]:
            return False
    return True


def candidates(spec, base_cfg, space: SearchSpace = DEFAULT_SPACE) -> list[dict]:
    """Enumerate pruned override dicts for ``spec`` under ``base_cfg``.

    Always returns ``[{}, ...]`` — the base config first, then every
    structurally-distinct variant.  Override dicts carry only the fields
    that differ from the base, so cache entries stay readable and a saved
    program's ``overrides`` metadata shows exactly what the tuner changed.
    """
    is_conv = isinstance(spec, ConvSpec)
    pools = {
        "cores": _pool(space.cores, base_cfg.cores),
        "balance": _pool(space.balance, base_cfg.balance),
        "lookahead": _pool(space.lookahead, int(base_cfg.lookahead or 0)),
        "conv_mode": _pool(space.conv_mode if is_conv else None, base_cfg.conv_mode),
        "block": _pool(
            tuple(space.blocks) if space.blocks else None, tuple(base_cfg.block)
        ),
    }
    base_key = (
        base_cfg.cores,
        base_cfg.balance,
        int(base_cfg.lookahead or 0),
        base_cfg.conv_mode,
        tuple(base_cfg.block),
    )
    seen: set[tuple] = {base_key}
    out: list[dict] = [{}]  # the base config is always candidate 0
    for cores, bal, la, cm, blk in itertools.product(
        pools["cores"], pools["balance"], pools["lookahead"],
        pools["conv_mode"], pools["block"],
    ):
        nt = math.ceil((spec.out_ch if is_conv else spec.out_dim) / blk[2])
        if cores > max(1, nt):
            continue  # more cores than output tile-columns: empty cores
        if cores == 1 and bal != base_cfg.balance:
            # balance only affects the inter-core partition; with one core
            # the only side effect (interleave gating) never changes step
            # counts — identical cost, prune.
            continue
        resolved = (cores, bal if cores > 1 else base_cfg.balance, la, cm, blk)
        if resolved in seen:
            continue
        seen.add(resolved)
        ov: dict = {}
        if cores != base_cfg.cores:
            ov["cores"] = cores
        if cores > 1 and bal != base_cfg.balance:
            ov["balance"] = bal
        if la != int(base_cfg.lookahead or 0):
            ov["lookahead"] = la
        if is_conv and cm != base_cfg.conv_mode:
            ov["conv_mode"] = cm
        if blk != tuple(base_cfg.block):
            ov["block"] = blk
        out.append(ov)
    # Deterministic order with the base first: the search's sort is stable,
    # so ties break toward earlier (simpler) candidates.
    return out
