"""The per-layer config search engine (DESIGN.md §12).

Two-phase, cheap-first:

1. **Cost phase** — every candidate from :mod:`repro.tune.space` is scored
   by the analytic TDS/makespan model (:mod:`repro.tune.cost`): pure
   host-side queue construction, no kernel compile, no device work.  The
   candidates are ranked by ``(cost, work_makespan, weight_bytes,
   cores, lookahead)`` — minimise the executed-makespan MAC volume first,
   then prefer less total work, less HBM traffic, and the simpler config.
2. **Measured phase** (optional, ``measure > 0``) — the top ``measure``
   candidates *that are not cost-worse than the default* are prepared on
   the real kernel path and timed with :func:`repro.obs.timeit` on a seeded
   input; the fastest measured candidate wins.  Restricting the shortlist
   to cost-ties-or-better keeps the deterministic never-worse guarantee
   even when wall time disagrees with the model.

``tune_overrides`` is the cache-integrated network-level entry point that
``phantom.compile(tune=...)`` consumes; it performs **zero** searches in
``"cached"`` mode (misses fall back to the base config), which the CI smoke
and the tune tests assert via the :class:`~repro.tune.cache.TuneCache`
counters.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings

import numpy as np

from repro.core.phantom_linear import PhantomConfig

from . import cost as cost_mod
from .cache import TuneCache
from .space import DEFAULT_SPACE, SearchSpace, candidates, override_in_space

__all__ = ["Trial", "TuneResult", "search_layer", "tune_overrides"]


@dataclasses.dataclass(frozen=True)
class Trial:
    """One costed candidate: the override diff + its deterministic metrics
    (+ measured wall µs when the measured phase ran it)."""

    override: dict
    metrics: dict
    measured_us: float | None = None


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one layer's search."""

    name: str
    override: dict  # winning partial-config diff ({} = keep the default)
    best: dict  # winner's cost metrics
    default: dict  # base config's cost metrics
    trials: tuple[Trial, ...]

    @property
    def cost_improvement(self) -> float:
        """default cost / tuned cost (≥ 1.0 by construction)."""
        return self.default["cost"] / self.best["cost"] if self.best["cost"] else 1.0


def _rank_key(trial: Trial, base_cfg):
    m = trial.metrics
    eff = base_cfg.with_overrides(**trial.override)
    return (
        m["cost"],
        m["work_makespan"],
        m["weight_bytes"],
        eff.cores,
        int(eff.lookahead or 0),
    )


def _measure_candidate(spec, params, batch, cfg, *, reps, interpret):
    """Wall-time one candidate on the real kernel path (registry prepare +
    apply on a seeded input) — the expensive signal, shortlist only."""
    import jax.numpy as jnp

    from repro.obs import timeit
    from repro.program.registry import kind_for

    kind = kind_for(spec)
    plan = kind.prepare(spec, params, batch, cfg)
    rng = np.random.default_rng(0)
    shape = (
        (batch, spec.in_h, spec.in_w, spec.in_ch)
        if hasattr(spec, "in_h")
        else (batch, spec.in_dim)
    )
    x = jnp.asarray(np.maximum(rng.standard_normal(shape), 0).astype(np.float32))
    _, us = timeit(
        lambda: kind.apply(
            x, plan, params, mask=None,
            act_threshold=cfg.act_threshold, interpret=interpret,
        ),
        reps=reps,
        warmup=1,
    )
    return us


def search_layer(
    spec,
    params: dict,
    batch: int,
    base_cfg: PhantomConfig,
    *,
    space: SearchSpace = DEFAULT_SPACE,
    act_bits: np.ndarray | None = None,
    act_density: float = 1.0,
    measure: int = 0,
    measure_reps: int = 3,
    interpret: bool | None = None,
    recorder=None,
) -> TuneResult:
    """Search one layer's candidate space; returns the winning override.

    ``act_bits`` (real calibration tile bits, base-grid-shaped) is only
    consulted for candidates sharing the base grid (block + conv_mode);
    other candidates fall back to the deterministic ``act_density`` pattern.
    ``measure`` > 0 wall-times that many cost-shortlisted candidates on the
    real kernel path.  ``recorder`` receives one ``tune/trial`` span per
    costed candidate plus per-layer best/default cost gauges.
    """
    w = np.asarray(params["w"])
    trials: list[Trial] = []
    base_grid = (tuple(base_cfg.block), base_cfg.conv_mode)
    for i, ov in enumerate(candidates(spec, base_cfg, space)):
        cfg = base_cfg.with_overrides(**ov)
        cm = (
            recorder.span("tune/trial", layer=spec.name, candidate=i)
            if recorder is not None
            else contextlib.nullcontext()
        )
        with cm:
            bits = (
                act_bits
                if act_bits is not None
                and (tuple(cfg.block), cfg.conv_mode) == base_grid
                else None
            )
            m = cost_mod.candidate_cost(
                spec, w, batch, cfg, act_bits=bits, act_density=act_density
            )
        trials.append(Trial(override=ov, metrics=m))
        if recorder is not None:
            recorder.inc("tune/trials")
    default = trials[0].metrics  # candidate 0 is always the base config
    ranked = sorted(trials, key=lambda t: _rank_key(t, base_cfg))
    if measure > 0:
        # Shortlist: cost-model winners that are ties-or-better than the
        # default — measurement picks among them, so it can refine but never
        # break the deterministic never-worse guarantee.
        short = [t for t in ranked if t.metrics["cost"] <= default["cost"]][:measure]
        measured: list[Trial] = []
        for t in short:
            cfg = base_cfg.with_overrides(**t.override)
            us = _measure_candidate(
                spec, params, batch, cfg, reps=measure_reps, interpret=interpret
            )
            measured.append(dataclasses.replace(t, measured_us=us))
            if recorder is not None:
                recorder.inc("tune/measured")
                recorder.observe("tune/measured_us", us, layer=spec.name)
        best = min(measured, key=lambda t: (t.measured_us, _rank_key(t, base_cfg)))
        trials = [t for t in ranked if t not in short] + measured
    else:
        best = ranked[0]
    if recorder is not None:
        recorder.gauge("tune/default_cost", default["cost"], layer=spec.name)
        recorder.gauge("tune/best_cost", best.metrics["cost"], layer=spec.name)
    return TuneResult(
        name=spec.name,
        override=dict(best.override),
        best=best.metrics,
        default=default,
        trials=tuple(trials),
    )


def tune_overrides(
    layers,
    params,
    batch: int,
    base_cfg: PhantomConfig,
    *,
    cache: TuneCache,
    mode: str = "search",
    space: SearchSpace = DEFAULT_SPACE,
    act_density=None,
    measure: int = 0,
    interpret: bool | None = None,
    recorder=None,
    results: list | None = None,
) -> dict[str, dict]:
    """Per-layer overrides for a network, through the persistent cache.

    ``mode="cached"``: lookups only — a miss falls back to the base config
    and no search runs (``cache.searches`` stays 0).  ``mode="search"``:
    misses trigger :func:`search_layer` and the winners are persisted.
    A *stale* hit — an entry whose override is no longer inside the live
    search space (:func:`~repro.tune.space.override_in_space`) — is never
    applied: it warns, counts under ``cache.stale``, and re-searches in
    **both** modes (falling back to ``tune="search"`` for that layer).
    ``act_density`` is a per-layer-name dict (or one float) of expected
    activation tile density for the cost model's synthetic bits.
    ``results`` (a list, appended in place) collects per-layer
    :class:`TuneResult`/cache-entry reports for CLI tables.
    """
    if mode not in ("cached", "search"):
        raise ValueError(f"tune mode must be 'cached' or 'search', got {mode!r}")
    overrides: dict[str, dict] = {}
    wrote = False
    for spec in layers:
        if not cost_mod.eligible(spec):
            continue
        w = params[spec.name]["w"]
        key = cache.key_for(
            spec, batch, base_cfg, w_density=TuneCache.weight_density(w)
        )
        entry = cache.get(key)
        stale = False
        if entry is not None and not override_in_space(
            entry.get("override") or {}, base_cfg, space
        ):
            # The cached winner can no longer be produced by a search over
            # the live space — the space (or the config surface) moved since
            # it was written.  Applying it would resurrect a retired config,
            # so re-search instead (even under mode="cached": a stale entry
            # is a cache *defect*, not a plain miss).
            warnings.warn(
                f"tune cache entry for layer {spec.name!r} carries override "
                f"{entry.get('override')!r} outside the current search "
                f"space; ignoring it and re-searching",
                UserWarning,
                stacklevel=2,
            )
            cache.hits -= 1  # get() counted a hit before validation
            cache.misses += 1
            cache.stale += 1
            entry = None
            stale = True
        if entry is not None:
            if entry["override"]:
                overrides[spec.name] = dict(entry["override"])
            if results is not None:
                results.append({"name": spec.name, "source": "cache", **entry})
            continue
        if mode == "cached" and not stale:
            if recorder is not None:
                recorder.inc("tune/cache_miss_fallback")
            if results is not None:
                results.append({"name": spec.name, "source": "miss", "override": {}})
            continue
        d = (
            act_density.get(spec.name, 1.0)
            if isinstance(act_density, dict)
            else (1.0 if act_density is None else float(act_density))
        )
        res = search_layer(
            spec,
            params[spec.name],
            batch,
            base_cfg,
            space=space,
            act_density=d,
            measure=measure,
            interpret=interpret,
            recorder=recorder,
        )
        cache.searches += 1
        if recorder is not None:
            recorder.inc("tune/searches")
        cache.put(
            key,
            res.override,
            cost=res.best["cost"],
            default_cost=res.default["cost"],
            executed_makespan=res.best["executed_makespan"],
            default_executed_makespan=res.default["executed_makespan"],
        )
        wrote = True
        if res.override:
            overrides[spec.name] = res.override
        if results is not None:
            results.append({"name": spec.name, "source": "search", "result": res})
    if wrote:
        cache.save()
    return overrides
