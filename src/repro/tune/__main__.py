"""``python -m repro.tune`` — tune a network end-to-end, print before/after.

Builds the paper's evaluation networks (§5.1 VGG16 / MobileNetV1, reduced
input resolution by default so the CLI finishes in seconds) with seeded
block-pruned weights at the published per-layer densities, searches every
eligible layer's config, and prints the default-vs-tuned cost table.  The
winners land in the persistent tune cache, so a subsequent
``phantom.compile(..., tune="cached")`` picks them up with zero searches.

``--smoke`` is the tier-1 CI mode: one small conv layer, measured phase
stubbed out (cost model only), asserting that ``tune="search"`` produces a
cache file and that a second compile with ``tune="cached"`` consumes it
with **zero** re-searches and identical outputs.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

import numpy as np

from repro.core import netlib
from repro.core.dataflow import ConvSpec
from repro.core.phantom_linear import PhantomConfig
from repro.core.sparsity import block_prune

from .cache import TuneCache
from .search import tune_overrides

_MODELS = {
    "vgg16": (
        netlib.vgg16_layers,
        netlib.VGG16_WEIGHT_DENSITY,
        netlib.VGG16_ACT_DENSITY,
    ),
    "mobilenet": (
        netlib.mobilenet_layers,
        netlib.MOBILENET_WEIGHT_DENSITY,
        netlib.MOBILENET_ACT_DENSITY,
    ),
}


def build_params(layers, w_density: dict, cfg: PhantomConfig, seed: int = 0):
    """Seeded params pytree with block-pruned weights at the per-layer
    published densities (same pruning primitive the train path uses)."""
    rng = np.random.default_rng(seed)
    params = {}
    for spec in layers:
        if isinstance(spec, ConvSpec):
            cpg = 1 if spec.depthwise else spec.in_ch
            shape = (spec.kh, spec.kw, cpg, spec.out_ch)
            n_out = spec.out_ch
        else:
            shape = (spec.in_dim, spec.out_dim)
            n_out = spec.out_dim
        w = rng.standard_normal(shape).astype(np.float32) * 0.05
        w2 = w.reshape(-1, n_out)
        mask = block_prune(
            w2, w_density.get(spec.name, 0.25), tuple(cfg.block[1:])
        )
        params[spec.name] = {
            "w": (w2 * mask).reshape(shape),
            "b": np.zeros((n_out,), dtype=np.float32),
        }
    return params


def _fmt_override(ov: dict) -> str:
    if not ov:
        return "(default)"
    return ",".join(f"{k}={v}" for k, v in sorted(ov.items()))


def _table(results) -> tuple[str, float, float]:
    """Per-layer before/after rows → (text, Σ default cost, Σ tuned cost)."""
    rows, tot_d, tot_t = [], 0.0, 0.0
    for r in results:
        if r["source"] == "search":
            res = r["result"]
            d, t, ov = res.default["cost"], res.best["cost"], res.override
        elif r["source"] == "cache":
            d, t, ov = r.get("default_cost", 0.0), r.get("cost", 0.0), r["override"]
        else:  # cached-mode miss: base config, no numbers to report
            d = t = 0.0
            ov = {}
        tot_d += d
        tot_t += t
        speed = (d / t) if t else 1.0
        rows.append(
            f"{r['name']:<12} {r['source']:<7} {d:>14.0f} {t:>14.0f} "
            f"{speed:>7.2f}x  {_fmt_override(ov)}"
        )
    head = (
        f"{'layer':<12} {'source':<7} {'default cost':>14} {'tuned cost':>14} "
        f"{'speedup':>8}  override"
    )
    return "\n".join([head, "-" * len(head), *rows]), tot_d, tot_t


def run_model(name: str, args, cache: TuneCache) -> None:
    make, wd, ad = _MODELS[name]
    layers = make(include_fc=True, input_hw=args.input_hw)
    cfg = PhantomConfig(enabled=True, block=(args.block,) * 3)
    params = build_params(layers, wd, cfg, seed=args.seed)
    results: list = []
    tune_overrides(
        layers,
        params,
        args.batch,
        cfg,
        cache=cache,
        mode="search",
        act_density=ad,
        measure=args.measure,
        results=results,
    )
    text, tot_d, tot_t = _table(results)
    print(f"\n== {name} (input {args.input_hw}x{args.input_hw}, "
          f"batch {args.batch}, block {args.block}) ==")
    print(text)
    total_speed = (tot_d / tot_t) if tot_t else 1.0
    print(f"total cost: {tot_d:.0f} -> {tot_t:.0f} ({total_speed:.2f}x); "
          f"cache: {cache.counters()}")


def run_smoke(args) -> int:
    """CI tier-1 smoke: search → cache file → cached compile, zero re-search.

    The measured phase is stubbed to the cost model (``measure=0``), so this
    is deterministic and takes seconds.  Returns a process exit code.
    """
    import phantom

    cache_path = args.cache or os.path.join(
        tempfile.mkdtemp(prefix="phantom-tune-smoke-"), "tune_cache.json"
    )
    spec = ConvSpec("c1", in_ch=16, out_ch=64, in_h=14, in_w=14, kh=3, kw=3)
    cfg = PhantomConfig(enabled=True, block=(16, 16, 16))
    params = build_params([spec], {"c1": 0.3}, cfg, seed=args.seed)

    cache = TuneCache(cache_path)
    prog = phantom.compile(
        [spec], params, cfg, batch=args.batch, tune="search", tune_cache=cache
    )
    if not os.path.exists(cache_path):
        print(f"SMOKE FAIL: no cache file at {cache_path}")
        return 1
    if cache.searches < 1:
        print(f"SMOKE FAIL: expected >=1 search, counters {cache.counters()}")
        return 1

    # Fresh cache object = fresh counters: a warm-cache compile must be pure
    # lookup — zero searches, zero misses, one hit per eligible layer.
    cache2 = TuneCache(cache_path)
    prog2 = phantom.compile(
        [spec], params, cfg, batch=args.batch, tune="cached", tune_cache=cache2
    )
    c = cache2.counters()
    if c["searches"] != 0 or c["misses"] != 0 or c["hits"] != 1:
        print(f"SMOKE FAIL: warm-cache compile was not search-free: {c}")
        return 1
    if prog2.overrides != prog.overrides:
        print(
            f"SMOKE FAIL: cached overrides {prog2.overrides} != "
            f"searched {prog.overrides}"
        )
        return 1
    rng = np.random.default_rng(args.seed)
    x = np.maximum(
        rng.standard_normal((args.batch, 14, 14, 16)), 0
    ).astype(np.float32)
    y1, y2 = np.asarray(prog(x)), np.asarray(prog2(x))
    if not np.array_equal(y1, y2):
        print("SMOKE FAIL: searched and cached programs disagree on outputs")
        return 1
    print(f"SMOKE OK: {cache_path} ({len(cache2)} entries, "
          f"tuned: {_fmt_override(prog.overrides.get('c1', {}))})")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.tune", description=__doc__.split("\n")[0]
    )
    p.add_argument("--model", choices=["vgg16", "mobilenet", "both"],
                   default="both")
    p.add_argument("--batch", type=int, default=1)
    p.add_argument("--input-hw", type=int, default=32,
                   help="input resolution (default 32: reduced for speed; "
                   "the paper evaluates 224)")
    p.add_argument("--block", type=int, default=32,
                   help="base square block size (default 32)")
    p.add_argument("--measure", type=int, default=0,
                   help="wall-time the top N cost-shortlisted candidates per "
                   "layer on the real kernel path (default 0: cost model only)")
    p.add_argument("--cache", default=None,
                   help="tune cache path (default checkpoint/tune_cache.json; "
                   "--smoke defaults to a temp dir)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--smoke", action="store_true",
                   help="CI tier-1 mode: one small conv layer, assert the "
                   "cache is produced then consumed with zero re-search")
    args = p.parse_args(argv)

    if args.smoke:
        return run_smoke(args)

    cache = TuneCache(args.cache or "checkpoint/tune_cache.json")
    models = ["vgg16", "mobilenet"] if args.model == "both" else [args.model]
    for name in models:
        run_model(name, args, cache)
    return 0


if __name__ == "__main__":
    sys.exit(main())
