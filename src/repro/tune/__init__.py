"""Autotuning subsystem (DESIGN.md §12): per-layer PhantomConfig search.

One layer, one best config: the global :class:`~repro.core.phantom_linear.
PhantomConfig` a network compiles under is rarely optimal for *every* layer
— a skewed-density conv wants more cores and inter-core balancing, a tiny FC
wants one core and zero lookahead.  This package searches the scheduling
knobs (``cores`` / ``balance`` / ``conv_mode`` / ``lookahead`` / ``block``)
per layer:

* :mod:`repro.tune.space`  — candidate enumeration + structural pruning;
* :mod:`repro.tune.cost`   — the analytic TDS/makespan cost model that
  rejects most candidates without compiling anything;
* :mod:`repro.tune.search` — the engine: cost phase, optional measured
  shortlist on the real kernel path, never worse than the default;
* :mod:`repro.tune.cache`  — the persistent, versioned result cache that
  makes search a once-per-fleet cost.

Entry points: ``phantom.compile(..., tune="cached"|"search")`` for
programs, ``python -m repro.tune`` for the end-to-end CLI.
"""
from .cache import (
    TUNE_SCHEMA,
    TuneCache,
    backend_fingerprint,
    density_bucket,
    layer_signature,
)
from .cost import candidate_cost, eligible, layer_grid, synth_act_bits
from .search import Trial, TuneResult, search_layer, tune_overrides
from .space import BENCH_SPACE, DEFAULT_SPACE, SearchSpace, candidates

__all__ = [
    "TUNE_SCHEMA",
    "TuneCache",
    "backend_fingerprint",
    "density_bucket",
    "layer_signature",
    "candidate_cost",
    "eligible",
    "layer_grid",
    "synth_act_bits",
    "Trial",
    "TuneResult",
    "search_layer",
    "tune_overrides",
    "BENCH_SPACE",
    "DEFAULT_SPACE",
    "SearchSpace",
    "candidates",
]
