"""Analytic cost model: score a candidate config without touching the kernel.

The pre-filter of the search engine (DESIGN.md §12).  For one layer and one
candidate :class:`~repro.core.phantom_linear.PhantomConfig` it predicts the
deterministic schedule metrics the runtime would exhibit:

* ``queue_steps``     — padded per-core queue length (the gated grid bound);
* ``executed_makespan`` — grid steps actually executed per §4.6 lock-step
  slot: per-core max of the §3.4 TDS cycle count under the layer's
  activation tile bits (``lookahead`` compaction included via
  :func:`repro.core.tds.batch_cycles`);
* ``work_makespan``   — per-core max MAC-block work, the §4.2 balance metric
  (:func:`repro.core.balance.inter_core_schedule` on the per-column costs);
* ``weight_bytes``    — packed payload HBM traffic;
* ``cost``            — the scalar the search minimises:
  ``executed_makespan × macs-per-grid-step``.  Normalising by the per-step
  MAC volume makes candidates with *different* block sizes / conv lowerings
  comparable (a smaller tile needs more steps, each moving less work).

Exactness: the queue construction is shared with the real weight-load path
(:func:`repro.kernels.ops.cost_artifact` calls the same builders
``prepare_weight`` / ``_prepare_direct`` use), so for a fixed block size the
predicted step counts equal the prepared plan's — which is what lets the
tuner guarantee "never worse than the default" on these metrics: the
default config is always in the candidate set and the winner is the argmin.

Activation bits: callers pass the real tile bits of a calibration batch
(``act_bits``) when they have one; otherwise a deterministic low-discrepancy
pattern at ``act_density`` stands in (same pattern for every candidate, so
the comparison stays apples-to-apples).
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import balance as cbalance
from repro.core import blocksparse as bs
from repro.core.dataflow import ConvSpec, FCSpec
from repro.kernels import ops, phantom_conv

__all__ = ["layer_grid", "synth_act_bits", "candidate_cost", "eligible"]


def eligible(spec) -> bool:
    """Whether the cost model understands this spec type (Conv/FC today —
    a new layer kind opts in by subclassing either spec or extending
    :func:`layer_grid`)."""
    return isinstance(spec, (ConvSpec, FCSpec))


def layer_grid(spec, w: np.ndarray, batch: int, cfg):
    """The (bmask, m_tiles, conv, macs_per_step) grid a config induces.

    Mirrors the real lowerings exactly: FC and im2col conv tile M by
    ``cfg.block[0]``; direct conv tiles M per output row (one ``[ow, bk]``
    gather per step), with the weight tap-aligned so no k-tile straddles a
    filter tap.  ``conv`` is the ``{"kw", "ct"}`` dict the direct-conv queue
    builder needs (``None`` for matmul-shaped queues).
    """
    bm, bk, bn = cfg.block
    w = np.asarray(w)
    if isinstance(spec, FCSpec):
        bmask = bs.block_mask_from_dense(w, (bk, bn)).mask
        mt = math.ceil(batch / bm)
        return bmask, mt, None, bm * bk * bn
    if not isinstance(spec, ConvSpec):
        raise TypeError(f"cost model does not understand {type(spec).__name__}")
    groups = spec.in_ch if spec.depthwise else 1
    kh, kw = spec.kh, spec.kw
    cin = spec.in_ch
    oh, ow = spec.out_hw
    w2d = (
        w.reshape(kh * kw * cin, spec.out_ch)
        if groups == 1
        else phantom_conv.grouped_weight_matrix(w, groups)
    )
    if cfg.conv_mode == "direct":
        ct = math.ceil(cin / bk)
        cp = ct * bk
        w3 = np.zeros((kh * kw, cp, spec.out_ch), dtype=w2d.dtype)
        w3[:, :cin] = w2d.reshape(kh * kw, cin, spec.out_ch)
        bmask = bs.block_mask_from_dense(w3.reshape(kh * kw * cp, spec.out_ch), (bk, bn)).mask
        return bmask, batch * oh, {"kw": kw, "ct": ct}, ow * bk * bn
    bmask = bs.block_mask_from_dense(w2d, (bk, bn)).mask
    mt = math.ceil(batch * oh * ow / bm)
    return bmask, mt, None, bm * bk * bn


def synth_act_bits(m_tiles: int, k_tiles: int, density: float) -> np.ndarray:
    """Deterministic int32 [Mt, Kt] tile bits at ≈``density`` live tiles.

    Golden-ratio low-discrepancy over the flat (mi, ki) index: live tiles
    spread uniformly, the same pattern for every candidate at the same grid
    shape, no RNG state.  ``density >= 1`` short-circuits to all-live (the
    conservative default when no calibration sample exists).
    """
    d = float(density)
    n = m_tiles * k_tiles
    if d >= 1.0:
        return np.ones((m_tiles, k_tiles), dtype=np.int32)
    phase = (np.arange(n, dtype=np.float64) * 0.6180339887498949) % 1.0
    return (phase < d).astype(np.int32).reshape(m_tiles, k_tiles)


def candidate_cost(
    spec,
    w: np.ndarray,
    batch: int,
    cfg,
    *,
    act_bits: np.ndarray | None = None,
    act_density: float = 1.0,
) -> dict:
    """Deterministic schedule metrics for running ``spec`` under ``cfg``.

    ``act_bits`` (int [Mt, Kt] for *this candidate's* grid) overrides the
    synthetic pattern — only usable when every candidate shares the grid
    shape (fixed block + conv_mode); the search engine enforces that.
    """
    bmask, mt, conv, macs_per_step = layer_grid(spec, w, batch, cfg)
    kt, nt = bmask.shape
    cores = max(1, int(cfg.cores))
    if cores > nt:
        raise ValueError(
            f"{cores} cores over {nt} output tile-columns: empty cores are "
            f"pure overhead (prune this candidate upstream)"
        )
    la = int(cfg.lookahead or 0)
    art = ops.cost_artifact(
        bmask,
        mt,
        cores=cores,
        balance=cfg.balance,
        interleave=cfg.interleave,
        conv=conv,
    )
    bits = (
        synth_act_bits(mt, kt, act_density)
        if act_bits is None
        else np.asarray(act_bits, dtype=np.int32)
    )
    if bits.shape != (mt, kt):
        raise ValueError(
            f"act_bits shape {bits.shape} does not match this candidate's "
            f"grid ({mt}, {kt}) — calibration bits only transfer between "
            f"candidates sharing block/conv_mode"
        )
    st = ops.lookahead_stats(art, bits, lookahead=la)
    # §4.2 work makespan on the same per-column block costs the partitioner
    # sees; capacity-capped like partition_columns so the two agree.
    col_cost = bmask.sum(axis=0).astype(np.float64)
    if cores > 1:
        sched = cbalance.inter_core_schedule(
            col_cost,
            cores,
            balanced=cfg.balance in ("inter", "full"),
            capacity=math.ceil(nt / cores),
        )
        work_makespan = int(sched.makespan) * mt
    else:
        work_makespan = int(col_cost.sum()) * mt
    bk, bn = cfg.block[1], cfg.block[2]
    itemsize = np.dtype(cfg.dtype).itemsize
    return {
        "queue_steps": int(st["queue_steps"]),
        "executed_makespan": int(st["executed_steps"]),
        "work_makespan": int(work_makespan),
        "utilization": float(st["utilization"]),
        "weight_bytes": int(bmask.sum()) * bk * bn * itemsize,
        "macs_per_step": int(macs_per_step),
        "cores": cores,
        "cost": float(st["executed_steps"]) * float(macs_per_step),
    }
