"""Persistent tuning cache: per-layer config overrides, keyed and versioned.

The search phase (:mod:`repro.tune.search`) is the expensive part of
autotuning — the cache makes it a once-per-fleet cost, exactly like the
program checkpoint makes lowering one (DESIGN.md §8/§12).  One JSON file
(default ``checkpoint/tune_cache.json``) holds ``{key: entry}`` where

* **key** = ``layer signature ⊕ density bucket ⊕ backend fingerprint``:

  - the *layer signature* captures everything that changes the candidate
    cost landscape at weight-load time: spec type + geometry fields, the
    batch size (queues bake in the M-tile count), and the non-searched base
    config knobs (block/dtype/act_threshold/...);
  - the *density bucket* coarsens the measured weight element density to a
    fixed grid so retrained weights at similar sparsity reuse each other's
    tunings, while a density shift big enough to change the best schedule
    lands in a new bucket (a miss, not a stale hit);
  - the *backend fingerprint* (platform + device kind + jax version) scopes
    measured-phase results to the hardware they were measured on.

* **entry** = the winning override fields (partial ``PhantomConfig`` diff,
  JSON-able) plus the cost-model metrics it won with.

**Invalidation**: the file stamps ``schema = TUNE_SCHEMA``; a bump discards
every entry at load (counted in :attr:`TuneCache.invalidations`).  Key
mismatches (density bucket moved, different backend, different geometry)
are ordinary misses.  Writes are atomic (tmp + ``os.replace``), mirroring
the checkpoint writer's crash-safety contract.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

__all__ = [
    "TUNE_SCHEMA",
    "TuneCache",
    "backend_fingerprint",
    "density_bucket",
    "layer_signature",
]

#: Bump on any change to the entry layout, the cost model's metrics, or the
#: candidate space semantics — cached winners from an older scheme must be
#: re-searched, not trusted.
TUNE_SCHEMA = 1

#: Weight element-density bucket edges: an entry tuned at density d is reused
#: for any density in the same half-open bucket [lo, hi).
DENSITY_EDGES = (0.0, 0.05, 0.1, 0.2, 0.3, 0.45, 0.6, 0.8, 1.01)


def backend_fingerprint() -> str:
    """``platform:device_kind:jax<version>`` — the hardware scope of
    measured-phase results (cost-model metrics are machine-independent, but
    the shortlist measurement is not)."""
    import jax

    dev = jax.devices()[0]
    return f"{jax.default_backend()}:{dev.device_kind}:jax{jax.__version__}"


def density_bucket(density: float) -> str:
    """The half-open bucket ``[lo, hi)`` containing ``density``, as a stable
    string key component (e.g. ``d0.20-0.30``)."""
    d = float(density)
    for lo, hi in zip(DENSITY_EDGES, DENSITY_EDGES[1:]):
        if lo <= d < hi:
            return f"d{lo:g}-{hi:g}"
    return f"d{DENSITY_EDGES[-2]:g}-{DENSITY_EDGES[-1]:g}"


#: Base-config fields that are *searched* — excluded from the signature so a
#: cache entry keyed under one base config is found again regardless of which
#: searched values the base happened to hold.
_SEARCHED_FIELDS = ("cores", "balance", "conv_mode", "lookahead", "block")


def layer_signature(spec, batch: int, base_cfg) -> str:
    """Deterministic signature of (layer geometry, batch, non-searched base
    knobs).  Layer kinds may refine it by defining ``tune_signature(spec,
    batch)`` (see :mod:`repro.program.registry`); the fallback is the spec's
    dataclass fields minus its display name, so two identically-shaped
    layers share tunings."""
    sig = None
    try:  # registry import is optional: the cache works on bare specs too
        from repro.program.registry import kind_for

        kind = kind_for(spec)
        ts = getattr(kind, "tune_signature", None)
        if ts is not None:
            sig = ts(spec, batch)
    except Exception:
        sig = None
    if sig is None:
        fields = {
            f.name: getattr(spec, f.name)
            for f in dataclasses.fields(spec)
            if f.name != "name"
        }
        parts = [f"{k}={fields[k]}" for k in sorted(fields)]
        sig = f"{type(spec).__name__}({','.join(parts)})@b{batch}"
    base = ";".join(
        f"{f.name}={getattr(base_cfg, f.name)}"
        for f in dataclasses.fields(base_cfg)
        if f.name not in _SEARCHED_FIELDS
    )
    return f"{sig}|{base}"


class TuneCache:
    """The persistent per-layer tuning cache (see module docstring).

    Counters (``hits`` / ``misses`` / ``searches`` / ``invalidations``) are
    per-instance and cumulative — the zero-re-search acceptance check
    (``compile(tune="cached")`` on a warm cache ⇒ ``searches == 0``) asserts
    directly on them.
    """

    def __init__(
        self,
        path: str = "checkpoint/tune_cache.json",
        *,
        schema: int = TUNE_SCHEMA,
        backend: str | None = None,
    ):
        self.path = str(path)
        self.schema = int(schema)
        self.backend = backend_fingerprint() if backend is None else str(backend)
        self.hits = 0
        self.misses = 0
        self.searches = 0
        self.invalidations = 0
        #: hits whose stored override fell outside the live search space and
        #: were therefore discarded and re-searched (see tune_overrides) —
        #: a stale entry is a cache defect, tracked separately from misses.
        self.stale = 0
        self._entries: dict[str, dict] = {}
        self._load()

    # -- persistence ---------------------------------------------------------
    def _load(self) -> None:
        if not os.path.exists(self.path):
            return
        try:
            data = json.loads(open(self.path).read())
        except (OSError, json.JSONDecodeError):
            self.invalidations += 1  # unreadable file == schema-invalid file
            return
        if not isinstance(data, dict) or data.get("schema") != self.schema:
            # Schema bump: every entry was produced under different
            # semantics — drop them all (the file is rewritten on next save).
            self.invalidations += 1
            return
        self._entries = dict(data.get("entries", {}))

    def save(self) -> str:
        """Atomically persist the cache (tmp + rename; never half-written)."""
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as f:
            json.dump(
                {"schema": self.schema, "entries": self._entries},
                f,
                indent=2,
                sort_keys=True,
            )
            f.write("\n")
        os.replace(tmp, self.path)
        return self.path

    # -- keys ----------------------------------------------------------------
    def key_for(self, spec, batch: int, base_cfg, *, w_density: float) -> str:
        """The full cache key: signature ⊕ density bucket ⊕ backend."""
        return "|".join(
            (
                layer_signature(spec, batch, base_cfg),
                density_bucket(w_density),
                self.backend,
            )
        )

    @staticmethod
    def weight_density(w) -> float:
        """Element density of a weight tensor — the quantity bucketed into
        the key (block density depends on the searched block size, so it
        cannot key the cache)."""
        w = np.asarray(w)
        return float(np.count_nonzero(w)) / max(1, w.size)

    # -- lookup / store ------------------------------------------------------
    def get(self, key: str) -> dict | None:
        """The cached entry for ``key`` (``{"override": ..., ...}``), or
        ``None`` on a miss.  Counts hits/misses."""
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        return entry

    def put(self, key: str, override: dict, **info) -> dict:
        """Store a search winner.  ``override`` is the partial PhantomConfig
        field diff; ``info`` (costs, measured µs, ...) rides along for
        reporting.  Not persisted until :meth:`save`."""
        entry = {"override": dict(override), **info}
        self._entries[key] = entry
        return entry

    def __len__(self) -> int:
        return len(self._entries)

    def counters(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "searches": self.searches,
            "invalidations": self.invalidations,
            "stale": self.stale,
            "entries": len(self._entries),
        }
