"""§Roofline report: render the dry-run JSONLs into the per-cell table.

Prefers roofline_corrected.jsonl (scan-body cost correction, single-pod —
see repro/launch/roofline_sweep.py) and falls back to the raw
dryrun_results.jsonl terms for the multi-pod cells, tagging each row with
its source.
"""
from __future__ import annotations

import json
import os

from .common import emit

_ROOT = os.path.join(os.path.dirname(__file__), "..")
RAW = os.path.join(_ROOT, "dryrun_results.jsonl")
CORRECTED = os.path.join(_ROOT, "roofline_corrected.jsonl")


def _read(path):
    recs = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                recs[(r["arch"], r["shape"], r.get("mesh", "16x16"))] = r
    return recs


def load() -> list[dict]:
    raw = _read(RAW)
    out = []
    for key, r in raw.items():
        if r.get("ok"):
            r = dict(r["roofline"], ok=True, arch=key[0], shape=key[1], mesh=key[2],
                     src="raw")
        out.append(r)
    for key, r in _read(CORRECTED).items():
        if r.get("ok"):
            out.append(dict(r, src="corrected"))
    return out


def run(path=None):
    rows = []
    for r in sorted(load(), key=lambda r: (r["arch"], r["shape"],
                                           r.get("mesh", ""), r.get("src", ""))):
        name = f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh','16x16')}/{r.get('src','raw')}"
        if not r.get("ok"):
            rows.append((name, "0", f"FAILED:{r.get('error','?')[:60]}"))
            continue
        rows.append(
            (name, "0",
             f"compute_ms={r['compute_s']*1e3:.3f};memory_ms={r['memory_s']*1e3:.3f};"
             f"collective_ms={r['collective_s']*1e3:.3f};dominant={r['dominant']};"
             f"useful={r['model_flops_ratio']:.3f}")
        )
    return emit(rows)


if __name__ == "__main__":
    run()
