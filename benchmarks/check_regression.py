"""Perf-regression gate over the ``BENCH_conv.json`` trajectory.

Runs the kernel bench fresh (same rng order as ``kernel_bench.run``, so the
structural metrics are bit-reproducible) and compares the resulting point
against the last committed trajectory point:

- **Structural metrics** (grid/queue shapes — multi-core makespans, the
  balance speedup, lookahead executed steps / step reduction / utilization,
  activation-byte ratios) are machine-independent and deterministic; they
  are gated with a small tolerance band so intentional re-tunings need a
  baseline refresh but drift fails loudly.
- **Wall-clock metrics** (interpret-mode CPU µs) do not transfer across
  runners; they are printed as advisory deltas only.

Tolerance bands
---------------
Each structural metric carries ``(direction, rel_tol)``:

* ``direction`` names which way is *worse* (``higher_worse`` for step /
  byte / makespan counts, ``lower_worse`` for speedups and utilization) —
  improvements of any size always pass;
* ``rel_tol`` is the relative band around the baseline inside which a
  worse value still passes.  The default **5%** absorbs intentional small
  re-tunings (an rng-order shift when a bench case is added, a tie-break
  change in a scheduler) without a baseline refresh, while genuine
  scheduling regressions — a worse §4.3.1 partition, lost §3.4 compaction,
  a tuner that stopped finding wins — move these metrics well past it.
  **0%** marks by-construction invariants (``direct_patch_bytes == 0``,
  the tuner's never-worse layer count): any loss is a real break.

Usage (CI tier-1)::

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline BENCH_conv.json --out bench_fresh.json

Exit code 1 on any structural regression, with the offending row named in
the output (a vanished baseline metric is itself a failure — a silently
dropped bench case must not pass the gate).  ``check_point`` is the pure
comparison (unit-tested with doctored baselines in ``tests/test_obs.py``).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# (metric, direction, rel_tol) — direction names which way is WORSE; see
# the "Tolerance bands" section of the module docstring for the band
# semantics (5% = re-tuning slack, 0% = by-construction invariant).
STRUCTURAL = [
    ("multicore_naive_work_makespan", "higher_worse", 0.05),
    ("multicore_balanced_work_makespan", "higher_worse", 0.05),
    ("multicore_balanced_makespan", "higher_worse", 0.05),
    ("multicore_balanced_imbalance", "higher_worse", 0.05),
    ("multicore_balance_speedup", "lower_worse", 0.05),
    ("lookahead_executed_steps", "higher_worse", 0.05),
    ("lookahead_step_reduction", "lower_worse", 0.05),
    ("lookahead_utilization", "lower_worse", 0.05),
    ("activation_bytes_ratio", "higher_worse", 0.05),
    ("direct_patch_bytes", "higher_worse", 0.0),  # 0 by construction (§3.6)
    # Autotuner (DESIGN.md §12): tuned cost / speedup over the fixed bench
    # layer set are deterministic cost-model outputs; the improved-layer
    # count is the never-worse acceptance floor (0% band: losing a win on
    # any bench layer means the tuner regressed, not drifted).
    ("autotune_default_cost", "higher_worse", 0.05),
    ("autotune_tuned_cost", "higher_worse", 0.05),
    ("autotune_cost_speedup", "lower_worse", 0.05),
    ("autotune_layers_improved", "lower_worse", 0.0),
]

# Interpret-mode wall times: reported, never gated.
ADVISORY = [
    "direct_us",
    "im2col_us",
    "speedup_direct_over_im2col",
    "lookahead_gated_us",
    "lookahead_compacted_us",
]


def check_point(fresh: dict, baseline: dict) -> tuple[list[str], list[str]]:
    """Compare a fresh bench point against a baseline point.

    Returns ``(failures, notes)``: failures are structural metrics worse
    than their tolerance band (or structural metrics that vanished);
    notes are passing comparisons and advisory wall-time deltas.
    """
    failures, notes = [], []
    for key, direction, tol in STRUCTURAL:
        if key not in baseline:
            notes.append(f"{key}: no baseline yet (new metric)")
            continue
        if key not in fresh:
            failures.append(f"{key}: missing from fresh run (baseline {baseline[key]})")
            continue
        base, new = float(baseline[key]), float(fresh[key])
        scale = abs(base) if base else 1.0
        worse = (new - base) / scale
        if direction == "lower_worse":
            worse = -worse
        if worse > tol:
            failures.append(
                f"{key}: {base:g} -> {new:g} ({worse:+.1%} worse, tol {tol:.0%})"
            )
        else:
            notes.append(f"{key}: {base:g} -> {new:g} (ok)")
    for key in ADVISORY:
        if key in baseline and key in fresh:
            base, new = float(baseline[key]), float(fresh[key])
            rel = (new - base) / base if base else 0.0
            notes.append(f"{key}: {base:g} -> {new:g} ({rel:+.1%}, advisory)")
    return failures, notes


def fresh_point() -> dict:
    """Run the kernel bench end to end and build a trajectory point.

    Reuses :func:`kernel_bench.run` verbatim so the shared-rng draw order —
    and therefore every structural metric — matches how the committed
    ``BENCH_conv.json`` points were produced.
    """
    from benchmarks import kernel_bench

    _, mode_result, mc_result, la_result, at_result = kernel_bench.run()
    return kernel_bench.build_point(mode_result, mc_result, la_result, at_result)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_conv.json")
    ap.add_argument("--out", default=None, help="write the fresh point JSON here")
    args = ap.parse_args(argv)

    hist = json.loads(pathlib.Path(args.baseline).read_text())
    if isinstance(hist, list) and not hist:
        print(
            f"check_regression: {args.baseline} holds an empty history — "
            f"run `python -m benchmarks.kernel_bench` once to record the "
            f"first trajectory point, then re-run this gate"
        )
        return 1
    baseline = hist[-1] if isinstance(hist, list) else hist
    fresh = fresh_point()
    if args.out:
        pathlib.Path(args.out).write_text(json.dumps(fresh, indent=2) + "\n")

    failures, notes = check_point(fresh, baseline)
    print(f"check_regression: fresh point vs {args.baseline}[-1]")
    for n in notes:
        print(f"  {n}")
    if failures:
        print("REGRESSION (structural metrics worse than tolerance):")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    print(f"check_regression: OK ({len(STRUCTURAL)} structural metrics in band)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
