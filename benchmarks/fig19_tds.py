"""Fig. 19 — TDS-IO vs TDS-OO: per-layer speedup at L_f=6 and the L_f sweep.

Paper claims: at L_f=6 OO ≈ 4.8×, IO ≈ 4.5× over dense (VGG16 average);
at L_f=18 OO ≈ 7.9×, IO ≈ 6.35× (OO/IO = 1.24×).
"""
from __future__ import annotations

from repro.core import dataflow as df, simulator

from .common import FAST, emit, timed


def run(opts=FAST, lf_sweep=(6, 9, 12, 15, 18)):
    rows = []
    variants = {
        "tds_io": df.Phantom2DConfig(lookahead=6, policy="inorder"),
        "tds_oo": df.Phantom2DConfig(lookahead=6, policy="outoforder"),
    }
    res, us = timed(
        simulator.vgg16_simulation, opts=opts, variants=variants, include_fc=True
    )
    for r in res:
        rows.append((f"fig19a/{r.name}/io", f"{us:.0f}", f"{r.speedup('tds_io'):.3f}"))
        rows.append((f"fig19a/{r.name}/oo", f"{us:.0f}", f"{r.speedup('tds_oo'):.3f}"))
    for lf in lf_sweep:
        v = {
            "io": df.Phantom2DConfig(lookahead=lf, policy="inorder"),
            "oo": df.Phantom2DConfig(lookahead=lf, policy="outoforder"),
        }
        res, us = timed(simulator.vgg16_simulation, opts=opts, variants=v)
        io = simulator.network_summary(res, "io")
        oo = simulator.network_summary(res, "oo")
        rows.append((f"fig19b/Lf{lf}/io", f"{us:.0f}", f"{io:.3f}"))
        rows.append((f"fig19b/Lf{lf}/oo", f"{us:.0f}", f"{oo:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
