"""Figs. 21–22 — sensitivity to sparsity and L_f: speedup and average
multiplier-thread utilization for the CV (L_f=9), MD (L_f=18), HP (L_f=27)
configurations of Phantom-2D, on VGG16 and MobileNet layer geometry.

Paper claims: >90% thread utilization up to 60% two-sided sparsity (VGG16);
at 80% sparsity MD ≈ 1.43× and HP ≈ 1.65× over CV; balanced/unbalanced at
80% ≈ 1.4× (HP).
"""
from __future__ import annotations

import numpy as np

from repro.core import dataflow as df, netlib, simulator

from .common import FAST, emit, timed

POINTS = (0.2, 0.4, 0.6, 0.8, 0.9)
CONFIGS = {
    "cv": df.Phantom2DConfig(lookahead=9),
    "md": df.Phantom2DConfig(lookahead=18),
    "hp": df.Phantom2DConfig(lookahead=27),
    "hp_unbal": df.Phantom2DConfig(
        lookahead=27, intra_balance=False, inter_balance=False
    ),
}


def run(opts=FAST):
    rows = []
    for net, layer_fn in (("vgg16", netlib.vgg16_layers), ("mobilenet", netlib.mobilenet_layers)):
        layers = layer_fn(include_fc=False)[2:8]  # representative mid-net slab
        for sp in POINTS:
            dens = 1.0 - sp
            wd = np.full(len(layers), dens)
            ad = np.full(len(layers), dens)
            res, us = timed(
                simulator.simulate_network, layers, wd, ad, CONFIGS, opts
            )
            for name in CONFIGS:
                sp_ = simulator.network_summary(res, name)
                util = float(np.mean([r.utilization[name] for r in res]))
                rows.append(
                    (f"fig21/{net}/s{sp:.1f}/{name}", f"{us:.0f}",
                     f"{sp_:.3f};util={util:.3f}")
                )
    return emit(rows)


if __name__ == "__main__":
    run()
