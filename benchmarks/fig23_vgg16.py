"""Fig. 23 — Phantom-2D (CV/MD/HP) vs dense, SCNN, SparTen on sparse VGG16.

Per the paper, FC layers are omitted (SCNN/SparTen cannot run them) and the
net has no non-unit-stride convs.  Paper claims (avg over layers):
  CV: 1.05× SparTen, 2.56× SCNN,  6.4× dense
  MD: 1.57×,         3.8×,        9.9×
  HP: 1.98×,         4.1×,       11×
"""
from __future__ import annotations

from repro.core import dataflow as df, simulator

from .common import FAST, emit, timed

CONFIGS = {
    "cv": df.Phantom2DConfig(lookahead=9),
    "md": df.Phantom2DConfig(lookahead=18),
    "hp": df.Phantom2DConfig(lookahead=27),
}


def run(opts=FAST):
    res, us = timed(
        simulator.vgg16_simulation,
        opts=opts,
        variants=CONFIGS,
        baselines=("scnn", "sparten"),
        include_fc=False,
    )
    rows = []
    for ver in CONFIGS:
        for base in ("dense", "scnn", "sparten"):
            s = simulator.network_summary(res, ver, base=base)
            rows.append((f"fig23/{ver}_vs_{base}", f"{us:.0f}", f"{s:.3f}"))
    # FC-inclusive Phantom numbers (§5.2.4 ¶2: 13×/11.4×/8.6× over dense).
    res_fc, us2 = timed(
        simulator.vgg16_simulation, opts=opts, variants=CONFIGS, include_fc=True
    )
    for ver in CONFIGS:
        s = simulator.network_summary(res_fc, ver)
        rows.append((f"fig23/withFC/{ver}_vs_dense", f"{us2:.0f}", f"{s:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
