"""Benchmark harness entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig19,fig23]

Prints ``name,us_per_call,derived`` CSV rows for every benchmark.  ``--full``
uses higher-fidelity simulator sampling (slower).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, help="comma list of benches")
    args = ap.parse_args()

    from . import (
        common,
        fig19_tds,
        fig20_balance,
        fig21_sensitivity,
        fig23_vgg16,
        fig24_mobilenet,
        fig25_memory,
        kernel_bench,
        roofline_report,
    )

    opts = common.FULL if args.full else common.FAST
    benches = {
        "fig19": lambda: fig19_tds.run(opts),
        "fig20": lambda: fig20_balance.run(opts),
        "fig21": lambda: fig21_sensitivity.run(opts),
        "fig23": lambda: fig23_vgg16.run(opts),
        "fig24": lambda: fig24_mobilenet.run(opts),
        "fig25": fig25_memory.run,
        "kernel": kernel_bench.run,
        "roofline": roofline_report.run,
    }
    only = set(args.only.split(",")) if args.only else None
    t0 = time.time()
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        fn()
    print(f"# total {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
