"""Fig. 24 — Phantom-2D vs Eyeriss v2 on sparse MobileNet.

Paper claims: CV ≈ 1.04×, MD ≈ 1.71×, HP ≈ 2.86× over Eyeriss v2; pointwise
layers ≈ 4.5× over Eyeriss v2 and ≈ 25× over dense for HP.
"""
from __future__ import annotations

import numpy as np

from repro.core import dataflow as df, simulator

from .common import FAST, emit, timed

CONFIGS = {
    "cv": df.Phantom2DConfig(lookahead=9),
    "md": df.Phantom2DConfig(lookahead=18),
    "hp": df.Phantom2DConfig(lookahead=27),
}


def run(opts=FAST):
    res, us = timed(
        simulator.mobilenet_simulation,
        opts=opts,
        variants=CONFIGS,
        baselines=("eyeriss_v2",),
        include_fc=False,
    )
    rows = []
    for ver in CONFIGS:
        rows.append(
            (f"fig24/{ver}_vs_eyeriss2", f"{us:.0f}",
             f"{simulator.network_summary(res, ver, base='eyeriss_v2'):.3f}")
        )
        rows.append(
            (f"fig24/{ver}_vs_dense", f"{us:.0f}",
             f"{simulator.network_summary(res, ver):.3f}")
        )
    # Pointwise-only slice (the dataflow the paper highlights).
    pw = [r for r in res if r.kind == "pw"]
    if pw:
        hp_pw = sum(r.cycles["dense"] for r in pw) / sum(r.cycles["hp"] for r in pw)
        ey_pw = sum(r.cycles["eyeriss_v2"] for r in pw) / sum(r.cycles["hp"] for r in pw)
        rows.append((f"fig24/pw/hp_vs_dense", f"{us:.0f}", f"{hp_pw:.3f}"))
        rows.append((f"fig24/pw/hp_vs_eyeriss2", f"{us:.0f}", f"{ey_pw:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
