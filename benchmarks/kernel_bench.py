"""TPU-adaptation microbench: two-sided block-sparse kernel vs dense.

On this CPU container the Pallas kernel runs in interpret mode, so wall
times are NOT TPU-representative; the *derived* metrics that transfer are
structural: grid-step compaction (queue steps vs dense tile count, = the
MXU-issue reduction on hardware) and packed-weight bytes (HBM traffic for
weights).  Dense-vs-masked jnp walltimes are included as the XLA:CPU proxy.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsity
from repro.kernels import ops

from .common import emit


def run():
    rows = []
    rng = np.random.default_rng(0)
    m = k = n = 1024
    blk = (128, 128, 128)
    for wd in (1.0, 0.5, 0.25, 0.125):
        w = rng.standard_normal((k, n)).astype(np.float32)
        if wd < 1.0:
            w *= sparsity.block_prune(w, wd, blk[1:])
        x = rng.standard_normal((m, k)).astype(np.float32)
        pw = ops.prepare_weight(w, m=m, block=blk)
        mt, kt, nt = pw.grid_tiles
        dense_steps = mt * kt * nt
        compaction = pw.steps / dense_steps
        wbytes = pw.packed.size * pw.packed.dtype.itemsize
        dbytes = k * n * 4

        xj, wj = jnp.asarray(x), jnp.asarray(w)
        f_dense = jax.jit(lambda a, b: a @ b)
        f_dense(xj, wj).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            f_dense(xj, wj).block_until_ready()
        t_dense = (time.perf_counter() - t0) / 5 * 1e6

        mask = jnp.asarray((w != 0).astype(np.float32))
        f_masked = jax.jit(lambda a, b, mm: a @ (b * mm))
        f_masked(xj, wj, mask).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            f_masked(xj, wj, mask).block_until_ready()
        t_masked = (time.perf_counter() - t0) / 5 * 1e6

        rows.append(
            (f"kernel/wd{wd}", f"{t_dense:.0f}",
             f"grid_compaction={compaction:.3f};weight_bytes_ratio={wbytes/dbytes:.3f};"
             f"masked_us={t_masked:.0f}")
        )
    return emit(rows)


if __name__ == "__main__":
    run()
