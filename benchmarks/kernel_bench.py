"""TPU-adaptation microbench: two-sided block-sparse kernel vs dense.

On this CPU container the Pallas kernel runs in interpret mode, so wall
times are NOT TPU-representative; the *derived* metrics that transfer are
structural: grid-step compaction (queue steps vs dense tile count, = the
MXU-issue reduction on hardware) and packed-weight bytes (HBM traffic for
weights).  Dense-vs-masked jnp walltimes are included as the XLA:CPU proxy.

``conv_mode_rows`` compares the two conv lowerings head to head — explicit
im2col (materialises the ``kh·kw``× patch matrix in HBM) vs the direct
implicit-im2col kernel (patch gather in-kernel; patch bytes are zero by
construction) — and ``write_conv_trajectory`` appends the result to
``BENCH_conv.json`` so the im2col→direct transition stays measurable over
time.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsity
from repro.kernels import ops, phantom_conv
from repro.obs import timeit

from .common import emit


def _conv_rows(rng):
    """im2col conv path: structural metrics per layer archetype.

    Archetypes cover what differentiates Phantom (§4): a VGG16-style 3x3
    stride-1 layer, a MobileNet stride-2 layer (the case SCNN cannot run),
    and a depthwise layer (block-diagonal weight → structural compaction).
    """
    rows = []
    cases = [
        ("vgg3x3_s1", dict(cin=128, cout=128, kh=3, stride=(1, 1), groups=1)),
        ("mbnet3x3_s2", dict(cin=64, cout=128, kh=3, stride=(2, 2), groups=1)),
        ("depthwise_s2", dict(cin=128, cout=128, kh=3, stride=(2, 2), groups=128)),
        ("pointwise", dict(cin=256, cout=256, kh=1, stride=(1, 1), groups=1)),
    ]
    b, hw, blk = 1, 28, (32, 32, 32)
    for name, c in cases:
        # Depthwise filters don't survive magnitude pruning (few, critical
        # weights — block-pruning the tiny HWIO tensor would drop whole
        # channels); their compaction comes from the structural zeros of
        # the block-diagonal im2col matrix alone.
        densities = (1.0,) if c["groups"] > 1 else (1.0, 0.3)
        for wd in densities:
            w = rng.standard_normal(
                (c["kh"], c["kh"], c["cin"] // c["groups"], c["cout"])
            ).astype(np.float32)
            if wd < 1.0:
                # Block-prune the im2col-reshaped matrix — the structured
                # pruning the TPU adaptation compacts (zero tiles leave the
                # work queue).
                w2 = w.reshape(-1, c["cout"])
                w2 *= sparsity.block_prune(w2, wd, blk[1:])
                w = w2.reshape(w.shape)
            pcw = phantom_conv.prepare_conv_weight(
                w, batch=b, in_hw=(hw, hw), stride=c["stride"],
                groups=c["groups"], block=blk,
            )
            art = pcw.pw if pcw.pw is not None else pcw.plan
            mt, kt, nt = art.grid_tiles
            dense_steps = mt * kt * nt
            x = rng.standard_normal((b, hw, hw, c["cin"])).astype(np.float32)
            xj, wj = jnp.asarray(x), jnp.asarray(w)
            dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NHWC", "HWIO", "NHWC"))
            f_dense = jax.jit(
                lambda a, k: jax.lax.conv_general_dilated(
                    a, k, c["stride"], "SAME", dimension_numbers=dn,
                    feature_group_count=c["groups"],
                )
            )
            _, t_dense = timeit(f_dense, xj, wj, reps=5, warmup=1)
            wbytes = art.packed.size * art.packed.dtype.itemsize
            # Dense baseline is the im2col matrix [kh*kw*Cin, Cout] — the
            # operand the kernel would otherwise move — not the compact
            # HWIO tensor (they differ for grouped/depthwise layers).
            dbytes = c["kh"] * c["kh"] * c["cin"] * c["cout"] * 4
            rows.append(
                (f"conv/{name}/wd{wd}", f"{t_dense:.0f}",
                 f"grid_compaction={pcw.steps / dense_steps:.3f};"
                 f"weight_bytes_ratio={wbytes / dbytes:.3f};"
                 f"block_density={pcw.density():.3f}")
            )
    return rows


def _time_call(fn, reps=3):
    return timeit(fn, reps=reps, warmup=1)[1]  # warmup absorbs compile/trace


def conv_mode_rows(rng, *, b=1, hw=14, cin=64, cout=64, kh=3, stride=(1, 1),
                   w_density=0.3, blk=(32, 32, 32)):
    """im2col vs direct on the same 3×3 s1 layer: wall-time (interpret-mode
    proxy) + the metric that transfers to hardware — peak patch-matrix bytes
    materialised in HBM (direct: 0 by construction)."""
    w = rng.standard_normal((kh, kh, cin, cout)).astype(np.float32)
    w2 = w.reshape(-1, cout)
    w2 *= sparsity.block_prune(w2, w_density, blk[1:])
    w = w2.reshape(w.shape)
    x = rng.standard_normal((b, hw, hw, cin)).astype(np.float32)
    x[x < 0] = 0.0  # post-ReLU dynamic sparsity
    xj = jnp.asarray(x)
    rows, result = [], {}
    for mode in ("im2col", "direct"):
        pcw = phantom_conv.prepare_conv_weight(
            w, batch=b, in_hw=(hw, hw), stride=stride, block=blk, mode=mode
        )
        oh, ow = pcw.out_hw
        if mode == "im2col":
            patch_bytes = b * oh * ow * kh * kh * cin * 4
            act_bytes = patch_bytes  # what the kernel actually reads
        else:
            patch_bytes = 0  # never materialised — the tentpole claim
            act_bytes = int(np.prod(pcw.plan.phase_shape)) * 4
        t_us = _time_call(
            lambda: phantom_conv.phantom_conv_call(xj, pcw, interpret=True)
        )
        result[mode] = dict(us=t_us, patch_bytes=patch_bytes,
                            act_bytes=act_bytes, steps=pcw.steps)
        rows.append(
            (f"conv_mode/{mode}/3x3_s{stride[0]}", f"{t_us:.0f}",
             f"patch_bytes={patch_bytes};act_bytes={act_bytes};"
             f"steps={pcw.steps}")
        )
    return rows, result


def lookahead_rows(rng, *, lookahead=8, b=1, hw=14, cin=64, cout=64, kh=3,
                   w_density=0.3, blk=(32, 32, 32)):
    """Runtime lookahead compaction (DESIGN.md §10) on the direct-conv bench
    layer: executed grid steps + wall time, gated (``lookahead=0``) vs
    compacted, at 50% activation-tile density (the back half of the channel
    axis is zeroed, killing exactly one of the two Cin tiles of every queue
    segment position).  The structural metric that transfers to hardware is
    ``queue_steps / executed_steps`` — the bench asserts the acceptance
    floor of ≥1.5× and bit-identical outputs."""
    w = rng.standard_normal((kh, kh, cin, cout)).astype(np.float32)
    w2 = w.reshape(-1, cout)
    w2 *= sparsity.block_prune(w2, w_density, blk[1:])
    w = w2.reshape(w.shape)
    x = rng.standard_normal((b, hw, hw, cin)).astype(np.float32)
    x[x < 0] = 0.0  # post-ReLU
    x[..., cin // 2 :] = 0.0  # 50% of activation k-tiles dead
    xj = jnp.asarray(x)
    rows, result, outs = [], {}, {}
    for la in (0, lookahead):
        pcw = phantom_conv.prepare_conv_weight(
            w, batch=b, in_hw=(hw, hw), block=blk, mode="direct", lookahead=la
        )
        t_us = _time_call(
            lambda: phantom_conv.phantom_conv_call(xj, pcw, interpret=True)
        )
        outs[la] = np.asarray(phantom_conv.phantom_conv_call(xj, pcw, interpret=True))
        bits = phantom_conv.direct_conv_tile_bits(xj, pcw, 0.0)
        st = ops.lookahead_stats(pcw.plan, bits, lookahead=la)
        result["compacted" if la else "gated"] = dict(
            us=t_us, lookahead=la, queue_steps=st["queue_steps"],
            executed_steps=st["executed_steps"],
            utilization=st["utilization"],
        )
        rows.append(
            (f"lookahead/L{la}/3x3_s1", f"{t_us:.0f}",
             f"queue_steps={st['queue_steps']};"
             f"executed_steps={st['executed_steps']};"
             f"utilization={st['utilization']:.3f}")
        )
    np.testing.assert_array_equal(outs[0], outs[lookahead])
    c = result["compacted"]
    assert c["queue_steps"] / c["executed_steps"] >= 1.5, result
    return rows, result


def multicore_rows(rng, *, cores=4, mt=4):
    """Balanced (densest-first LPT, §4.3.1) vs naive round-robin partition
    across virtual cores, on a skewed-density layer — the DESIGN.md §9
    acceptance row.  A heavy column block every ``cores``-th position makes
    round-robin collide heavies onto one core; LPT spreads them.  Metrics
    come from the *real* execution artifacts: ``makespan`` is the padded
    per-core queue length the grid executes, ``work_makespan`` the per-core
    MAC-step maximum, ``imbalance`` max/mean per-core work.  Outputs are
    bit-identical across policies — the bench asserts it."""
    kt, nt, blk = 12, 8, (32, 32, 32)
    bk, bn = blk[1:]
    w = np.zeros((kt * bk, nt * bn), np.float32)
    for c in range(nt):
        rows_kept = kt if c % cores == 0 else 1  # heavy every cores-th column
        w[: rows_kept * bk, c * bn : (c + 1) * bn] = rng.standard_normal(
            (rows_kept * bk, bn)
        ).astype(np.float32)
    m = mt * blk[0]
    x = jnp.asarray(rng.standard_normal((m, w.shape[0])).astype(np.float32))
    rows, result, outs = [], {}, {}
    for bal in ("none", "full"):
        pw = ops.prepare_weight(w, m=m, block=blk, cores=cores, balance=bal)
        t_us = _time_call(lambda: ops.phantom_matmul(x, pw, interpret=True))
        outs[bal] = np.asarray(ops.phantom_matmul(x, pw, interpret=True))
        work = pw.core_cost * mt
        result[bal] = dict(
            us=t_us,
            makespan=int(pw.core_steps.max()),
            work_makespan=int(work.max()),
            imbalance=float(work.max() / work.mean()),
        )
        rows.append(
            (
                f"multicore/{bal}/cores{cores}",
                f"{t_us:.0f}",
                f"makespan={result[bal]['makespan']};"
                f"work_makespan={result[bal]['work_makespan']};"
                f"imbalance={result[bal]['imbalance']:.3f}",
            )
        )
    np.testing.assert_array_equal(outs["none"], outs["full"])
    assert result["full"]["work_makespan"] <= result["none"]["work_makespan"]
    return rows, result


def autotune_rows(rng, *, cores=4, mt=4):
    """Per-layer autotuning (DESIGN.md §12) on the skewed bench layer set —
    the acceptance row for ``repro.tune``.  Uses ``BENCH_SPACE`` (single
    grid: base block + base lowering for every candidate) so the asserted
    metrics are raw executed makespans, directly comparable across
    candidates.  Asserts the never-worse guarantee: every layer's tuned
    executed makespan ≤ the global default's, strictly better on ≥1 layer.

    Layer set: the §4.2 skewed-density FC layer of :func:`multicore_rows`
    (heavy column block every ``cores``-th position — the case a global
    single-core default leaves ~``cores``× on the table), the direct-conv
    bench layer of :func:`conv_mode_rows`, and a deliberately tiny FC whose
    best config IS the default (the tuner must return it unchanged)."""
    from repro.core.dataflow import ConvSpec, FCSpec
    from repro.core.phantom_linear import PhantomConfig
    from repro.tune import BENCH_SPACE, search_layer

    blk = (32, 32, 32)
    bk, bn = blk[1:]
    cfg = PhantomConfig(enabled=True, block=blk)
    kt, nt = 12, 8
    w_skew = np.zeros((kt * bk, nt * bn), np.float32)
    for c in range(nt):
        rows_kept = kt if c % cores == 0 else 1  # heavy every cores-th column
        w_skew[: rows_kept * bk, c * bn : (c + 1) * bn] = rng.standard_normal(
            (rows_kept * bk, bn)
        ).astype(np.float32)
    w_conv = rng.standard_normal((3, 3, 64, 64)).astype(np.float32)
    w2 = w_conv.reshape(-1, 64)
    w2 *= sparsity.block_prune(w2, 0.3, blk[1:])
    w_conv = w2.reshape(w_conv.shape)
    w_tiny = rng.standard_normal((bk, bn)).astype(np.float32)
    cases = [
        (FCSpec("skewed_fc", kt * bk, nt * bn), w_skew, mt * blk[0]),
        (ConvSpec("conv3x3", 64, 64, 14, 14), w_conv, 1),
        (FCSpec("tiny_fc", bk, bn), w_tiny, blk[0]),
    ]
    rows, per_layer = [], {}
    tot_default = tot_tuned = improved = 0
    for spec, w, batch in cases:
        res = search_layer(spec, {"w": w}, batch, cfg, space=BENCH_SPACE)
        d_ms, t_ms = res.default["executed_makespan"], res.best["executed_makespan"]
        # The acceptance property: single-grid candidates + default always
        # in the set + argmin ⇒ tuned can never be worse on executed steps.
        assert t_ms <= d_ms, (spec.name, res.default, res.best)
        improved += t_ms < d_ms
        tot_default += res.default["cost"]
        tot_tuned += res.best["cost"]
        per_layer[spec.name] = dict(
            default_makespan=d_ms, tuned_makespan=t_ms, override=res.override
        )
        ov = ";".join(f"{k}={v}" for k, v in sorted(res.override.items())) or "default"
        rows.append(
            (f"autotune/{spec.name}", "-",
             f"default_makespan={d_ms};tuned_makespan={t_ms};{ov}")
        )
    assert improved >= 1, per_layer  # strictly better somewhere, or the
    # skewed layer set no longer exercises the tuner
    result = dict(
        layers=per_layer,
        default_cost=tot_default,
        tuned_cost=tot_tuned,
        layers_improved=improved,
    )
    return rows, result


def build_point(result, mc_result=None, la_result=None, at_result=None):
    """One trajectory point from bench results — shared by
    :func:`write_conv_trajectory` (append to BENCH_conv.json) and
    ``benchmarks.check_regression`` (compare against the last point)."""
    point = {
        "direct_us": round(result["direct"]["us"], 1),
        "im2col_us": round(result["im2col"]["us"], 1),
        "speedup_direct_over_im2col": round(
            result["im2col"]["us"] / result["direct"]["us"], 3
        ),
        "direct_patch_bytes": result["direct"]["patch_bytes"],
        "im2col_patch_bytes": result["im2col"]["patch_bytes"],
        "activation_bytes_ratio": round(
            result["direct"]["act_bytes"] / result["im2col"]["act_bytes"], 3
        ),
    }
    if mc_result is not None:
        point.update(
            multicore_naive_makespan=mc_result["none"]["makespan"],
            multicore_balanced_makespan=mc_result["full"]["makespan"],
            multicore_naive_work_makespan=mc_result["none"]["work_makespan"],
            multicore_balanced_work_makespan=mc_result["full"]["work_makespan"],
            multicore_naive_imbalance=round(mc_result["none"]["imbalance"], 3),
            multicore_balanced_imbalance=round(mc_result["full"]["imbalance"], 3),
            multicore_balance_speedup=round(
                mc_result["none"]["work_makespan"]
                / mc_result["full"]["work_makespan"],
                3,
            ),
        )
    if la_result is not None:
        g, c = la_result["gated"], la_result["compacted"]
        point.update(
            lookahead=c["lookahead"],
            lookahead_gated_us=round(g["us"], 1),
            lookahead_compacted_us=round(c["us"], 1),
            lookahead_queue_steps=g["queue_steps"],
            lookahead_executed_steps=c["executed_steps"],
            lookahead_step_reduction=round(
                c["queue_steps"] / c["executed_steps"], 3
            ),
            lookahead_utilization=round(c["utilization"], 3),
        )
    if at_result is not None:
        point.update(
            autotune_default_cost=int(at_result["default_cost"]),
            autotune_tuned_cost=int(at_result["tuned_cost"]),
            autotune_cost_speedup=round(
                at_result["default_cost"] / at_result["tuned_cost"], 3
            ),
            autotune_layers_improved=int(at_result["layers_improved"]),
        )
    return point


def run_id_of(point: dict) -> str:
    """Deterministic run id: sha256 over the point's *structural* fields
    (wall-time ``*_us`` metrics and their derived speedup excluded — they
    differ on every run even when nothing changed), first 12 hex chars.
    Two runs of the same code produce the same id, so repeated appends of
    the same row set are detectable."""
    import hashlib

    wall = {"speedup_direct_over_im2col"}
    stable = {
        k: v for k, v in sorted(point.items())
        if k != "run_id" and not k.endswith("_us") and k not in wall
    }
    blob = json.dumps(stable, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


def write_conv_trajectory(result, mc_result=None, la_result=None,
                          at_result=None, path="BENCH_conv.json"):
    """Append one trajectory point comparing the two conv lowerings (plus,
    when supplied, the multi-core balanced-vs-naive makespans, the lookahead
    gated-vs-compacted executed steps / wall time, and the autotune
    default-vs-tuned costs).

    Every point is stamped with a structural ``run_id``
    (:func:`run_id_of`); re-running the unchanged bench **replaces** the
    last point instead of appending a duplicate, so
    ``check_regression.py`` always bands against the latest *distinct* run
    — repeated local runs cannot pad the history or shift the baseline.
    """
    p = pathlib.Path(path)
    hist = json.loads(p.read_text()) if p.exists() else []
    point = build_point(result, mc_result, la_result, at_result)
    point["run_id"] = run_id_of(point)
    if hist and hist[-1].get("run_id") == point["run_id"]:
        hist[-1] = point  # same structural run: refresh advisory wall times
    else:
        hist.append(point)
    p.write_text(json.dumps(hist, indent=2) + "\n")
    return hist[-1]


def program_rows(rng):
    """Program API (DESIGN.md §8): compile once per batch size, then read the
    structural stats surface — steps vs dense, weight-effectual MACs — that
    the engine↔simulator contract (§5) is checked against.  No forward runs;
    this is the weight-load-time cost/compaction picture."""
    import phantom
    from repro.core.dataflow import ConvSpec, FCSpec

    layers = [
        ConvSpec("c1", 3, 32, 28, 28),
        ConvSpec("c2", 32, 64, 28, 28),
        FCSpec("fc", 64, 10, pool="gap"),
    ]
    blk = (32, 32, 32)
    params = {}
    for l in layers:
        shp = (
            (l.kh, l.kw, l.in_ch, l.out_ch)
            if isinstance(l, ConvSpec)
            else (l.in_dim, l.out_dim)
        )
        w = rng.standard_normal(shp).astype(np.float32)
        w2 = w.reshape(-1, shp[-1])
        if w2.shape[0] >= blk[1]:  # don't prune sub-tile weights to nothing
            w2 *= sparsity.block_prune(w2, 0.3, blk[1:])
        params[l.name] = {
            "w": jnp.asarray(w2.reshape(shp)),
            "b": jnp.asarray(np.zeros(shp[-1], np.float32)),
        }
    cfg = phantom.PhantomConfig(enabled=True, block=blk)
    # One cold call: compile time *is* the quantity (no warmup to exclude).
    prog, t_compile = timeit(
        phantom.compile, layers, params, cfg, batch=(1, 8), reps=1, warmup=0
    )
    rows = [
        (
            "program/compile", f"{t_compile:.0f}",
            f"layers={len(prog.nodes)};batches={list(prog.batch_sizes)};"
            f"lowerings={prog.lowerings}",
        )
    ]
    for name, s in prog.stats(8).items():
        rows.append(
            (
                f"program/{name}", "-",
                f"steps={s['steps']};dense_steps={s['dense_steps']};"
                f"valid_mac_frac={s['valid_macs'] / s['dense_macs']:.3f}",
            )
        )
    return rows


def obs_overhead_rows(rng, *, trials=3, reps=5):
    """Recorder overhead on a whole-network forward (DESIGN.md §11
    acceptance: <5% wall time vs ``recorder=None``).  Same compiled program,
    same input; only the ``recorder`` attribute toggles between timings.
    Min-over-trials makes the ratio robust to scheduler noise."""
    import phantom
    from repro.core.dataflow import ConvSpec, FCSpec
    from repro.obs import Recorder

    layers = [
        ConvSpec("c1", 3, 16, 14, 14),
        ConvSpec("c2", 16, 32, 14, 14),
        FCSpec("fc", 32, 10, pool="gap"),
    ]
    blk = (16, 16, 16)
    params = {}
    for l in layers:
        shp = (
            (l.kh, l.kw, l.in_ch, l.out_ch)
            if isinstance(l, ConvSpec)
            else (l.in_dim, l.out_dim)
        )
        params[l.name] = {
            "w": jnp.asarray(rng.standard_normal(shp).astype(np.float32) * 0.1),
            "b": jnp.asarray(np.zeros(shp[-1], np.float32)),
        }
    prog = phantom.compile(
        layers, params, phantom.PhantomConfig(enabled=True, block=blk), batch=2
    )
    x = jnp.asarray(rng.standard_normal((2, 14, 14, 3)).astype(np.float32))

    def measure():
        return min(timeit(prog, x, reps=reps, warmup=1)[1] for _ in range(trials))

    prog.recorder = None
    t_off = measure()
    prog.recorder = Recorder()
    t_on = measure()
    ratio = t_on / t_off
    assert ratio < 1.05, f"recorder overhead {ratio:.3f}x exceeds the 5% budget"
    return [
        (
            "obs/recorder_overhead", f"{t_on:.0f}",
            f"recorder_off_us={t_off:.0f};ratio={ratio:.3f}",
        )
    ]


def verify_rows(rng, *, trials=5):
    """Verify-on-load overhead (DESIGN.md §13 acceptance: <5% of load
    time).  Saves one compiled program, then times the verify stage a
    default load runs (``verify_program(deep=False)``) directly against
    ``load(verify=False)`` — the asserted budget covers what every load
    pays.  Timing the stage beats differencing two whole loads: an ~8 ms
    load jitters by more than the whole budget, so ``on/off`` ratios are
    noise.  End-to-end loads for all three tiers (off / default /
    ``"full"``) are still reported as advisory columns; the ``"full"``
    tier (sha256 fingerprint + per-step scans) is CLI/CI-only and not
    budgeted.  Min over trials; first call of each mode is untimed
    warmup."""
    import os
    import tempfile

    import phantom
    from repro.core.dataflow import ConvSpec, FCSpec

    layers = [
        ConvSpec("c1", 3, 32, 28, 28),
        ConvSpec("c2", 32, 64, 28, 28),
        FCSpec("fc", 64, 10, pool="gap"),
    ]
    blk = (32, 32, 32)
    params = {}
    for l in layers:
        shp = (
            (l.kh, l.kw, l.in_ch, l.out_ch)
            if isinstance(l, ConvSpec)
            else (l.in_dim, l.out_dim)
        )
        w = rng.standard_normal(shp).astype(np.float32)
        w2 = w.reshape(-1, shp[-1])
        if w2.shape[0] >= blk[1]:
            w2 *= sparsity.block_prune(w2, 0.3, blk[1:])
        params[l.name] = {
            "w": jnp.asarray(w2.reshape(shp)),
            "b": jnp.asarray(np.zeros(shp[-1], np.float32)),
        }
    cfg = phantom.PhantomConfig(enabled=True, block=blk)
    prog = phantom.compile(layers, params, cfg, batch=(1, 8))
    with tempfile.TemporaryDirectory(prefix="phantom-bench-") as tmp:
        path = os.path.join(tmp, "prog")
        prog.save(path)

        def measure(verify):
            def load():
                return phantom.PhantomProgram.load(path, verify=verify)

            load()  # fs-cache / import warmup, untimed
            return min(timeit(load, reps=1, warmup=0)[1] for _ in range(trials))

        t_off = measure(False)
        t_on = measure(True)
        t_full = measure("full")

        from repro.verify import verify_program

        loaded = phantom.PhantomProgram.load(path, verify=False)
        verify_program(loaded, deep=False)  # warmup, untimed
        t_verify = min(
            timeit(lambda: verify_program(loaded, deep=False),
                   reps=1, warmup=0)[1]
            for _ in range(trials)
        )
    ratio = t_verify / t_off
    assert ratio < 0.05, (
        f"verify-on-load stage costs {ratio:.1%} of load time, over the 5% "
        f"budget (load={t_off:.0f}us verify={t_verify:.0f}us)"
    )
    return [
        (
            "verify/load_overhead", f"{t_verify:.0f}",
            f"load_us={t_off:.0f};ratio={ratio:.3f};on_us={t_on:.0f};"
            f"full_us={t_full:.0f}",
        )
    ]


def run_multicore():
    """The multi-core balance rows alone (fast — printed by the CI tier-1
    job to keep the balanced-vs-naive makespans visible per commit)."""
    rows, result = multicore_rows(np.random.default_rng(0))
    return emit(rows), result


def run_lookahead():
    """The lookahead compaction rows alone (fast — printed by the CI tier-1
    job so the executed-step reduction stays visible per commit)."""
    rows, result = lookahead_rows(np.random.default_rng(0))
    return emit(rows), result


def run():
    rows = []
    rng = np.random.default_rng(0)
    m = k = n = 1024
    blk = (128, 128, 128)
    for wd in (1.0, 0.5, 0.25, 0.125):
        w = rng.standard_normal((k, n)).astype(np.float32)
        if wd < 1.0:
            w *= sparsity.block_prune(w, wd, blk[1:])
        x = rng.standard_normal((m, k)).astype(np.float32)
        pw = ops.prepare_weight(w, m=m, block=blk)
        mt, kt, nt = pw.grid_tiles
        dense_steps = mt * kt * nt
        compaction = pw.steps / dense_steps
        wbytes = pw.packed.size * pw.packed.dtype.itemsize
        dbytes = k * n * 4

        xj, wj = jnp.asarray(x), jnp.asarray(w)
        f_dense = jax.jit(lambda a, b: a @ b)
        _, t_dense = timeit(f_dense, xj, wj, reps=5, warmup=1)

        mask = jnp.asarray((w != 0).astype(np.float32))
        f_masked = jax.jit(lambda a, b, mm: a @ (b * mm))
        _, t_masked = timeit(f_masked, xj, wj, mask, reps=5, warmup=1)

        rows.append(
            (f"kernel/wd{wd}", f"{t_dense:.0f}",
             f"grid_compaction={compaction:.3f};weight_bytes_ratio={wbytes/dbytes:.3f};"
             f"masked_us={t_masked:.0f}")
        )
    rows += _conv_rows(rng)
    mode_rows, mode_result = conv_mode_rows(rng)
    rows += mode_rows
    mc_rows, mc_result = multicore_rows(rng)
    rows += mc_rows
    la_rows, la_result = lookahead_rows(rng)
    rows += la_rows
    rows += program_rows(rng)
    rows += obs_overhead_rows(rng)
    at_rows, at_result = autotune_rows(rng)
    rows += at_rows
    rows += verify_rows(rng)
    return emit(rows), mode_result, mc_result, la_result, at_result


def run_autotune():
    """The autotune rows alone (fast — printed by the CI tier-1 job so the
    per-layer default-vs-tuned makespans stay visible per commit)."""
    rows, result = autotune_rows(np.random.default_rng(0))
    return emit(rows), result


if __name__ == "__main__":
    import sys

    if len(sys.argv) > 1 and sys.argv[1] == "multicore":
        run_multicore()
    elif len(sys.argv) > 1 and sys.argv[1] == "lookahead":
        run_lookahead()
    elif len(sys.argv) > 1 and sys.argv[1] == "autotune":
        run_autotune()
    else:
        _, result, mc_result, la_result, at_result = run()
        point = write_conv_trajectory(result, mc_result, la_result, at_result)
        print("BENCH_conv.json +=", json.dumps(point))
