"""Shared benchmark utilities: CSV emission + default simulator options."""
from __future__ import annotations

import time

import numpy as np

from repro.core import simulator

FAST = simulator.SimOptions(job_frac=0.2, max_jobs=16, max_entries=192, seed=0)
FULL = simulator.SimOptions(job_frac=0.25, max_jobs=48, max_entries=384, seed=0)


def emit(rows: list[tuple], header=("name", "us_per_call", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
