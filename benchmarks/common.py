"""Shared benchmark utilities: CSV emission + default simulator options.

Timing is delegated to :mod:`repro.obs` (DESIGN.md §11) — the one
warmup-aware, ``block_until_ready``-correct implementation — instead of a
local ``time.perf_counter`` loop.
"""
from __future__ import annotations

from repro.core import simulator
from repro.obs import timeit

FAST = simulator.SimOptions(job_frac=0.2, max_jobs=16, max_entries=192, seed=0)
FULL = simulator.SimOptions(job_frac=0.25, max_jobs=48, max_entries=384, seed=0)


def emit(rows: list[tuple], header=("name", "us_per_call", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows


def timed(fn, *args, **kw):
    """One un-warmed call → ``(out, µs)``: the simulator benchmarks time a
    single cold run on purpose (host numpy; no compile cache to exclude)."""
    return timeit(fn, *args, reps=1, warmup=0, **kw)
