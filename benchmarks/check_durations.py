"""Per-test duration budget, parsed from pytest's ``--durations`` report.

CI runs ``pytest --durations=0 | tee pytest.log`` and then::

    python -m benchmarks.check_durations pytest.log --budget 60

Any single test phase (call/setup/teardown) over the budget fails the job —
the tier-1 suite stays fast because no individual test is allowed to grow
into a benchmark.  The parser matches pytest's report lines::

    1.23s call     tests/test_kernels.py::test_matmul_parity

``parse_durations`` is the pure piece (unit-tested in ``tests/test_obs.py``).
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

_LINE = re.compile(r"^\s*(\d+(?:\.\d+)?)s\s+(call|setup|teardown)\s+(\S+)\s*$")


def parse_durations(text: str) -> list[tuple[float, str, str]]:
    """Extract ``(seconds, phase, test_id)`` rows from pytest output."""
    rows = []
    for line in text.splitlines():
        m = _LINE.match(line)
        if m:
            rows.append((float(m.group(1)), m.group(2), m.group(3)))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("log", help="pytest output containing a --durations report")
    ap.add_argument(
        "--budget", type=float, default=60.0,
        help="max seconds for any single test phase (default 60)",
    )
    args = ap.parse_args(argv)

    rows = parse_durations(pathlib.Path(args.log).read_text())
    if not rows:
        print(
            "check_durations: no duration lines found — did pytest run with "
            "--durations=N (and -vv or durations above pytest's 0.005s floor)?"
        )
        return 1
    over = [r for r in rows if r[0] > args.budget]
    worst = max(rows)
    print(
        f"check_durations: {len(rows)} phases parsed, worst "
        f"{worst[0]:.2f}s ({worst[1]} {worst[2]}), budget {args.budget:g}s"
    )
    if over:
        for secs, phase, test in sorted(over, reverse=True):
            print(f"  OVER BUDGET {secs:.2f}s {phase} {test}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
