"""Fig. 25 — sparse-mask vs CSC metadata DRAM traffic for intermediate
activations (selected VGG16 / MobileNet layers).

Paper claims: ≈ 4× (VGG16) / 3.7× (MobileNet) more CSC traffic at low
activation sparsity, ≈ 1.7× at moderate-to-high sparsity.
"""
from __future__ import annotations

import numpy as np

from repro.core import masks, netlib, sparsity
from repro.core.dataflow import ConvSpec

from .common import emit, timed


def run(seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = []
    for net, layers, adens in (
        ("vgg16", netlib.vgg16_layers(include_fc=False), netlib.VGG16_ACT_DENSITY),
        ("mobilenet", netlib.mobilenet_layers(include_fc=False), netlib.MOBILENET_ACT_DENSITY),
    ):
        for spec in layers[::3]:  # selected layers, as in the figure
            d = adens.get(spec.name, 0.35)
            shape = (spec.in_h, spec.in_w, spec.in_ch)
            m = sparsity.bernoulli_mask(shape, d, rng)
            # CSC layout (H, W·C): column per (W, C) stripe, H-row indices
            # (paper footnote 2 counts the location vectors only).
            (mb, cb), us = timed(
                lambda: (
                    masks.mask_traffic_bytes(shape),
                    masks.csc_traffic_bytes(m.reshape(shape[0], -1)),
                )
            )
            rows.append(
                (f"fig25/{net}/{spec.name}", f"{us:.0f}",
                 f"csc_over_mask={cb/mb:.2f};act_density={d:.2f}")
            )
    return emit(rows)


if __name__ == "__main__":
    run()
