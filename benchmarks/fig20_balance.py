"""Fig. 20 — two-level load balancing impact at L_f=6 (VGG16 & MobileNet).

Paper claims: average balanced/unbalanced gain ≈ 1.1× (VGG16) / 1.08×
(MobileNet); up to 1.5× / 1.3× in early layers.
"""
from __future__ import annotations

from repro.core import dataflow as df, simulator

from .common import FAST, emit, timed

VARIANTS = {
    "unbalanced": df.Phantom2DConfig(
        lookahead=6, intra_balance=False, inter_balance=False
    ),
    "balanced": df.Phantom2DConfig(lookahead=6),
}


def run(opts=FAST):
    rows = []
    for net, fn in (
        ("vgg16", simulator.vgg16_simulation),
        ("mobilenet", simulator.mobilenet_simulation),
    ):
        res, us = timed(fn, opts=opts, variants=VARIANTS)
        for r in res:
            gain = r.cycles["unbalanced"] / r.cycles["balanced"]
            rows.append((f"fig20/{net}/{r.name}", f"{us:.0f}", f"{gain:.3f}"))
        net_gain = simulator.network_summary(res, "balanced") / simulator.network_summary(
            res, "unbalanced"
        )
        rows.append((f"fig20/{net}/avg", f"{us:.0f}", f"{net_gain:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
