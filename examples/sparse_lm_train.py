"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps on the synthetic pipeline, with checkpoint/restart in the
middle — the full production loop at laptop scale.

  PYTHONPATH=src python examples/sparse_lm_train.py [--steps 300] [--full-100m]

By default a smaller config keeps CPU runtime reasonable; ``--full-100m``
uses the real ~100M smollm-family config from configs/smollm_360m.py.
"""
import argparse
import dataclasses
import tempfile

import jax
import numpy as np

from repro import configs, optim
from repro.data import DataConfig, SyntheticTokens
from repro.models.registry import build
from repro.train import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full-100m", action="store_true")
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

if args.full_100m:
    from repro.configs.smollm_360m import TRAIN_100M as cfg
else:
    cfg = dataclasses.replace(
        configs.get_smoke("smollm_360m"), n_layers=4, d_model=128, d_ff=384,
        vocab=2048, n_heads=4, n_kv_heads=4, head_dim=32,
    )
model = build(cfg)
n_params = sum(
    int(np.prod(s.shape)) for s in jax.tree.leaves(
        model.spec, is_leaf=lambda x: hasattr(x, "shape"))
)
print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")

data = SyntheticTokens(
    DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, noise=0.02)
)
opt_cfg = optim.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)

with tempfile.TemporaryDirectory() as ckpt_dir:
    half = args.steps // 2
    tr = Trainer(model, data, opt_cfg, TrainConfig(ckpt_every=half, log_every=20),
                 ckpt_dir=ckpt_dir)
    p, o = tr.init_state()
    p, o = tr.run(p, o, half)
    print(f"[phase 1] step {half}: loss {tr.history[-1]['loss']:.4f} — "
          f"simulating failure, restarting from checkpoint")

    tr2 = Trainer(model, data, opt_cfg, TrainConfig(log_every=20), ckpt_dir=ckpt_dir)
    p2, o2 = tr2.init_state()
    p2, o2 = tr2.maybe_restore(p2, o2)
    p2, o2 = tr2.run(p2, o2, args.steps - half)
    losses = [h["loss"] for h in tr.history + tr2.history]
    print(f"[phase 2] resumed at {tr2.start_step}; final loss {losses[-1]:.4f}")
    print(f"loss: start {np.mean(losses[:10]):.4f} -> end {np.mean(losses[-10:]):.4f}"
          f"  ({'LEARNING' if losses[-1] < losses[0] - 0.3 else 'check config'})")
