"""Serving example: continuous-batching engine with the Phantom technique
enabled — block-pruned FFN/o-proj weights, masked block-sparse execution —
vs the dense baseline on the same requests.

  PYTHONPATH=src python examples/phantom_serving.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.core.phantom_linear import PhantomConfig
from repro.launch.serve import phantomize
from repro.models.registry import build
from repro.serve import ServeEngine

ARCH = "qwen2_0p5b"
rng = np.random.default_rng(0)
prompts = [rng.integers(0, 500, size=rng.integers(4, 10)).tolist() for _ in range(6)]


def serve(phantom: bool):
    cfg = configs.get_smoke(ARCH)
    if phantom:
        cfg = dataclasses.replace(
            cfg, phantom=PhantomConfig(enabled=True, mode="masked",
                                       weight_density=0.4, block=(8, 8, 8)),
        )
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if phantom:
        params = phantomize(model, params, 0.4)
    eng = ServeEngine(model, params, batch_size=3, max_len=64)
    for pr in prompts:
        eng.submit(pr, max_new_tokens=8)
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    return done, toks / dt


dense_out, dense_tps = serve(False)
ph_out, ph_tps = serve(True)
print(f"dense  : {dense_tps:6.1f} tok/s  first outputs {dense_out[0].output[:6]}")
print(f"phantom: {ph_tps:6.1f} tok/s  first outputs {ph_out[0].output[:6]}")
print("note: CPU walltime is illustrative; the TPU win comes from the")
print("compacted kernel grid (see benchmarks/kernel_bench.py compaction ratios).")
