"""Quickstart: the Phantom core on the paper's own Fig. 1 example, the cycle
simulator, and the TPU block-sparse kernel — in two minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

print("=" * 70)
print("1) Functional Phantom core on a sparse 3x3 convolution (paper Fig. 1)")
print("=" * 70)
from repro.core import engine

rng = np.random.default_rng(0)
act = rng.integers(-3, 4, (3, 8)).astype(float) * (rng.random((3, 8)) < 0.45)
flt = rng.integers(-3, 4, (3, 3)).astype(float) * (rng.random((3, 3)) < 0.66)
res = engine.phantom_conv2d(act, flt, lookahead=3, policy="outoforder")
print(f"  outputs       : {res.outputs}")
print(f"  output mask   : {res.out_mask.astype(int)}  (§3.8 encoding)")
print(f"  phantom cycles: {res.stats.cycles}  dense: {res.stats.dense_cycles} "
      f"-> {res.stats.speedup_vs_dense:.2f}x, util {res.stats.utilization:.0%}")

print()
print("=" * 70)
print("2) Cycle-level Phantom-2D simulator: one VGG16 layer, all variants")
print("=" * 70)
from repro.core import dataflow as df, simulator

layers = [df.ConvSpec("conv8", 256, 512, 28, 28)]
variants = {
    "tds_io": df.Phantom2DConfig(lookahead=6, policy="inorder"),
    "tds_oo": df.Phantom2DConfig(lookahead=6),
    "hp": df.Phantom2DConfig(lookahead=27),
}
res = simulator.simulate_network(
    layers, [0.23], [0.32], variants, simulator.SimOptions(),
    baselines=("sparten",),
)[0]
for k, v in res.cycles.items():
    if k != "dense":
        print(f"  {k:8s}: {res.cycles['dense'] / v:5.2f}x over dense")

print()
print("=" * 70)
print("3) TPU adaptation: two-sided block-sparse matmul (Pallas, interpret)")
print("=" * 70)
import jax.numpy as jnp
from repro.core import sparsity
from repro.kernels import ops

w = rng.standard_normal((256, 256)).astype(np.float32)
w *= sparsity.block_prune(w, 0.25, (64, 64))
x = rng.standard_normal((128, 256)).astype(np.float32)
x[:64, :64] = 0.0  # a zero activation tile -> gated off in-kernel
pw = ops.prepare_weight(w, m=128, block=(64, 64, 64))
y = ops.phantom_matmul(jnp.asarray(x), pw, interpret=True)
err = float(jnp.abs(y - x @ w).max())
mt, kt, nt = pw.grid_tiles
print(f"  weight block density : {pw.density():.2f}")
print(f"  grid steps           : {pw.steps} vs dense {mt*kt*nt} "
      f"({pw.steps/(mt*kt*nt):.2f}x)")
print(f"  max |err| vs dense   : {err:.2e}")

print()
print("=" * 70)
print("4) Real convolution through the core: direct (implicit-im2col) conv")
print("=" * 70)
from repro.kernels import phantom_conv
from repro.kernels.ref import ref_phantom_conv

# A MobileNet-style stride-2 conv — the non-unit-stride case SCNN cannot
# run (§4, goal G3) — with a block-pruned weight.  mode="direct" is the
# default: the patch gather happens inside the kernel, so the kh·kw× patch
# matrix is never materialised (pass mode="im2col" to fall back to the
# explicit lowering, kept as the bit-exact oracle).
wc = rng.standard_normal((3, 3, 32, 64)).astype(np.float32)
w2 = wc.reshape(-1, 64)
w2 *= sparsity.block_prune(w2, 0.3, (32, 32))
wc = w2.reshape(wc.shape)
xc = rng.standard_normal((1, 16, 16, 32)).astype(np.float32)
xc[xc < 0] = 0.0  # post-ReLU input: dynamic activation sparsity
pcw = phantom_conv.prepare_conv_weight(
    wc, batch=1, in_hw=(16, 16), stride=(2, 2), block=(32, 32, 32))
yc = phantom_conv.phantom_conv_call(
    jnp.asarray(xc), pcw, x_mask=jnp.asarray(xc != 0), interpret=True)
ycref = ref_phantom_conv(jnp.asarray(xc), jnp.asarray(wc), (2, 2), "SAME")
mt, kt, nt = pcw.plan.grid_tiles
patch_elems = np.prod(yc.shape[:3]) * 9 * 32
print(f"  conv 3x3 s2 32->64   : out {tuple(yc.shape)}  [mode={pcw.mode}]")
print(f"  weight block density : {pcw.density():.2f}")
print(f"  grid steps           : {pcw.steps} vs dense {mt*kt*nt} "
      f"({pcw.steps/(mt*kt*nt):.2f}x)")
print(f"  patch matrix bytes   : 0 (implicit gather; im2col would move "
      f"{patch_elems*4} B)")
print(f"  max |err| vs lax.conv: {float(jnp.abs(yc - ycref).max()):.2e}")

print()
print("=" * 70)
print("5) The program API: phantom.compile → compile once, serve anywhere")
print("=" * 70)
import phantom
from repro.core.dataflow import ConvSpec, FCSpec
from repro.serve import CnnServeEngine

layers = [ConvSpec("c1", 3, 16, 8, 8), ConvSpec("c2", 16, 32, 8, 8),
          FCSpec("fc", 32, 10, pool="gap")]
params = {}
for l in layers:
    shp = (l.kh, l.kw, l.in_ch, l.out_ch) if isinstance(l, ConvSpec) else (l.in_dim, l.out_dim)
    wl = rng.standard_normal(shp).astype(np.float32) * 0.1
    wl *= rng.random(shp) < 0.4
    params[l.name] = {"w": jnp.asarray(wl),
                      "b": jnp.asarray(np.zeros(shp[-1], np.float32))}

# One compile-once artifact: weight-load-time lowering (mask+payload
# compaction, queue scheduling, §3.8 encoding flow) happens here, once.
cfg = phantom.PhantomConfig(enabled=True, block=(16, 16, 16))
prog = phantom.compile(layers, params, cfg, batch=2)
print(f"  compiled {len(prog.nodes)} layers at batch {prog.batch_sizes} "
      f"({prog.lowerings} lowering)")
for name, s in prog.stats(2).items():
    print(f"    {name:3s}: steps {s['steps']:4d}/{s['dense_steps']:4d} "
          f"density {s['density']:.2f} valid_macs {s['valid_macs']}")

# Multi-core (Phantom-2D, DESIGN.md §9): the same network partitioned
# across 2 virtual cores — densest-first LPT per layer, one pallas_call
# with a leading cores grid axis, bit-identical logits.
prog2 = phantom.compile(
    layers, params,
    phantom.PhantomConfig(enabled=True, block=(16, 16, 16), cores=2),
    batch=2,
)
x2 = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
assert np.array_equal(np.asarray(prog2(x2, interpret=True)),
                      np.asarray(prog(x2, interpret=True)))
s2 = prog2.stats(2)["c2"]
print(f"  cores=2 bit-identical; c2 per-core work {s2['per_core_work']} "
      f"(makespan {s2['makespan']}, imbalance {s2['imbalance']:.2f})")

# Fixed-slot batched serving over the program (padded slots gated off
# in-kernel); a prog.save()/PhantomProgram.load() round-trip would serve
# in a fresh process with zero re-lowering.
eng = CnnServeEngine(program=prog, batch_size=2, interpret=True)
reqs = [eng.submit(rng.standard_normal((8, 8, 3)).astype(np.float32))
        for _ in range(3)]
eng.run()
print(f"  served {eng.images_served} images in {eng.batches_run} batches "
      f"({eng.padded_slots} padded slot gated off in-kernel), "
      f"lowerings still {prog.lowerings}")
print(f"  logits[0][:4]        : {reqs[0].logits[:4]}")
print()
print("done.")
