"""End-to-end CNN path: prune a small VGG-style net, run inference in JAX,
time the SAME network on the Phantom-2D cycle simulator vs the competitor
models — the paper's full flow (prune → masks → schedule) — and serve a
batch of image requests through the Phantom core itself (direct conv
kernel, fixed-slot batching).

  PYTHONPATH=src python examples/cnn_phantom_serve.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import phantom
from repro.core import dataflow as df, simulator, sparsity
from repro.models.cnn import cnn_forward, cnn_spec
from repro.models.common import init_params
from repro.serve import CnnServeEngine

INPUT_HW = 32  # CIFAR-sized for CPU friendliness

spec, layers = cnn_spec("vgg16", input_hw=INPUT_HW)
params = init_params(jax.random.PRNGKey(0), spec)

# --- Han-style magnitude pruning of every conv/fc weight --------------------
DENSITY = 0.3
for name, p in params.items():
    w = np.asarray(p["w"])
    mask = sparsity.magnitude_prune(w, DENSITY)
    params[name]["w"] = jnp.asarray(w * mask)

x = jax.random.normal(jax.random.PRNGKey(1), (2, INPUT_HW, INPUT_HW, 3))
logits = cnn_forward(params, x, layers)
print(f"pruned VGG16[{INPUT_HW}px] logits: shape={logits.shape} "
      f"finite={bool(jnp.isfinite(logits).all())}")

# --- Activation sparsity from the real forward (ReLU zeros) -----------------
acts = jax.nn.relu(x)
print(f"input density ~ {float((x > 0).mean()):.2f} (ReLU gives the dynamic side)")

# --- Cycle-level timing of the same layers on Phantom-2D -------------------
wd = np.full(len(layers), DENSITY)
ad = np.full(len(layers), 0.40)
variants = {
    "cv": df.Phantom2DConfig(lookahead=9),
    "hp": df.Phantom2DConfig(lookahead=27),
}
res = simulator.simulate_network(
    layers, wd, ad, variants, simulator.SimOptions(max_jobs=12),
    baselines=("sparten",), skip_fc_for=("sparten",),
)
print(f"{'layer':8s} {'dense/hp':>9s} {'dense/cv':>9s} {'dense/sparten':>14s}")
for r in res:
    sp = r.cycles.get("sparten", float("nan"))
    sps = f"{r.cycles['dense']/sp:9.2f}x" if sp == sp else "      n/a"
    print(f"{r.name:8s} {r.cycles['dense']/r.cycles['hp']:8.2f}x "
          f"{r.cycles['dense']/r.cycles['cv']:8.2f}x {sps:>14s}")
print(f"net: HP {simulator.network_summary(res, 'hp'):.2f}x, "
      f"CV {simulator.network_summary(res, 'cv'):.2f}x over dense")

# --- Batched serving on the Phantom core itself ----------------------------
# A small head of the network (first conv block + classifier) runs real
# multi-image requests through the direct implicit-im2col kernel: one
# compiled PhantomProgram, fixed batch slots, short batches padded with
# zero images whose tiles are gated off in-kernel.
head = [df.ConvSpec("conv1", 3, 16, 16, 16), df.ConvSpec("conv2", 16, 16, 16, 16),
        df.FCSpec("fc", 16, 10, pool="gap")]
hp_rng = np.random.default_rng(2)
hparams = {}
for l in head:
    shp = (l.kh, l.kw, l.in_ch, l.out_ch) if isinstance(l, df.ConvSpec) else (l.in_dim, l.out_dim)
    w = hp_rng.standard_normal(shp).astype(np.float32) * 0.1
    w *= sparsity.magnitude_prune(w, DENSITY)
    hparams[l.name] = {"w": jnp.asarray(w),
                       "b": jnp.asarray(np.zeros(shp[-1], np.float32))}
prog = phantom.compile(
    head, hparams, phantom.PhantomConfig(enabled=True, block=(16, 16, 16)), batch=2)
eng = CnnServeEngine(program=prog, batch_size=2)
reqs = [eng.submit(hp_rng.standard_normal((16, 16, 3)).astype(np.float32))
        for _ in range(5)]
eng.run()
ref = cnn_forward(hparams, jnp.asarray(np.stack([r.image for r in reqs])), head)
err = max(float(np.abs(r.logits - np.asarray(ref)[i]).max()) for i, r in enumerate(reqs))
print(f"serve: {eng.images_served} requests / {eng.batches_run} batches "
      f"(padded {eng.padded_slots}), conv_mode={prog.cfg.conv_mode}, "
      f"{prog.lowerings} lowering, max|err| vs dense {err:.1e}")
