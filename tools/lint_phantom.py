#!/usr/bin/env python3
"""AST-based repo lint for Phantom codebase rules (DESIGN.md §13).

Three rules, each reported as ``path:line: [PHxxx] message`` (exit 1 on any
finding — the CI tier-1 step fails the build):

* **PH001** — no hand-rolled timing outside the observability layer.
  Wall-clock reads (``time.perf_counter`` / ``time.time`` /
  ``time.monotonic`` / ``timeit.default_timer`` calls) belong in
  ``repro.obs.timeit`` and the span recorder; ad-hoc timing loops elsewhere
  measure without warmup/`block_until_ready` discipline and rot into
  pseudo-benchmarks.  Allowlisted: ``repro/obs/``, ``repro/checkpoint/``
  (manifest timestamps), ``repro/launch/``.

* **PH002** — no nondeterminism in cost models, the verifier, or the
  fault-injection harness (``repro/tune/``, ``repro/verify/``,
  ``repro/serve/faults.py``): wall-clock-dependent values
  (``datetime.now`` etc.), the global ``random`` module, or an *unseeded*
  ``numpy`` ``default_rng()``.  Tuning decisions, verification verdicts and
  fault schedules must be replayable bit-for-bit; seeded generators are
  fine.

* **PH003** — a class registered via ``register_layer_kind`` in the same
  module must implement the full ``LayerKind`` protocol (``prepare`` /
  ``apply`` / ``mask_out`` / ``stats`` and a ``name`` attribute).  The
  registry's ``runtime_checkable`` isinstance check only sees the methods
  at call time, one missing hook = one runtime crash per hook.

Usage::

    python tools/lint_phantom.py src/
"""
from __future__ import annotations

import argparse
import ast
import pathlib
import sys

TIMING_ALLOW = ("repro/obs/", "repro/checkpoint/", "repro/launch/")
TIMING_FUNCS = {
    "time": {"time", "time_ns", "perf_counter", "perf_counter_ns",
             "monotonic", "monotonic_ns", "process_time"},
    "timeit": {"default_timer"},
}
DETERMINISTIC_DIRS = ("repro/tune/", "repro/verify/", "repro/serve/faults.py")
PROTOCOL = ("prepare", "apply", "mask_out", "stats")


def _dotted(node) -> str | None:
    """``a.b.c`` for a pure attribute chain rooted at a Name, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _call_target(node: ast.Call):
    """``(base, attr)`` for ``base.attr(...)`` calls (``base`` may be dotted,
    e.g. ``np.random`` for ``np.random.default_rng()``), ``(None, name)``
    for bare ``name(...)`` calls, else ``(None, None)``."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return _dotted(f.value), f.attr
    if isinstance(f, ast.Name):
        return None, f.id
    return None, None


class _Linter(ast.NodeVisitor):
    def __init__(self, relpath: str):
        self.relpath = relpath
        self.findings: list[tuple[int, str, str]] = []
        self.timing_scope = not any(p in relpath for p in TIMING_ALLOW)
        self.det_scope = any(p in relpath for p in DETERMINISTIC_DIRS)
        # names imported straight into the module namespace
        self.from_time: set[str] = set()
        self.from_random: set[str] = set()
        self.classes: dict[str, ast.ClassDef] = {}
        self.registered: list[tuple[int, str]] = []  # (line, class name)

    def add(self, line: int, code: str, msg: str):
        self.findings.append((line, code, msg))

    # -- imports --------------------------------------------------------------
    def visit_ImportFrom(self, node: ast.ImportFrom):
        names = {a.asname or a.name for a in node.names}
        if node.module == "time":
            self.from_time |= names & TIMING_FUNCS["time"]
        elif node.module == "timeit":
            self.from_time |= names & TIMING_FUNCS["timeit"]
        elif node.module == "random":
            self.from_random |= names
        self.generic_visit(node)

    def visit_ClassDef(self, node: ast.ClassDef):
        self.classes[node.name] = node
        self.generic_visit(node)

    # -- calls ----------------------------------------------------------------
    def visit_Call(self, node: ast.Call):
        base, attr = _call_target(node)
        if self.timing_scope and (
            (base in TIMING_FUNCS and attr in TIMING_FUNCS[base])
            or (base is None and attr in self.from_time)
        ):
            self.add(
                node.lineno, "PH001",
                f"hand-rolled timing call {attr}(); use repro.obs.timeit / "
                f"Recorder.span (allowlisted: {', '.join(TIMING_ALLOW)})",
            )
        if self.det_scope:
            if base == "random" or (base is None and attr in self.from_random):
                self.add(
                    node.lineno, "PH002",
                    f"global-random call {attr}() in deterministic code; "
                    f"use a seeded np.random.default_rng",
                )
            elif attr in ("now", "utcnow", "today") and base is not None and (
                base.split(".")[-1] in ("datetime", "date", "dt")
            ):
                self.add(
                    node.lineno, "PH002",
                    f"wall-clock value {base}.{attr}() in deterministic "
                    f"code; thread timestamps in as arguments",
                )
            elif attr == "default_rng" and not node.args and not node.keywords:
                self.add(
                    node.lineno, "PH002",
                    "unseeded default_rng() in deterministic code; pass an "
                    "explicit seed",
                )
        if attr == "register_layer_kind" and len(node.args) >= 2:
            kind = node.args[1]
            cls = None
            if isinstance(kind, ast.Call) and isinstance(kind.func, ast.Name):
                cls = kind.func.id
            elif isinstance(kind, ast.Name):
                cls = kind.id
            if cls is not None:
                self.registered.append((node.lineno, cls))
        self.generic_visit(node)

    # -- post-pass ------------------------------------------------------------
    def check_registrations(self):
        for line, cls in self.registered:
            node = self.classes.get(cls)
            if node is None:
                continue  # class defined elsewhere: out of AST scope
            have = set()
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    have.add(item.name)
                elif isinstance(item, ast.Assign):
                    have |= {
                        t.id for t in item.targets if isinstance(t, ast.Name)
                    }
                elif isinstance(item, ast.AnnAssign) and isinstance(
                    item.target, ast.Name
                ):
                    have.add(item.target.id)
            missing = [m for m in PROTOCOL if m not in have]
            if "name" not in have:
                missing.append("name")
            if missing:
                self.add(
                    line, "PH003",
                    f"{cls} registered as a LayerKind but does not define "
                    f"{missing} (full protocol: name + "
                    f"{'/'.join(PROTOCOL)})",
                )


def lint_file(path: pathlib.Path, root: pathlib.Path) -> list[str]:
    rel = path.as_posix()
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [f"{rel}:{e.lineno or 0}: [PH000] syntax error: {e.msg}"]
    linter = _Linter(rel)
    linter.visit(tree)
    linter.check_registrations()
    return [
        f"{rel}:{line}: [{code}] {msg}"
        for line, code, msg in sorted(linter.findings)
    ]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("targets", nargs="+", help="files or directories to lint")
    args = p.parse_args(argv)
    files: list[pathlib.Path] = []
    for t in args.targets:
        path = pathlib.Path(t)
        if path.is_dir():
            files += sorted(path.rglob("*.py"))
        else:
            files.append(path)
    findings = []
    for f in files:
        findings += lint_file(f, pathlib.Path("."))
    for line in findings:
        print(line)
    if findings:
        print(f"lint_phantom: {len(findings)} finding(s) in {len(files)} files")
        return 1
    print(f"lint_phantom: OK ({len(files)} files clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
